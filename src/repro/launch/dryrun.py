"""Multi-pod dry-run: .lower().compile() for every (arch x shape x mesh).

Proves the distribution config is coherent without hardware: per cell we
lower the step under the production mesh, compile, and record
memory_analysis / cost_analysis / the collective schedule (operand bytes of
all-gather, all-reduce, reduce-scatter, all-to-all, collective-permute
parsed from the compiled HLO) into experiments/dryrun/<cell>.json for the
roofline analysis (benchmarks/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--compress-pods]
"""
from __future__ import annotations

# The dry-run needs 512 placeholder devices; jax locks the device count on
# first init, so these lines MUST precede every other import (including any
# `from repro...`). XLA honours the LAST occurrence of a repeated flag, so an
# inherited device-count override (e.g. the CI distributed lane's 8 fake
# devices) is dropped rather than prepended-around.
import os

_inherited = [f for f in os.environ.get("XLA_FLAGS", "").split()
              if not f.startswith("--xla_force_host_platform_device_count")]
os.environ["XLA_FLAGS"] = " ".join(["--xla_force_host_platform_device_count=512"] + _inherited)

import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.runtime import partitioning as part
from repro.runtime import sharding_rules as rules_mod
from repro.runtime.steps import batch_pspecs, make_prefill_step, make_serve_step, make_train_step, state_pspecs

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:\([^)]*\)|\S+))\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64|f64)\[([\d,]*)\]")

_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of collective ops in the (SPMD-partitioned) HLO,
    keyed "op" and "op/dtype" (dtype split diagnoses e.g. f32 gathers that
    should be bf16)."""
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes_blob, op = m.group(2), m.group(3)
        for sm in _SHAPE_RE.finditer(shapes_blob):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes = n * _BYTES[dt]
            out[op] = out.get(op, 0) + nbytes
            out[f"{op}/{dt}"] = out.get(f"{op}/{dt}", 0) + nbytes
    return out


def _unit_variant(cfg, k: int):
    """Config with k pattern-group units and UNROLLED layers: compiled
    cost_analysis cannot see inside while-loop bodies, so the cost probes
    inline everything and the totals extrapolate affinely in k."""
    import dataclasses

    from repro.runtime.sharding_rules import use_fsdp, use_seqpar

    return dataclasses.replace(
        cfg,
        n_layers=cfg.first_dense + k * len(cfg.pattern),
        enc_layers=k if cfg.enc_layers else 0,
        scan_layers=False,
        force_fsdp=int(use_fsdp(cfg)),      # pin the FULL model's sharding policy
        force_seqpar=int(use_seqpar(cfg)),
    )


def _compile_once(arch, shape, cfg, mesh, *, compress_pods, donate, rules_override):
    npods = mesh.shape.get("pod", 0) if compress_pods else 0
    kind, specs = input_specs(arch, shape, npods=npods, cfg=cfg)
    rules = rules_mod.activation_rules(cfg, mesh)
    if rules_override:
        rules.update(rules_override)
    rec = {"kind": kind}
    t0 = time.time()
    with part.mesh_rules(mesh, rules):
        if kind == "train":
            step = make_train_step(cfg, mesh, compress_pods=compress_pods)
            st_spec = state_pspecs(specs["state"], cfg, mesh)
            b_spec = batch_pspecs(specs["batch"], mesh)
            in_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), (st_spec, b_spec))
            jf = jax.jit(step, in_shardings=in_sh, out_shardings=(jax.tree.map(lambda s: NamedSharding(mesh, s), st_spec), None), donate_argnums=(0,) if donate else ())
            lowered = jf.lower(specs["state"], specs["batch"])
        elif kind == "prefill":
            step = make_prefill_step(cfg)
            p_spec = rules_mod.tree_pspecs(specs["params"], cfg, mesh)
            b_spec = batch_pspecs(specs["batch"], mesh)
            in_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), (p_spec, b_spec))
            jf = jax.jit(step, in_shardings=in_sh)
            lowered = jf.lower(specs["params"], specs["batch"])
        else:  # decode
            step = make_serve_step(cfg)
            p_spec = rules_mod.tree_pspecs(specs["params"], cfg, mesh)
            c_spec = rules_mod.cache_pspecs(specs["cache"], cfg, mesh, rules)
            t_spec = batch_pspecs(specs["token"], mesh)
            in_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), (p_spec, c_spec, t_spec, P()))
            out_sh = (None, jax.tree.map(lambda s: NamedSharding(mesh, s), c_spec))
            jf = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=(1,) if donate else ())
            lowered = jf.lower(specs["params"], specs["cache"], specs["token"], specs["pos"])
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        mem = compiled.memory_analysis()
        # memory_analysis is PER DEVICE (the partitioned module)
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        }
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        # flops / bytes are PER DEVICE and count each scan body ONCE
        rec["cost"] = {"flops": float(cost.get("flops", 0.0)), "bytes": float(cost.get("bytes accessed", 0.0))}
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo)
        rec["n_partitions"] = mesh.size
    return rec


def run_cell(arch: str, shape: str, *, multi_pod: bool, compress_pods: bool = False, donate: bool = True,
             rules_override=None, cfg_override=None, tag: str = "", extrapolate: bool = True):
    """Full-model compile proof + (optionally) exact per-step cost totals via
    two reduced-depth compiles: cost(k units) is affine in k, so
    total = c(1) + (G-1) * (c(2) - c(1)) with G = cfg.n_groups."""
    import dataclasses

    cfg = get_config(arch)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {
        "arch": arch, "shape": shape, "mesh": dict(mesh.shape),
        "compress_pods": bool(compress_pods), "tag": tag,
    }
    full = _compile_once(arch, shape, cfg, mesh, compress_pods=compress_pods, donate=donate, rules_override=rules_override)
    rec.update(full)
    if extrapolate:
        G = cfg.n_groups
        c1 = _compile_once(arch, shape, _unit_variant(cfg, 1), mesh, compress_pods=compress_pods, donate=donate, rules_override=rules_override)
        c2 = _compile_once(arch, shape, _unit_variant(cfg, 2), mesh, compress_pods=compress_pods, donate=donate, rules_override=rules_override)
        tot = {}
        for key in ("flops", "bytes"):
            d = c2["cost"][key] - c1["cost"][key]
            tot[key] = c1["cost"][key] + (G - 1) * d
        colls = {}
        for op in set(c1["collectives"]) | set(c2["collectives"]):
            a, b = c1["collectives"].get(op, 0), c2["collectives"].get(op, 0)
            # clamp: XLA occasionally swaps strategies between k=1 and k=2
            colls[op] = max(a + (G - 1) * (b - a), max(a, b))
        rec["cost_total"] = tot                     # per device, full depth
        rec["collectives_total"] = colls            # per device, full depth
        rec["unit_costs"] = {"c1": c1["cost"], "c2": c2["cost"], "G": G}
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--compress-pods", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    todo = list(cells()) if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch, shape in todo:
        for mp in meshes:
            name = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}{('__' + args.tag) if args.tag else ''}"
            try:
                # cost extrapolation only needed on the single-pod mesh (roofline)
                rec = run_cell(arch, shape, multi_pod=mp, compress_pods=args.compress_pods and mp,
                               tag=args.tag, extrapolate=not mp)
                (OUT_DIR / f"{name}.json").write_text(json.dumps(rec, indent=1))
                tot = rec.get("cost_total", rec["cost"])
                coll = sum(v for k, v in rec.get("collectives_total", rec["collectives"]).items() if "/" not in k)
                mem = rec["memory"]
                perdev = ((mem["argument_bytes"] or 0) + (mem["temp_bytes"] or 0) + (mem["output_bytes"] or 0) - (mem["alias_bytes"] or 0))
                print(
                    f"OK   {name}: compile={rec['compile_s']}s flops/dev={tot['flops']:.3e} "
                    f"bytes/dev={tot['bytes']:.3e} coll/dev={coll:.3e}B mem/dev={perdev / 2**30:.2f}GiB"
                )
            except Exception as e:  # noqa: BLE001 - report and continue
                failures += 1
                print(f"FAIL {name}: {type(e).__name__}: {e}")
                traceback.print_exc(limit=3)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
