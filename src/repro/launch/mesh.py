"""Production meshes (functions, never module-level constants: importing this
module must not touch jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests / elastic rescale)."""
    return jax.make_mesh(shape, axes)
