"""Batched serving driver: prefill a prompt batch, decode N tokens.

    python -m repro.launch.serve --arch gemma3-12b --scaled --tokens 32

``--kv-compress`` demonstrates error-bounded KV-cache offload on the serve
path: after prefill, every float cache leaf rides the cuSZ-Hi compressor
with the orchestrated ``pipeline="auto"`` lossless stack (best-fit
registered pipeline per leaf) into a container-v3 frame stream — one
independently decodable frame per layer tensor, appended incrementally —
then the stream is read back frame by frame and decode continues from the
reconstructed cache: the paged-out/paged-in scenario for long prompts.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_params, prefill


def _kv_roundtrip(cache, spec, compressd: str | None = None):
    """Offload+restore the float cache leaves as one v3 frame stream.

    Offload is *incremental*: each cache leaf (a layer's K or V tensor)
    compresses into its own container-v3 frame and is appended to the
    stream the moment it is ready — the paged-out bytes for layer L exist
    while layer L+1 is still encoding, instead of one monolithic
    compress-everything roundtrip. Restore streams the frames back in
    order (``FrameReader``) and rebuilds the cache leaf by leaf; each
    frame is independently decodable, so a paging implementation can pull
    back any single layer. Non-float or tiny leaves pass through untouched
    (they are index/position bookkeeping, not KV data).

    With ``compressd`` set (a daemon address, see
    :mod:`repro.launch.compressd`) the per-leaf compress/decompress runs on
    the shared daemon instead of in-process — KV layers all share a handful
    of shapes, so after the first layer every encode is a plan-cache hit,
    and many serve replicas can share one daemon's cache. The frame-stream
    format on disk is identical either way.

    Returns (restored cache, stats dict).
    """
    import io

    from repro.core import Compressor, CompressorSpec, FrameReader, FrameWriter

    if isinstance(spec, str):  # canonical spec-string grammar
        spec = CompressorSpec.from_string(spec)
    client = None
    if compressd:
        from repro.launch.compressd import CompressdClient

        client = CompressdClient(compressd, stream="serve-kv")
    comp = Compressor(spec)
    spec_str = spec.to_string()
    stats = {"raw_bytes": 0, "comp_bytes": 0, "frames": 0, "pipelines": {}}
    leaves, treedef = jax.tree.flatten(cache)

    # ---- offload: one frame per float cache leaf, streamed as produced
    # (context manager: an encode failure aborts the writer, leaving the
    # stream honestly truncated instead of trailer-sealed-but-short)
    sink = io.BytesIO()
    framed: list[int] = []  # leaf indices, in frame order
    with FrameWriter(sink, {"kind": "kvcache", "spec": spec_str}, sync=True) as writer:
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            if not jnp.issubdtype(leaf.dtype, jnp.floating) or arr.size < 4096:
                continue
            field = arr.astype(np.float32)
            if client is not None:
                buf = client.compress(field, spec=spec_str)
                if (client.last_info or {}).get("plan_cache") == "hit":
                    stats["plan_cache_hits"] = stats.get("plan_cache_hits", 0) + 1
            else:
                buf = comp.compress(field)
            writer.write_frame(buf)
            framed.append(i)
            picked = Compressor.inspect(buf).get("pipeline", "?")
            stats["raw_bytes"] += arr.size * arr.dtype.itemsize
            stats["comp_bytes"] += len(buf)
            stats["pipelines"][picked] = stats["pipelines"].get(picked, 0) + 1
    stats["frames"] = writer.close()
    stats["stream_bytes"] = sink.getbuffer().nbytes

    # ---- restore: stream the frames back, rebuilding leaf by leaf; a
    # damaged frame costs only its own layer (that leaf keeps its
    # uncompressed value), never the rest of the cache
    sink.seek(0)
    with FrameReader(sink) as reader:
        by_frame = dict(enumerate(framed))
        for k, frame in reader.iter_frames(on_error="skip"):
            i = by_frame[k]
            if client is not None:
                out = client.decompress(frame).reshape(leaves[i].shape)
            else:
                # decompress straight onto device: the decode twins keep the
                # stream resident, so the restored page never bounces via host
                out = comp.decompress(frame, out="device").reshape(leaves[i].shape)
            leaves[i] = out.astype(leaves[i].dtype)
        if not reader.damage.ok:
            stats["damage"] = reader.damage.summary()
    if client is not None:
        client.close()
    cache = jax.tree.unflatten(treedef, leaves)
    stats["cr"] = stats["raw_bytes"] / max(stats["comp_bytes"], 1)
    return cache, stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--scaled", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-compress", action="store_true",
                    help="offload/restore the prefill KV cache through pipeline='auto'")
    ap.add_argument("--kv-spec", default=None, metavar="SPEC",
                    help="compression spec string for --kv-compress "
                         "(CompressorSpec.from_string grammar; default "
                         "'lossy,rel,1e-3,autotune=false,pipeline=auto')")
    ap.add_argument("--kv-eb", type=float, default=None,
                    help="DEPRECATED: use --kv-spec 'lossy,rel,EB,...' instead")
    ap.add_argument("--compressd", default=None, metavar="ADDR",
                    help="route --kv-compress through a compressd daemon at "
                         "ADDR (host:port or unix:/path) instead of in-process")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.scaled:
        cfg = cfg.scaled()
    rng = jax.random.PRNGKey(0)
    params = init_params(cfg, rng)
    total = args.prompt_len + args.tokens
    batch = {"tokens": jax.random.randint(rng, (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.stub_frontend == "vit":
        batch["img"] = jnp.zeros((args.batch, 0, cfg.d_model), jnp.bfloat16)
    if cfg.enc_layers:
        batch["frames"] = jax.random.normal(rng, (args.batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)

    t0 = time.time()
    logits, cache = jax.jit(lambda p, b: prefill(p, cfg, b, cache_len=total))(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    if args.kv_compress:
        kv_spec = args.kv_spec or "lossy,rel,1e-3,autotune=false,pipeline=auto"
        if args.kv_eb is not None:
            if args.kv_spec is not None:
                ap.error("--kv-eb and --kv-spec are mutually exclusive")
            import warnings

            warnings.warn("--kv-eb is deprecated; use --kv-spec "
                          f"'lossy,rel,{args.kv_eb:g},autotune=false,pipeline=auto'",
                          DeprecationWarning, stacklevel=2)
            kv_spec = f"lossy,rel,{args.kv_eb:g},autotune=false,pipeline=auto"
        t0 = time.time()
        cache, kv = _kv_roundtrip(cache, kv_spec, compressd=args.compressd)
        via = f" via compressd {args.compressd} ({kv.get('plan_cache_hits', 0)} plan-cache hits)" \
            if args.compressd else ""
        print(
            f"kv-cache offload: {kv['raw_bytes']/2**20:.1f} MiB -> {kv['comp_bytes']/2**20:.1f} MiB "
            f"in {kv['frames']} layer-frames (CR {kv['cr']:.2f}, spec={kv_spec!r}, "
            f"pipelines {kv['pipelines']}, {time.time()-t0:.2f}s roundtrip){via}"
        )

    dstep = jax.jit(lambda p, c, t, i: decode_step(p, cfg, t, i, c), donate_argnums=(1,))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.tokens - 1):
        logits, cache = dstep(params, cache, tok, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t0
    toks = np.stack([np.asarray(t) for t in out], 1)
    print(f"prefill {args.prompt_len} tok x{args.batch}: {t_prefill*1e3:.1f} ms")
    print(f"decode  {args.tokens} tok x{args.batch}: {t_decode*1e3:.1f} ms ({args.tokens*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("sample:", toks[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
