"""End-to-end training driver.

    python -m repro.launch.train --arch yi-34b --scaled --steps 200
    python -m repro.launch.train --arch olmoe-1b-7b --scaled --mesh 1,2 ...

--scaled trains the reduced config (CPU-feasible); the full configs are
exercised through the dry-run. With a mesh, params/batch are sharded per
runtime.sharding_rules; with --ckpt-eb the checkpoints go through the
cuSZ-Hi codec.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import get_config
from repro.data import Prefetcher, TokenPipeline
from repro.launch.mesh import make_mesh
from repro.runtime import partitioning as part
from repro.runtime import sharding_rules as rules_mod
from repro.runtime.steps import batch_pspecs, make_train_state, make_train_step, state_pspecs
from repro.runtime.train_loop import LoopConfig, Trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-34b")
    ap.add_argument("--scaled", action="store_true", help="train the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="", help="e.g. 2,2 -> (data,model); 2,2,2 -> (pod,data,model)")
    ap.add_argument("--compress-pods", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-eb", type=float, default=0.0)
    ap.add_argument("--save-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.scaled:
        cfg = cfg.scaled()
    mesh = None
    rules = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = make_mesh(dims, axes)
        rules = rules_mod.activation_rules(cfg, mesh)

    extras = {}
    if cfg.stub_frontend == "vit":
        extras["img"] = (cfg.n_img_tokens, cfg.d_model)
    if cfg.enc_layers:
        extras["frames"] = (cfg.enc_seq, cfg.d_model)
    data = Prefetcher(TokenPipeline(cfg.vocab, args.batch, args.seq, extras=extras))

    with part.mesh_rules(mesh, rules):
        npods = mesh.shape.get("pod", 0) if (mesh and args.compress_pods) else 0
        state = make_train_state(cfg, jax.random.PRNGKey(0), npods=npods)
        step = make_train_step(cfg, mesh, lr=args.lr, compress_pods=args.compress_pods)
        if mesh is not None:
            shapes = jax.eval_shape(lambda: state)
            st_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), state_pspecs(shapes, cfg, mesh))
            state = jax.device_put(state, st_sh)
            sample = next(iter([next(data)]))
            b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_pspecs(jax.eval_shape(lambda: sample), mesh))
            step_j = jax.jit(step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None), donate_argnums=(0,))
            data = ( {k: jax.device_put(v, b_sh[k]) for k, v in b.items()} for b in data)
        else:
            step_j = jax.jit(step, donate_argnums=(0,))
        trainer = Trainer(
            step_j,
            state,
            data,
            LoopConfig(total_steps=args.steps, save_every=args.save_every, ckpt_dir=args.ckpt_dir, ckpt_eb=args.ckpt_eb),
        )
        trainer.run()
        losses = trainer.losses
        if losses:
            k = max(len(losses) // 5, 1)
            print(f"first-{k} mean loss {np.mean(losses[:k]):.4f} -> last-{k} {np.mean(losses[-k:]):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
