"""ShapeDtypeStruct stand-ins for every model input — no device allocation.

input_specs(arch, shape) gives the *step argument* specs for the cell:
  train_4k   -> train_step(state, batch)
  prefill_32k-> prefill_step(params, batch)
  decode_32k / long_500k -> serve_step(params, cache, token, pos)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig
from repro.models import init_cache, init_params
from repro.runtime.steps import TrainState, make_train_state


def batch_specs(cfg: ModelConfig, seq: int, gbatch: int) -> dict:
    """Training/prefill batch: tokens+labels (+ stub-frontend embeddings)."""
    text = seq
    out = {}
    if cfg.stub_frontend == "vit":
        text = seq - cfg.n_img_tokens
        out["img"] = jax.ShapeDtypeStruct((gbatch, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.enc_layers:
        out["frames"] = jax.ShapeDtypeStruct((gbatch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    out["tokens"] = jax.ShapeDtypeStruct((gbatch, text), jnp.int32)
    out["labels"] = jax.ShapeDtypeStruct((gbatch, text), jnp.int32)
    return out


def state_specs(cfg: ModelConfig, *, npods: int = 0) -> TrainState:
    return jax.eval_shape(lambda: make_train_state(cfg, jax.random.PRNGKey(0), npods=npods))


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def cache_specs(cfg: ModelConfig, gbatch: int, seq: int):
    return jax.eval_shape(lambda: init_cache(cfg, gbatch, seq))


def input_specs(arch: str, shape: str, *, npods: int = 0, cfg: ModelConfig | None = None):
    """Returns (kind, specs dict) for the (arch x shape) cell."""
    cfg = cfg or get_config(arch)
    seq, gbatch, kind = SHAPES[shape]
    if kind == "train":
        return kind, {
            "state": state_specs(cfg, npods=npods),
            "batch": batch_specs(cfg, seq, gbatch),
        }
    if kind == "prefill":
        return kind, {
            "params": params_specs(cfg),
            "batch": batch_specs(cfg, seq, gbatch),
        }
    # decode: one new token against a cache of `seq`
    return kind, {
        "params": params_specs(cfg),
        "cache": cache_specs(cfg, gbatch, seq),
        "token": jax.ShapeDtypeStruct((gbatch,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
