"""compressd — multi-tenant streaming compression daemon.

    python -m repro.launch.compressd --addr 127.0.0.1:7733 --workers 4

Every consumer so far (checkpoint saver, ``serve --kv-compress``,
gradient packing) links the compressor in-process and pays autotuning
per call. ``compressd`` productionizes the ROADMAP's "compression
service surface": one daemon accepts many concurrent compress/decompress
streams (checkpoint shards, KV pages, field snapshots) over a local
socket, feeds them through the batched compressor on a worker pool, and
shares one LRU plan cache (:class:`repro.core.plancache.PlanCache`)
across all tenants — the heavy-traffic case is the same tensor shapes
arriving forever, so recurring signatures skip re-autotuning entirely.

Protocol (v1, length-prefixed binary, symmetric request/response)::

    frame := b"CPD1" | u32 header_len | header | u64 payload_len | payload

with ``header`` a :mod:`repro.core.serial` dict. Request headers carry
``op`` plus op-specific fields; response headers carry ``ok`` and either
results or ``error``/``message`` (the client re-raises the matching
typed exception from :mod:`repro.core.errors`). Ops:

``compress``
    header ``{op, shape, dtype, stream?, spec?}``, payload = raw array
    bytes (C order). ``spec`` is a whitelisted CompressorSpec kwargs dict
    (eb, eb_mode, predictor, pipeline, ...). Response: CR/MB/s/plan-cache
    outcome in the header, the container bytes as payload.
``decompress``
    payload = any v1/v2/v3 container; response payload = raw float32
    field bytes, shape/dtype in the header.
``stats``
    per-stream telemetry (CR, MB/s, request counts), queue depth,
    in-flight bytes, plan-cache hit rate, totals.
``health``
    cheap liveness + load snapshot (draining flag, in-flight bytes,
    queued admissions). Like ``stats`` it bypasses admission entirely,
    so a supervisor's probe succeeds even when the daemon is saturated
    or mid-drain.
``ping`` / ``shutdown``
    liveness / orderly remote stop.
``sleep``
    diagnostic op (used by the backpressure tests and load drills): holds
    its payload's in-flight budget for ``seconds`` without computing.

Backpressure — the daemon *degrades, never dies*: admission control runs
after the request prefix but **before** the payload is read off the
socket, so queued and rejected requests never buffer bytes.

* payload larger than ``max_request_bytes`` -> drained and rejected with
  :class:`repro.core.errors.RequestTooLargeError`;
* admitting would exceed ``max_inflight_bytes`` -> the request *queues*
  (bytes stay in the kernel buffer / sender blocks) until capacity
  frees, up to ``queue_depth`` concurrent waiters;
* queue at its depth cap -> immediate
  :class:`repro.core.errors.ServiceOverloadedError` (load shed).

Zero-payload control ops (stats/ping/health/shutdown) bypass admission
and run on the connection thread, so observability stays responsive
under load. Per-request faults (bad spec, damaged container, engine
failure past the compressor's own fallback ladder) become typed error
responses; the worker pool and the other streams are untouched.

Survivability — the daemon also *exits* cleanly and refuses to wedge:

* **deadlines** — ``deadline_ms`` (``REPRO_COMPRESSD_DEADLINE_MS``)
  bounds each request from admission through handler completion; a
  request that blows its budget gets a typed
  :class:`repro.core.errors.DeadlineExceededError` response and its
  in-flight byte reservation is released only once the straggling worker
  actually finishes (a done-callback), so the admission ledger never
  leaks capacity;
* **idle reaping** — a connection silent for ``idle_s``
  (``REPRO_COMPRESSD_IDLE_S``) is closed, so leaked client sockets do
  not pin connection threads forever;
* **graceful drain** — SIGTERM (or :meth:`CompressdServer.drain`) stops
  accepting: the listener closes (unix socket unlinked immediately, so
  restarts can rebind), new requests on live connections shed with
  ``ServiceOverloadedError``, in-flight requests run to completion up to
  ``REPRO_COMPRESSD_DRAIN_S``, then the daemon closes;
* **stale sockets** — binding a unix path that exists probes it first:
  a dead owner's leftover socket is unlinked and replaced, a live
  daemon's socket raises instead of hijacking it.

Env knobs (flags win): ``REPRO_COMPRESSD_WORKERS``,
``REPRO_COMPRESSD_QUEUE_DEPTH``, ``REPRO_COMPRESSD_MAX_REQUEST_MB``,
``REPRO_COMPRESSD_INFLIGHT_MB``, ``REPRO_COMPRESSD_PLANS`` (plan-cache
entries), ``REPRO_COMPRESSD_DEADLINE_MS`` (0 = no deadline),
``REPRO_COMPRESSD_IDLE_S``, ``REPRO_COMPRESSD_DRAIN_S``. Clients:
:class:`CompressdClient` here (opt-in bounded retry via ``retries=``),
``serve --compressd ADDR`` for KV paging, ``REPRO_COMPRESSD`` for the
checkpoint codec.
"""
from __future__ import annotations

import argparse
import os
import signal
import socket
import struct
import threading
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeoutError

import numpy as np

from repro.core import errors as _errors
from repro.core import Compressor, CompressorSpec, PlanCache
from repro.core.errors import (
    DeadlineExceededError,
    RequestTooLargeError,
    ServiceError,
    ServiceOverloadedError,
    ServiceProtocolError,
    SpecError,
)
from repro.core.retry import RetryPolicy, retry_call
from repro.core.serial import pack_obj, unpack_obj

MAGIC = b"CPD1"
_PREFIX = struct.Struct("<I")   # header length
_PLEN = struct.Struct("<Q")     # payload length
_MAX_HEADER = 1 << 20           # 1 MiB of header is already absurd
_DRAIN_CHUNK = 1 << 16

# CompressorSpec kwargs a request may set; everything else is rejected so a
# client typo cannot silently fall back to defaults
_SPEC_KEYS = frozenset({
    "eb", "eb_mode", "predictor", "pipeline", "anchor_stride", "autotune",
    "reorder", "backend", "engine", "splines", "schemes",
    "pipeline_candidates", "plan_anchor_strides", "psnr_target", "verify",
})

# zero-payload ops served on the connection thread, bypassing admission
_CONTROL_OPS = ("stats", "ping", "health", "shutdown")


def _env_int(name: str, default: int) -> int:
    try:
        v = int(os.environ.get(name, ""))
        return v if v > 0 else default
    except ValueError:
        return default


def _env_nonneg(name: str, default: float) -> float:
    """Like :func:`_env_int` but float-valued and 0 is a legal setting
    (0 disables the knob rather than falling back to the default)."""
    try:
        v = float(os.environ.get(name, ""))
        return v if v >= 0 else default
    except ValueError:
        return default


def default_workers() -> int:
    return _env_int("REPRO_COMPRESSD_WORKERS", 4)


# ------------------------------------------------------------------ framing
def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            raise ConnectionError("peer closed mid-frame")
        got += k
    return bytes(buf)


def _drain(sock: socket.socket, n: int) -> None:
    """Discard ``n`` payload bytes without materializing them (rejections
    stay O(chunk) in memory and keep the stream framing intact)."""
    left = n
    while left > 0:
        k = len(sock.recv(min(left, _DRAIN_CHUNK)))
        if k == 0:
            raise ConnectionError("peer closed mid-frame")
        left -= k


def pack_frame(header: dict, payload: bytes = b"") -> bytes:
    hb = pack_obj(header)
    return MAGIC + _PREFIX.pack(len(hb)) + hb + _PLEN.pack(len(payload)) + payload


def _read_prefix(sock: socket.socket) -> tuple[dict, int]:
    """Read one frame's magic + header + payload length (NOT the payload —
    admission control decides whether the payload is read or drained)."""
    magic = _recv_exact(sock, len(MAGIC))
    if magic != MAGIC:
        raise ServiceProtocolError(f"bad frame magic {magic!r}; expected {MAGIC!r}")
    (hlen,) = _PREFIX.unpack(_recv_exact(sock, _PREFIX.size))
    if hlen > _MAX_HEADER:
        raise ServiceProtocolError(f"header length {hlen} exceeds {_MAX_HEADER}")
    try:
        header = unpack_obj(_recv_exact(sock, hlen))
    except Exception as e:
        raise ServiceProtocolError(f"undecodable header: {e!r}") from e
    if not isinstance(header, dict):
        raise ServiceProtocolError(f"header must be a dict, got {type(header).__name__}")
    (plen,) = _PLEN.unpack(_recv_exact(sock, _PLEN.size))
    return header, plen


def read_frame(sock: socket.socket) -> tuple[dict, bytes]:
    header, plen = _read_prefix(sock)
    return header, _recv_exact(sock, plen)


def parse_addr(addr: str):
    """``host:port`` (TCP) or ``unix:/path`` -> (family, sockaddr)."""
    if addr.startswith("unix:"):
        return socket.AF_UNIX, addr[len("unix:"):]
    host, _, port = addr.rpartition(":")
    if not host or not port:
        raise ValueError(f"address must be 'host:port' or 'unix:/path', got {addr!r}")
    return socket.AF_INET, (host, int(port))


# ------------------------------------------------------------------- server
class CompressdServer:
    """The daemon. ``start()`` serves from a background thread (tests,
    in-process benches); ``serve_forever()`` blocks (the CLI). Both accept
    one thread per connection, with requests executed on a shared
    ``workers``-wide pool — concurrency scales with client count while a
    single connection stays strictly ordered."""

    def __init__(self, addr: str = "127.0.0.1:0", *, workers: int | None = None,
                 queue_depth: int | None = None, max_request_bytes: int | None = None,
                 max_inflight_bytes: int | None = None, plan_cache: PlanCache | None = None,
                 plan_cache_entries: int | None = None, allow_shutdown: bool = True,
                 deadline_ms: float | None = None, idle_s: float | None = None,
                 drain_s: float | None = None):
        self.workers = workers if workers is not None else default_workers()
        # survivability knobs; 0 disables (no deadline / no idle reaping)
        self.deadline_ms = (float(deadline_ms) if deadline_ms is not None
                            else _env_nonneg("REPRO_COMPRESSD_DEADLINE_MS", 0.0))
        self.idle_s = (float(idle_s) if idle_s is not None
                       else _env_nonneg("REPRO_COMPRESSD_IDLE_S", 300.0))
        self.drain_s = (float(drain_s) if drain_s is not None
                        else _env_nonneg("REPRO_COMPRESSD_DRAIN_S", 30.0))
        self.queue_depth = (queue_depth if queue_depth is not None
                            else _env_int("REPRO_COMPRESSD_QUEUE_DEPTH", 32))
        self.max_request_bytes = (max_request_bytes if max_request_bytes is not None
                                  else _env_int("REPRO_COMPRESSD_MAX_REQUEST_MB", 256) << 20)
        self.max_inflight_bytes = (max_inflight_bytes if max_inflight_bytes is not None
                                   else _env_int("REPRO_COMPRESSD_INFLIGHT_MB", 512) << 20)
        # a lone maximal request must always be admissible, else it would
        # queue forever against an empty daemon
        self.max_inflight_bytes = max(self.max_inflight_bytes, self.max_request_bytes)
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache(
            plan_cache_entries if plan_cache_entries is not None
            else _env_int("REPRO_COMPRESSD_PLANS", 256))
        self.allow_shutdown = allow_shutdown

        self._family, sockaddr = parse_addr(addr)
        self._listener = socket.socket(self._family, socket.SOCK_STREAM)
        if self._family == socket.AF_INET:
            self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._unix_path = sockaddr if self._family == socket.AF_UNIX else None
        if self._unix_path and os.path.exists(self._unix_path):
            self._reclaim_stale_socket(self._unix_path)
        self._listener.bind(sockaddr)
        self._listener.listen(128)
        # periodic accept timeout: closing the listener from another thread
        # does not reliably wake a blocked accept(), so the loop polls the
        # closing flag instead
        self._listener.settimeout(0.2)

        self._pool = ThreadPoolExecutor(max_workers=self.workers,
                                        thread_name_prefix="compressd-worker")
        self._closing = threading.Event()
        self._accept_thread: threading.Thread | None = None
        self._conns: set[socket.socket] = set()
        self._conn_threads: list[threading.Thread] = []
        self._conn_lock = threading.Lock()

        # admission state (condition guards the byte/waiter counters)
        self._cv = threading.Condition()
        self._inflight_bytes = 0
        self._queued = 0
        self._draining = threading.Event()
        self._drain_lock = threading.Lock()  # serializes concurrent drain() calls

        # telemetry (single lock; all counters are cheap increments)
        self._tlock = threading.Lock()
        self._t0 = time.time()
        self._streams: dict[str, dict] = {}
        self._rejected_overload = 0
        self._rejected_oversize = 0
        self._deadline_exceeded = 0
        self._idle_reaped = 0
        self._errors = 0

        # one Compressor per canonical spec, all sharing the plan cache;
        # Compressor per-call state is thread-local, so sharing instances
        # across the worker pool is safe
        self._comps: dict[tuple, Compressor] = {}
        self._comp_lock = threading.Lock()

    # ------------------------------------------------------------ lifecycle
    @staticmethod
    def _reclaim_stale_socket(path: str) -> None:
        """A unix socket path left behind by a dead daemon (SIGKILL, OOM)
        would make every restart fail with EADDRINUSE. Probe it: nobody
        answering -> unlink and rebind; a live daemon -> raise rather than
        hijack its address."""
        probe = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        probe.settimeout(0.5)
        try:
            probe.connect(path)
        except (ConnectionRefusedError, ConnectionResetError, socket.timeout,
                FileNotFoundError):
            try:
                os.unlink(path)
            except FileNotFoundError:
                pass
        else:
            raise OSError(
                f"unix socket {path!r} has a live daemon; refusing to replace it")
        finally:
            probe.close()

    @property
    def address(self) -> str:
        if self._family == socket.AF_UNIX:
            return f"unix:{self._unix_path}"
        host, port = self._listener.getsockname()
        return f"{host}:{port}"

    def start(self) -> "CompressdServer":
        self._accept_thread = threading.Thread(target=self._accept_loop,
                                               name="compressd-accept", daemon=True)
        self._accept_thread.start()
        return self

    def serve_forever(self) -> None:
        self._accept_loop()

    def drain(self, budget_s: float | None = None) -> None:
        """Graceful stop: quit accepting (listener closed, unix socket
        unlinked so a successor can bind immediately), shed new requests
        on live connections, let in-flight work finish for up to
        ``budget_s`` (default ``drain_s``), then close. Idempotent; a
        second concurrent call blocks until the first finishes."""
        with self._drain_lock:
            if self._closing.is_set():
                return
            self._draining.set()
            try:
                self._listener.close()
            except OSError:
                pass
            if self._unix_path:
                try:
                    os.unlink(self._unix_path)
                except OSError:
                    pass
            budget = self.drain_s if budget_s is None else float(budget_s)
            deadline = time.monotonic() + budget
            with self._cv:
                while self._inflight_bytes > 0 and time.monotonic() < deadline:
                    self._cv.wait(0.05)
            self.close()

    def close(self) -> None:
        if self._closing.is_set():
            return
        self._closing.set()
        try:
            self._listener.close()
        finally:
            if self._unix_path:
                try:
                    os.unlink(self._unix_path)
                except OSError:
                    pass
        with self._cv:
            self._cv.notify_all()  # wake queued admissions so they abort
        with self._conn_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        self._pool.shutdown(wait=False)
        me = threading.current_thread()
        if self._accept_thread is not None and self._accept_thread is not me:
            self._accept_thread.join(timeout=5)
        for t in self._conn_threads:
            if t is not me:  # a conn thread may trigger close() via "shutdown"
                t.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------ accepting
    def _accept_loop(self) -> None:
        while not self._closing.is_set():
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue  # poll the closing flag
            except OSError:
                break  # listener closed
            if self._family == socket.AF_INET:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            if self.idle_s > 0:
                # a connection silent past idle_s raises socket.timeout in
                # _read_prefix and gets reaped (leaked clients can't pin
                # connection threads forever)
                conn.settimeout(self.idle_s)
            with self._conn_lock:
                self._conns.add(conn)
                t = threading.Thread(target=self._serve_conn, args=(conn,),
                                     name="compressd-conn", daemon=True)
                self._conn_threads.append(t)
            t.start()

    # ------------------------------------------------------------ admission
    def _admit(self, payload_len: int, deadline: float | None = None) -> None:
        """Reserve ``payload_len`` in-flight bytes, queueing up to the
        depth cap. Raises the typed rejection errors; on return the bytes
        are reserved and MUST be released via :meth:`_release`.
        ``deadline`` (``time.monotonic()`` instant) bounds the queue wait:
        a request cannot burn its whole budget waiting for admission."""
        if self._draining.is_set():
            with self._tlock:
                self._rejected_overload += 1
            raise ServiceOverloadedError(
                "server is draining: finishing in-flight requests, not "
                "accepting new work")
        if payload_len > self.max_request_bytes:
            with self._tlock:
                self._rejected_oversize += 1
            raise RequestTooLargeError(
                f"request payload {payload_len} B exceeds max_request_bytes="
                f"{self.max_request_bytes}")
        with self._cv:
            if self._inflight_bytes + payload_len > self.max_inflight_bytes:
                if self._queued >= self.queue_depth:
                    with self._tlock:
                        self._rejected_overload += 1
                    raise ServiceOverloadedError(
                        f"admission queue full ({self._queued} waiting, depth cap "
                        f"{self.queue_depth}, {self._inflight_bytes} B in flight)")
                self._queued += 1
                try:
                    while self._inflight_bytes + payload_len > self.max_inflight_bytes:
                        if self._closing.is_set() or self._draining.is_set():
                            raise ServiceError("server shutting down")
                        if deadline is not None and time.monotonic() >= deadline:
                            with self._tlock:
                                self._deadline_exceeded += 1
                            raise DeadlineExceededError(
                                f"request deadline ({self.deadline_ms:g} ms) expired "
                                f"while queued for admission")
                        self._cv.wait(0.05)
                finally:
                    self._queued -= 1
            self._inflight_bytes += payload_len

    def _release(self, payload_len: int) -> None:
        with self._cv:
            self._inflight_bytes -= payload_len
            self._cv.notify_all()

    # ----------------------------------------------------------- connection
    def _serve_conn(self, sock: socket.socket) -> None:
        try:
            while not self._closing.is_set():
                try:
                    header, plen = _read_prefix(sock)
                except socket.timeout:
                    with self._tlock:
                        self._idle_reaped += 1
                    break  # idle connection reaped
                except (ConnectionError, OSError):
                    break
                except ServiceProtocolError as e:
                    self._send_error(sock, e)
                    break  # framing is lost; the connection cannot recover
                op = str(header.get("op", ""))
                if plen == 0 and op in _CONTROL_OPS:
                    # control ops bypass admission and the pool: they must
                    # stay responsive exactly when the daemon is saturated
                    self._respond(sock, *self._handle_control(op))
                    if op == "shutdown" and self.allow_shutdown:
                        self.close()
                        break
                    continue
                deadline = (time.monotonic() + self.deadline_ms / 1e3
                            if self.deadline_ms > 0 else None)
                try:
                    self._admit(plen, deadline)
                except ServiceError as e:
                    try:
                        _drain(sock, plen)
                        self._send_error(sock, e)
                        continue
                    except (ConnectionError, OSError):
                        break
                # bytes are reserved from here; released on the normal path
                # below, or by the done-callback when a deadline strands the
                # worker (releasing early would lie to admission control —
                # the straggler still holds memory until it finishes)
                released = False
                try:
                    payload = _recv_exact(sock, plen)
                    fut = self._pool.submit(self._handle, header, payload)
                    try:
                        budget = (None if deadline is None
                                  else max(0.0, deadline - time.monotonic()))
                        rh, rp = fut.result(timeout=budget)
                    except FutureTimeoutError:
                        fut.cancel()  # still queued -> never runs
                        fut.add_done_callback(
                            lambda f, n=plen: self._reap_stranded(f, n))
                        released = True
                        with self._tlock:
                            self._deadline_exceeded += 1
                        e = DeadlineExceededError(
                            f"request exceeded its {self.deadline_ms:g} ms deadline "
                            f"(op {op!r}, {plen} B payload)")
                        rh, rp = self._error_response(e), b""
                    except ServiceError as e:
                        rh, rp = self._error_response(e), b""
                    except Exception as e:  # degrade, never die
                        rh, rp = self._error_response(e), b""
                finally:
                    if not released:
                        self._release(plen)
                if not self._respond(sock, rh, rp):
                    break
        finally:
            with self._conn_lock:
                self._conns.discard(sock)
            try:
                sock.close()
            except OSError:
                pass

    def _reap_stranded(self, fut, payload_len: int) -> None:
        """Done-callback for a worker that outlived its request's deadline:
        release the in-flight reservation now that the bytes are truly free,
        and swallow the orphaned result/exception (the error response was
        already sent)."""
        try:
            if not fut.cancelled():
                fut.exception()
        finally:
            self._release(payload_len)

    def _respond(self, sock, header: dict, payload: bytes) -> bool:
        try:
            sock.sendall(pack_frame(header, payload))
            return True
        except (ConnectionError, OSError):
            return False

    def _error_response(self, e: Exception) -> dict:
        with self._tlock:
            self._errors += 1
        return {"ok": False, "error": type(e).__name__, "message": str(e)}

    def _send_error(self, sock, e: Exception) -> bool:
        return self._respond(sock, self._error_response(e), b"")

    # ------------------------------------------------------------- handlers
    def _compressor(self, spec_req) -> Compressor:
        """Resolve a request's ``spec`` field to a (cached) Compressor.

        The canonical wire form is the spec *string* (the
        ``CompressorSpec.from_string`` grammar) — one opaque value, parsed
        and validated in one place. The legacy dict-of-kwargs form still
        works (key-whitelisted as before) so old clients keep running; the
        client side deprecates it."""
        if isinstance(spec_req, str):
            try:
                spec = CompressorSpec.from_string(spec_req)
            except SpecError as e:
                raise ServiceProtocolError(f"bad spec string: {e}") from e
            key = ("spec", spec_req)
        else:
            kw = {}
            for k, v in (spec_req or {}).items():
                if k not in _SPEC_KEYS:
                    raise ServiceProtocolError(
                        f"unknown spec field {k!r}; allowed: {', '.join(sorted(_SPEC_KEYS))}")
                kw[k] = tuple(v) if isinstance(v, list) else v
            # bad field values keep raising as before (ValueError on the wire)
            spec = CompressorSpec(**kw)
            key = tuple(sorted(kw.items()))
        with self._comp_lock:
            comp = self._comps.get(key)
            if comp is None:
                comp = Compressor(spec, plan_cache=self.plan_cache)
                self._comps[key] = comp
        return comp

    def _stream(self, name: str) -> dict:
        rec = self._streams.get(name)
        if rec is None:
            rec = self._streams[name] = {
                "requests": 0, "errors": 0, "raw_bytes": 0, "comp_bytes": 0,
                "seconds": 0.0, "plan_cache_hits": 0, "plan_cache_misses": 0,
            }
        return rec

    def _handle(self, header: dict, payload: bytes) -> tuple[dict, bytes]:
        op = str(header.get("op", ""))
        if op == "compress":
            return self._op_compress(header, payload)
        if op == "decompress":
            return self._op_decompress(header, payload)
        if op == "sleep":  # diagnostic: hold the in-flight budget, do nothing
            time.sleep(min(float(header.get("seconds", 0.0)), 30.0))
            return {"ok": True, "held_bytes": len(payload)}, b""
        raise ServiceProtocolError(f"unknown op {op!r}")

    def _handle_control(self, op: str) -> tuple[dict, bytes]:
        if op == "ping":
            return {"ok": True, "pong": True}, b""
        if op == "health":
            with self._cv:
                inflight, queued = self._inflight_bytes, self._queued
            return {
                "ok": True,
                "healthy": not self._closing.is_set(),
                "draining": self._draining.is_set(),
                "inflight_bytes": inflight,
                "queued": queued,
                "deadline_ms": self.deadline_ms,
                "uptime_s": time.time() - self._t0,
            }, b""
        if op == "shutdown":
            if not self.allow_shutdown:
                return self._error_response(ServiceError("remote shutdown disabled")), b""
            return {"ok": True, "shutting_down": True}, b""
        return {"ok": True, **self.stats()}, b""

    def _op_compress(self, header: dict, payload: bytes) -> tuple[dict, bytes]:
        stream = str(header.get("stream", "default"))
        try:
            shape = tuple(int(s) for s in header["shape"])
            dtype = np.dtype(str(header.get("dtype", "float32")))
        except (KeyError, TypeError) as e:
            raise ServiceProtocolError(f"compress needs shape/dtype: {e!r}") from e
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        if n * dtype.itemsize != len(payload):
            raise ServiceProtocolError(
                f"payload is {len(payload)} B but shape {shape} dtype {dtype} "
                f"needs {n * dtype.itemsize} B")
        arr = np.frombuffer(payload, dtype=dtype).reshape(shape)
        comp = self._compressor(header.get("spec") or {})
        t0 = time.perf_counter()
        try:
            buf = comp.compress(arr)
        except Exception:
            with self._tlock:
                self._stream(stream)["errors"] += 1
            raise
        dt = time.perf_counter() - t0
        tel = comp.last_telemetry or {}
        cache_state = tel.get("plan_cache")
        with self._tlock:
            rec = self._stream(stream)
            rec["requests"] += 1
            rec["raw_bytes"] += len(payload)
            rec["comp_bytes"] += len(buf)
            rec["seconds"] += dt
            if cache_state == "hit":
                rec["plan_cache_hits"] += 1
            elif cache_state == "miss":
                rec["plan_cache_misses"] += 1
        info = {
            "ok": True, "cr": len(payload) / max(len(buf), 1), "seconds": dt,
            "mbps": len(payload) / dt / 1e6 if dt > 0 else 0.0,
            "plan_cache": cache_state, "pipeline": tel.get("pipeline"),
            "fallbacks": len(tel.get("fallbacks") or ()),
        }
        return info, buf

    def _op_decompress(self, header: dict, payload: bytes) -> tuple[dict, bytes]:
        stream = str(header.get("stream", "default"))
        comp = self._compressor(header.get("spec") or {})
        t0 = time.perf_counter()
        try:
            out = comp.decompress(bytes(payload))
        except Exception:
            with self._tlock:
                self._stream(stream)["errors"] += 1
            raise
        dt = time.perf_counter() - t0
        raw = out.tobytes()
        with self._tlock:
            rec = self._stream(stream)
            rec["requests"] += 1
            rec["raw_bytes"] += len(raw)
            rec["comp_bytes"] += len(payload)
            rec["seconds"] += dt
        info = {"ok": True, "shape": list(out.shape), "dtype": str(out.dtype),
                "seconds": dt, "mbps": len(raw) / dt / 1e6 if dt > 0 else 0.0}
        return info, raw

    # ------------------------------------------------------------ telemetry
    def stats(self) -> dict:
        with self._cv:
            queue = {
                "inflight_bytes": self._inflight_bytes,
                "queued": self._queued,
                "queue_depth": self.queue_depth,
                "max_inflight_bytes": self.max_inflight_bytes,
                "max_request_bytes": self.max_request_bytes,
            }
        with self._tlock:
            streams = {}
            totals = {"requests": 0, "errors": self._errors, "raw_bytes": 0,
                      "comp_bytes": 0, "seconds": 0.0}
            for name, rec in self._streams.items():
                view = dict(rec)
                view["cr"] = rec["raw_bytes"] / max(rec["comp_bytes"], 1)
                view["mbps"] = (rec["raw_bytes"] / rec["seconds"] / 1e6
                                if rec["seconds"] > 0 else 0.0)
                streams[name] = view
                for k in ("requests", "raw_bytes", "comp_bytes", "seconds"):
                    totals[k] += rec[k]
            queue["rejected_overload"] = self._rejected_overload
            queue["rejected_oversize"] = self._rejected_oversize
            queue["deadline_exceeded"] = self._deadline_exceeded
            queue["idle_reaped"] = self._idle_reaped
        return {
            "uptime_s": time.time() - self._t0,
            "workers": self.workers,
            "draining": self._draining.is_set(),
            "queue": queue,
            "plan_cache": self.plan_cache.stats(),
            "streams": streams,
            "totals": totals,
        }


# ------------------------------------------------------------------- client
class CompressdClient:
    """Blocking client for one daemon connection.

    Not thread-safe: one client per thread (connections are cheap; the
    daemon's concurrency comes from many connections). Errors reported by
    the daemon re-raise as the matching typed exception from
    :mod:`repro.core.errors` (falling back to :class:`ServiceError`).
    ``last_info`` keeps the most recent response header (CR, MB/s,
    plan-cache outcome) for observability.

    ``retries`` opts into bounded retry with exponential backoff on
    *transient* failures — load shed (``ServiceOverloadedError``) and
    broken connections (daemon restarting, drain-window races). Default
    0: callers that want to see backpressure (and the tests that assert
    it) see the raw typed errors. Deadline expiries and protocol/spec
    errors never retry — resending the identical request would just burn
    another deadline.
    """

    def __init__(self, addr: str, *, timeout: float = 120.0, stream: str | None = None,
                 retries: int = 0, retry_backoff_s: float = 0.05):
        self.addr = addr
        self.timeout = timeout
        self.stream = stream
        self.retries = int(retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.last_info: dict | None = None
        self._sock: socket.socket | None = None

    # ------------------------------------------------------------ transport
    def _connect(self) -> socket.socket:
        if self._sock is None:
            family, sockaddr = parse_addr(self.addr)
            s = socket.socket(family, socket.SOCK_STREAM)
            s.settimeout(self.timeout)
            s.connect(sockaddr)
            if family == socket.AF_INET:
                s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._sock = s
        return self._sock

    def request(self, header: dict, payload: bytes = b"") -> tuple[dict, bytes]:
        """One request/response exchange; raises the daemon's typed error.
        With ``retries > 0``, shed/connection failures re-send the request
        (it lives entirely in this frame, so a resend is safe) after
        exponential backoff; other errors raise immediately."""
        if self.retries <= 0:
            return self._request_once(header, payload)
        policy = RetryPolicy(
            attempts=self.retries + 1, base_delay=self.retry_backoff_s,
            retry_on=(ServiceOverloadedError, ConnectionError, OSError))
        return retry_call(lambda: self._request_once(header, payload), policy=policy)

    def _request_once(self, header: dict, payload: bytes = b"") -> tuple[dict, bytes]:
        sock = self._connect()
        try:
            sock.sendall(pack_frame(header, payload))
            rh, rp = read_frame(sock)
        except (ConnectionError, OSError, struct.error):
            self.close()  # framing is lost; force a reconnect next time
            raise
        self.last_info = rh
        if not rh.get("ok", False):
            raise self._to_exception(rh)
        return rh, rp

    @staticmethod
    def _to_exception(rh: dict) -> Exception:
        name = str(rh.get("error", "ServiceError"))
        msg = str(rh.get("message", "service error"))
        cls = getattr(_errors, name, None)
        if isinstance(cls, type) and issubclass(cls, BaseException):
            return cls(msg)
        builtin = {"ValueError": ValueError, "TypeError": TypeError, "KeyError": KeyError}
        return builtin.get(name, ServiceError)(f"{name}: {msg}" if name not in builtin else msg)

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            finally:
                self._sock = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------ ops
    @staticmethod
    def _spec_header(spec, legacy: dict):
        """The wire ``spec`` value: canonical string from ``spec=``, or the
        legacy kwargs dict (deprecated) — never both."""
        if spec is not None and legacy:
            raise TypeError("pass spec=... or legacy spec kwargs, not both")
        if spec is not None:
            if isinstance(spec, CompressorSpec):
                return spec.to_string()
            CompressorSpec.from_string(spec)  # validate client-side: typed SpecError
            return str(spec)
        if legacy:
            warnings.warn(
                "per-field spec kwargs on CompressdClient are deprecated; pass "
                "spec=\"lossy,<eb_mode>,<eb>,...\" (CompressorSpec.from_string "
                "grammar) instead", DeprecationWarning, stacklevel=3)
            return {k: list(v) if isinstance(v, tuple) else v for k, v in legacy.items()}
        return None

    def compress(self, arr: np.ndarray, *, spec=None, stream: str | None = None,
                 **legacy) -> bytes:
        """Compress ``arr`` on the daemon; returns the container bytes.

        ``spec`` is the canonical compression-spec string (the
        ``CompressorSpec.from_string`` grammar) or a ``CompressorSpec``;
        the response header lands on ``last_info``. Bare CompressorSpec
        kwargs (``eb=...``, ...) still work but are deprecated.
        """
        arr = np.ascontiguousarray(arr)
        header = {"op": "compress", "shape": list(arr.shape), "dtype": str(arr.dtype)}
        wire_spec = self._spec_header(spec, legacy)
        if wire_spec is not None:
            header["spec"] = wire_spec
        if stream or self.stream:
            header["stream"] = stream or self.stream
        _, payload = self.request(header, arr.tobytes())
        return payload

    def decompress(self, buf: bytes, *, spec=None, stream: str | None = None,
                   **legacy) -> np.ndarray:
        header = {"op": "decompress"}
        wire_spec = self._spec_header(spec, legacy)
        if wire_spec is not None:
            header["spec"] = wire_spec
        if stream or self.stream:
            header["stream"] = stream or self.stream
        rh, payload = self.request(header, bytes(buf))
        return np.frombuffer(payload, dtype=np.dtype(str(rh["dtype"]))).reshape(
            tuple(rh["shape"])).copy()

    def stats(self) -> dict:
        rh, _ = self.request({"op": "stats"})
        return rh

    def health(self) -> dict:
        rh, _ = self.request({"op": "health"})
        return rh

    def ping(self) -> bool:
        rh, _ = self.request({"op": "ping"})
        return bool(rh.get("pong"))

    def shutdown(self) -> None:
        self.request({"op": "shutdown"})
        self.close()


def wait_ready(addr: str, timeout: float = 30.0, interval: float = 0.1) -> None:
    """Block until a daemon at ``addr`` answers ping (subprocess startup)."""
    deadline = time.monotonic() + timeout
    last: Exception | None = None
    while time.monotonic() < deadline:
        try:
            with CompressdClient(addr, timeout=min(timeout, 5.0)) as c:
                if c.ping():
                    return
        except (ConnectionError, OSError, ServiceError) as e:
            last = e
        time.sleep(interval)
    raise TimeoutError(f"compressd at {addr} not ready after {timeout}s: {last!r}")


# ---------------------------------------------------------------------- CLI
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="multi-tenant streaming compression daemon")
    ap.add_argument("--addr", default="127.0.0.1:0",
                    help="host:port (port 0 = ephemeral) or unix:/path")
    ap.add_argument("--workers", type=int, default=None,
                    help=f"worker pool width (default REPRO_COMPRESSD_WORKERS or {default_workers()})")
    ap.add_argument("--queue-depth", type=int, default=None,
                    help="max queued admissions before load shed")
    ap.add_argument("--max-request-mb", type=int, default=None,
                    help="per-request payload cap (MiB)")
    ap.add_argument("--max-inflight-mb", type=int, default=None,
                    help="total admitted payload bytes cap (MiB)")
    ap.add_argument("--plan-cache-entries", type=int, default=None,
                    help="LRU plan cache capacity (field signatures)")
    ap.add_argument("--no-remote-shutdown", action="store_true",
                    help="ignore shutdown requests from clients")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline in ms (0 = none; "
                         "default REPRO_COMPRESSD_DEADLINE_MS)")
    ap.add_argument("--idle-s", type=float, default=None,
                    help="reap connections idle this long (0 = never; "
                         "default REPRO_COMPRESSD_IDLE_S or 300)")
    ap.add_argument("--drain-s", type=float, default=None,
                    help="SIGTERM drain budget for in-flight requests "
                         "(default REPRO_COMPRESSD_DRAIN_S or 30)")
    args = ap.parse_args(argv)
    server = CompressdServer(
        args.addr,
        workers=args.workers,
        queue_depth=args.queue_depth,
        max_request_bytes=None if args.max_request_mb is None else args.max_request_mb << 20,
        max_inflight_bytes=None if args.max_inflight_mb is None else args.max_inflight_mb << 20,
        plan_cache_entries=args.plan_cache_entries,
        allow_shutdown=not args.no_remote_shutdown,
        deadline_ms=args.deadline_ms,
        idle_s=args.idle_s,
        drain_s=args.drain_s,
    )

    # SIGTERM (the supervisor's stop signal) drains instead of dying
    # mid-request: the handler fires in the main thread, which is blocked
    # inside serve_forever, so the drain runs on a helper thread and
    # serve_forever returns once the listener closes.
    def _on_sigterm(signum, frame):
        threading.Thread(target=server.drain, name="compressd-drain",
                         daemon=True).start()

    try:
        signal.signal(signal.SIGTERM, _on_sigterm)
    except ValueError:
        pass  # not the main thread (embedded use); drain() is still callable
    print(f"compressd listening on {server.address} "
          f"(workers={server.workers}, queue_depth={server.queue_depth}, "
          f"deadline_ms={server.deadline_ms:g})", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        # second drain() call waits for an in-progress SIGTERM drain, then
        # no-ops; a plain Ctrl-C with nothing in flight closes immediately
        server.drain()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
