"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Gated linear recurrence h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * u_t) with
input-dependent a_t = exp(-c * softplus(Lambda) * r_t). Training/prefill
runs a log-depth jax.lax.associative_scan over the sequence; decode is a
one-step update. Combined with windowed local attention this gives the
bounded-state long_500k path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import _dense_init

_C = 8.0


def init_rglru(key, cfg: ModelConfig):
    d, L = cfg.d_model, cfg.lru_dim
    ks = jax.random.split(key, 6)
    return {
        "w_gelu": _dense_init(ks[0], (d, L)),
        "w_x": _dense_init(ks[1], (d, L)),
        "conv_w": _dense_init(ks[2], (cfg.conv_width, L), scale=0.2),
        "w_r": _dense_init(ks[3], (L, L)),
        "w_i": _dense_init(ks[4], (L, L)),
        "lam": jnp.full((L,), 1.0, jnp.float32),  # softplus(1) ~ 1.31
        "w_out": _dense_init(ks[5], (L, d)),
    }


def _gates(u, p):
    r = jax.nn.sigmoid((u @ p["w_r"].astype(u.dtype)).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["w_i"].astype(u.dtype)).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * u.astype(jnp.float32))
    return a, b


def _conv(x, w):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))


def rglru_block(x, p, cfg: ModelConfig, return_cache: bool = False):
    """Full-sequence Griffin recurrent block. x: (B,S,d)."""
    gate = jax.nn.gelu(x @ p["w_gelu"].astype(x.dtype))
    xin = x @ p["w_x"].astype(x.dtype)
    u = _conv(xin, p["conv_w"])
    a, b = _gates(u, p)

    def combine(l, r):
        return l[0] * r[0], r[0] * l[1] + r[1]

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = (h.astype(x.dtype) * gate) @ p["w_out"].astype(x.dtype)
    if return_cache:
        K = cfg.conv_width
        return out, {"h": h[:, -1], "conv": xin[:, x.shape[1] - (K - 1) :]}
    return out


def rglru_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    L = cfg.lru_dim
    return {
        "h": jnp.zeros((batch, L), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, L), dtype),
    }


def rglru_decode(x1, p, cfg: ModelConfig, cache):
    """One-token update. x1: (B,1,d)."""
    gate = jax.nn.gelu(x1 @ p["w_gelu"].astype(x1.dtype))
    xin = x1 @ p["w_x"].astype(x1.dtype)  # (B,1,L)
    win = jnp.concatenate([cache["conv"], xin], 1)  # (B,K,L)
    u = jnp.einsum("bkl,kl->bl", win, p["conv_w"].astype(x1.dtype))[:, None]
    a, b = _gates(u, p)
    h = a[:, 0] * cache["h"] + b[:, 0]
    y = h[:, None].astype(x1.dtype) * gate
    return y @ p["w_out"].astype(x1.dtype), {"h": h, "conv": win[:, 1:]}
