"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
compute within chunks, a sequential (lax.scan) state recurrence across
chunks. Decode is the O(1)-per-token recurrent update — the reason this
arch runs the long_500k cell that full attention cannot.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.runtime import partitioning as part

from .layers import _dense_init, rms_norm


def init_ssm(key, cfg: ModelConfig):
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * di + 2 * N + H)),
        "conv_w": _dense_init(ks[1], (cfg.ssm_conv, conv_dim), scale=0.2),
        "A_log": jnp.zeros((H,), jnp.float32),            # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.zeros((di,), jnp.float32),
        "out_proj": _dense_init(ks[2], (di, d)),
    }


def _causal_conv(x, w):
    """Depthwise causal conv: x (B,S,C), w (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    return jax.nn.silu(out)


def _split_proj(zxbcdt, cfg: ModelConfig):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xs = zxbcdt[..., di : 2 * di]
    Bc = zxbcdt[..., 2 * di : 2 * di + N]
    Cc = zxbcdt[..., 2 * di + N : 2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N :]
    return z, xs, Bc, Cc, dt


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, return_state: bool = False):
    """xh: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N) -> y (B,S,H,P)."""
    B, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, f"seq {S} not divisible by chunk {L}"
    nc = S // L
    xc = xh.reshape(B, nc, L, H, Pd)
    dtc = dt.reshape(B, nc, L, H).astype(jnp.float32)
    Bcc = Bm.reshape(B, nc, L, N)
    Ccc = Cm.reshape(B, nc, L, N)
    dA = dtc * A  # (B,nc,L,H) log-decay increments (negative)
    cum = jnp.cumsum(dA, axis=2)
    # intra-chunk: scores[l,m] = (C_l . B_m) exp(cum_l - cum_m) dt_m, m <= l
    G = jnp.einsum("bcln,bcmn->bclm", Ccc, Bcc, preferred_element_type=jnp.float32)
    delta = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,L,M,H)
    mask = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])[None, None, :, :, None]
    W = jnp.where(mask, jnp.exp(delta), 0.0) * G[..., None]
    xdt = xc.astype(jnp.float32) * dtc[..., None]
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", W, xdt)
    # chunk-final states: S_c = sum_m exp(cum_L - cum_m) dt_m B_m (x) x_m
    wS = jnp.exp(cum[:, :, -1:, :] - cum) * dtc  # (B,nc,L,H)
    states = jnp.einsum("bcmn,bcmh,bcmhp->bchnp", Bcc, wS, xc.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1])  # (B,nc,H)
    # inter-chunk recurrence, unrolled (nc is small and static; an unrolled
    # chain also keeps compiled-HLO cost analysis exact for the dry-run)
    carry = jnp.zeros((B, H, N, Pd), jnp.float32)
    prev_list = []
    for c in range(nc):
        prev_list.append(carry)
        carry = carry * chunk_decay[:, c][..., None, None] + states[:, c]
    final_state = carry
    prev_states = jnp.stack(prev_list, 1)  # (B,nc,H,N,P) state before chunk
    y_inter = jnp.einsum("bcln,bclh,bchnp->bclhp", Ccc, jnp.exp(cum), prev_states)
    y = (y_intra + y_inter).reshape(B, S, H, Pd)
    return (y, final_state) if return_state else y


def ssm_block(x, p, cfg: ModelConfig, return_cache: bool = False):
    """Full-sequence SSD. x: (B,S,d) -> (B,S,d) [, decode-entry cache]."""
    B, S, d = x.shape
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xs, Bc, Cc, dt = _split_proj(zxbcdt, cfg)
    cin = jnp.concatenate([xs, Bc, Cc], -1)
    conv = _causal_conv(cin, p["conv_w"])
    xs, Bc, Cc = conv[..., :di], conv[..., di : di + N], conv[..., di + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, S, H, Pd)
    xh = part.shard(xh, "batch", "seq", "ssm_heads", None)
    out = ssd_chunked(xh, dt, A, Bc, Cc, cfg.ssm_chunk, return_state=return_cache)
    y, final_state = out if return_cache else (out, None)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    y = y @ p["out_proj"].astype(x.dtype)
    if return_cache:
        K = cfg.ssm_conv
        return y, {"state": final_state, "conv": cin[:, S - (K - 1) :].astype(cin.dtype)}
    return y


def ssm_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    return {
        "state": jnp.zeros((batch, H, N, Pd), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * N), dtype),
    }


def ssm_decode(x1, p, cfg: ModelConfig, cache):
    """One-token recurrent update. x1: (B,1,d)."""
    B = x1.shape[0]
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    zxbcdt = x1 @ p["in_proj"].astype(x1.dtype)
    z, xs, Bc, Cc, dt = _split_proj(zxbcdt, cfg)
    cin = jnp.concatenate([xs, Bc, Cc], -1)  # (B,1,conv_dim)
    win = jnp.concatenate([cache["conv"], cin], 1)  # (B,K,conv_dim)
    conv = jax.nn.silu(jnp.einsum("bkc,kc->bc", win, p["conv_w"].astype(x1.dtype)))[:, None]
    new_conv = win[:, 1:]
    xs, Bc, Cc = conv[..., :di], conv[..., di : di + N], conv[..., di + N :]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)  # (B,H)
    xh = xs[:, 0].reshape(B, H, Pd).astype(jnp.float32)
    st = cache["state"] * a[..., None, None] + jnp.einsum("bn,bh,bhp->bhnp", Bc[:, 0].astype(jnp.float32), dt, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cc[:, 0].astype(jnp.float32), st)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, 1, di).astype(x1.dtype) * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(x1.dtype), {"state": st, "conv": new_conv}
