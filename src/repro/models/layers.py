"""Shared transformer layers: RMSNorm, RoPE, GQA attention (global/local,
train/prefill/decode), dense MLP, MoE FFN (sort-based dispatch, shard_map).

All functions are pure; parameters are plain dict pytrees created by the
matching init_* functions. Compute dtype is bf16, accumulation fp32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.runtime import partitioning as part

CDTYPE = jnp.bfloat16


def _dense_init(key, shape, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(jnp.float32)


def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------- RoPE
def rope(x, positions, theta):
    """x: (..., S, H, dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = (1.0 / theta) ** (jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- attention
def init_attention(key, cfg: ModelConfig):
    d, H, Hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, H * dh)),
        "wk": _dense_init(ks[1], (d, Hk * dh)),
        "wv": _dense_init(ks[2], (d, Hk * dh)),
        "wo": _dense_init(ks[3], (H * dh, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), jnp.float32)
        p["bk"] = jnp.zeros((Hk * dh,), jnp.float32)
        p["bv"] = jnp.zeros((Hk * dh,), jnp.float32)
    return p


def _qkv(x, p, cfg: ModelConfig, positions, use_rope=True):
    B, S, _ = x.shape
    H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, dh)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, Hk, dh)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, Hk, dh)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype).reshape(H, dh)
        k = k + p["bk"].astype(x.dtype).reshape(Hk, dh)
        v = v + p["bv"].astype(x.dtype).reshape(Hk, dh)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = part.shard(q, "batch", "seq", "heads", None)
    # K/V use their own seq rule: under sequence-TP ("seq"->model) they stay
    # replicated along seq so blockwise tiles slice without per-tile reshards
    k = part.shard(k, "batch", "seq_kv", "kv_heads", None)
    v = part.shard(v, "batch", "seq_kv", "kv_heads", None)
    return q, k, v


def _gqa_scores(q, k, cfg):
    """q: (B,S,H,dh), k: (B,T,Hk,dh) -> (B,Hk,G,S,T) fp32, no repeated KV."""
    B, S, H, dh = q.shape
    Hk = k.shape[2]
    G = H // Hk
    qg = q.reshape(B, S, Hk, G, dh)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32) / math.sqrt(dh)


def _gqa_out(probs, v, cfg):
    """probs: (B,Hk,G,S,T); v: (B,T,Hk,dh) -> (B,S,H*dh)."""
    B, Hk, G, S, T = probs.shape
    o = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return o.reshape(B, S, Hk * G * v.shape[-1])


def _attn_full(q, k, v, cfg, kind):
    """Naive full-scores attention (exact reference; attn_chunk=0)."""
    S, T = q.shape[1], k.shape[1]
    scores = _gqa_scores(q, k, cfg)
    qi, ki = jnp.arange(S)[:, None], jnp.arange(T)[None, :]
    if kind == "bidir":
        mask = jnp.ones((S, T), bool)
    elif kind == "local":
        mask = (ki <= qi) & (qi - ki < cfg.window)
    else:
        mask = ki <= qi
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    return _gqa_out(jax.nn.softmax(scores, axis=-1), v, cfg)


def _attn_blockwise(q, k, v, cfg, kind):
    """Flash-style blockwise attention in jnp (exact online softmax).

    Tiles both the query and KV axes with cfg.attn_chunk; statically skips
    fully-masked tiles (so local-attention FLOPs really are O(S*window)).
    Each tile step is rematerialized — backward keeps only running stats.
    Loops are python-unrolled: tiles stay visible to the dry-run cost
    analysis and XLA pipelines them freely.
    """
    B, S, H, dh = q.shape
    T = k.shape[1]
    Hk = k.shape[2]
    G = H // Hk
    C = cfg.attn_chunk
    Cq, Ck = min(C, S), min(C, T)
    if S % Cq or T % Ck:  # fall back on exact full path for ragged shapes
        return _attn_full(q, k, v, cfg, kind)
    qg = q.reshape(B, S, Hk, G, dh)
    scale = 1.0 / math.sqrt(dh)
    outs = []
    for q0 in range(0, S, Cq):
        qc = qg[:, q0 : q0 + Cq]
        m = jnp.full((B, Hk, G, Cq), -1e30, jnp.float32)
        den = jnp.zeros((B, Hk, G, Cq), jnp.float32)
        acc = jnp.zeros((B, Hk, G, Cq, dh), jnp.float32)

        def tile(m, den, acc, kc, vc, q0=q0, k0=0):
            s = jnp.einsum("bskgd,btkd->bkgst", qc, kc, preferred_element_type=jnp.float32) * scale
            qi = q0 + jnp.arange(Cq)[:, None]
            ki = k0 + jnp.arange(kc.shape[1])[None, :]
            if kind == "local":
                msk = (ki <= qi) & (qi - ki < cfg.window)
            elif kind == "attn":
                msk = ki <= qi
            else:
                msk = jnp.ones_like(ki <= qi)
            s = jnp.where(msk[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            den_new = den * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bkgst,btkd->bkgsd", p.astype(vc.dtype), vc)
            return m_new, den_new, acc_new

        for k0 in range(0, T, Ck):
            # static tile skipping: causal/local windows never look ahead,
            # local never looks further back than the window
            if kind in ("attn", "local") and k0 > q0 + Cq - 1:
                continue
            if kind == "local" and k0 + Ck - 1 < q0 - cfg.window + 1:
                continue
            kc, vc = k[:, k0 : k0 + Ck], v[:, k0 : k0 + Ck]
            step = functools.partial(tile, k0=k0)
            m, den, acc = jax.checkpoint(step)(m, den, acc, kc, vc)
        o = acc / jnp.maximum(den[..., None], 1e-30)  # (B,Hk,G,Cq,dh)
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(B, Cq, H * dh))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def _attn_core(q, k, v, cfg, kind):
    if cfg.attn_chunk and max(q.shape[1], k.shape[1]) > cfg.attn_chunk:
        return _attn_blockwise(q, k, v, cfg, kind)
    return _attn_full(q, k, v, cfg, kind)


def attention(x, p, cfg: ModelConfig, *, kind="attn", positions=None, memory=None):
    """Full-sequence attention. kind: attn|local|cross|bidir."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if kind == "cross":
        q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.n_heads, cfg.d_head)
        T = memory.shape[1]
        k = (memory @ p["wk"].astype(x.dtype)).reshape(B, T, cfg.n_kv_heads, cfg.d_head)
        v = (memory @ p["wv"].astype(x.dtype)).reshape(B, T, cfg.n_kv_heads, cfg.d_head)
        return _attn_core(q, k, v, cfg, "bidir") @ p["wo"].astype(x.dtype)
    q, k, v = _qkv(x, p, cfg, positions)
    return _attn_core(q, k, v, cfg, kind) @ p["wo"].astype(x.dtype)


def attention_prefill(x, p, cfg: ModelConfig, *, kind="attn", cache_len=None):
    """Like attention() but also returns the KV cache (capacity cache_len)."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(x, p, cfg, positions)
    out = _attn_core(q, k, v, cfg, kind) @ p["wo"].astype(x.dtype)
    C = cache_len or S
    if kind == "local":
        C = min(C, cfg.window)
    if C >= S:
        pad = [(0, 0), (0, C - S), (0, 0), (0, 0)]
        kc, vc = jnp.pad(k, pad), jnp.pad(v, pad)
    else:  # keep last C entries (ring base 0 when S % C == 0)
        kc, vc = k[:, S - C :], v[:, S - C :]
    return out, {"k": kc, "v": vc}


def _kv_dequant(kq, scale, dtype):
    """int8 (B,C,Hk,dh) + per-(B,C,Hk) scale -> dtype."""
    return (kq.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _kv_quant(k):
    """Error-bounded int8 KV quantization: per-(token, head) scale,
    |err| <= scale/2 = max|k|/254 — the paper's quantizer at fixed rate,
    halving decode HBM traffic (KV is read every step, written once)."""
    scale = jnp.maximum(jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1), 1e-30) / 127.0
    q = jnp.clip(jnp.rint(k.astype(jnp.float32) / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def attention_decode(x1, p, cfg: ModelConfig, cache, pos, *, kind="attn", memory=None):
    """x1: (B,1,d); cache {'k','v'}: (B,C,Hk,dh); pos: scalar index of the new token.

    Global attn: slot = pos (capacity >= seq_len). Local: ring slot = pos % window.
    With cfg.kv_quant the cache leaves are int8 + scales. Returns (out, new_cache).
    """
    B = x1.shape[0]
    H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if kind == "cross":
        q = (x1 @ p["wq"].astype(x1.dtype)).reshape(B, 1, H, dh)
        scores = _gqa_scores(q, cache["k"], cfg)
        probs = jax.nn.softmax(scores, axis=-1)
        return _gqa_out(probs, cache["v"], cfg) @ p["wo"].astype(x1.dtype), cache
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k1, v1 = _qkv(x1, p, cfg, positions)
    C = cache["k"].shape[1]
    slot = pos % C if kind == "local" else pos
    if cfg.kv_quant:
        k1q, k1s = _kv_quant(k1)
        v1q, v1s = _kv_quant(v1)
        kc = jax.lax.dynamic_update_slice(cache["k"], k1q, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v1q, (0, slot, 0, 0))
        ks = jax.lax.dynamic_update_slice(cache["k_scale"], k1s, (0, slot, 0))
        vs = jax.lax.dynamic_update_slice(cache["v_scale"], v1s, (0, slot, 0))
        kc = part.shard(kc, "batch", "kv_seq", "kv_heads", None)
        vc = part.shard(vc, "batch", "kv_seq", "kv_heads", None)
        kd = _kv_dequant(kc, ks, x1.dtype)
        vd = _kv_dequant(vc, vs, x1.dtype)
        new_cache = {"k": kc, "v": vc, "k_scale": ks, "v_scale": vs}
        scores = _gqa_scores(q, kd, cfg)
        idx = jnp.arange(C)
        valid = ((idx <= slot) | (pos >= C)) if kind == "local" else (idx <= pos)
        scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(probs, vd, cfg) @ p["wo"].astype(x1.dtype)
        return out, new_cache
    kc = jax.lax.dynamic_update_slice(cache["k"], k1.astype(cache["k"].dtype), (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v1.astype(cache["v"].dtype), (0, slot, 0, 0))
    kc = part.shard(kc, "batch", "kv_seq", "kv_heads", None)
    vc = part.shard(vc, "batch", "kv_seq", "kv_heads", None)
    scores = _gqa_scores(q, kc, cfg)  # (B,Hk,G,1,C)
    idx = jnp.arange(C)
    if kind == "local":
        valid = (idx <= slot) | (pos >= C)  # ring: all slots valid once warm
    else:
        valid = idx <= pos
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, vc, cfg) @ p["wo"].astype(x1.dtype)
    return out, {"k": kc, "v": vc}


# ---------------------------------------------------------------- MLP
def init_mlp(key, cfg: ModelConfig, d_ff=None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":  # llama-style gated
        return {"w1": _dense_init(ks[0], (d, ff)), "w3": _dense_init(ks[1], (d, ff)), "w2": _dense_init(ks[2], (ff, d))}
    return {"w1": _dense_init(ks[0], (d, ff)), "w2": _dense_init(ks[2], (ff, d))}


def mlp(x, p, cfg: ModelConfig):
    h = x @ p["w1"].astype(x.dtype)
    h = part.shard(h, "batch", "seq", "ffn")
    if "w3" in p:
        h = jax.nn.silu(h) * (x @ p["w3"].astype(x.dtype))
    else:
        h = jax.nn.gelu(h)
    return h @ p["w2"].astype(x.dtype)


# ---------------------------------------------------------------- MoE
def init_moe(key, cfg: ModelConfig):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, E), scale=0.02),
        "w1": _dense_init(ks[1], (E, d, f)),
        "w3": _dense_init(ks[2], (E, d, f)),
        "w2": _dense_init(ks[3], (E, f, d)),
    }
    if cfg.n_shared:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.n_shared * cfg.d_expert)
    return p


def _moe_local(x, p, cfg: ModelConfig, model_axis: str | None):
    """Token-choice top-k with capacity; runs per data shard (or single device).

    x: (B,S,d) local tokens. Two TP layouts over `model_axis`:
      * FFN-sharded (default): every shard dispatches to ALL experts, expert
        FFN dim sharded (w1/w3 cols, w2 rows);
      * expert-parallel (cfg.moe_expert_parallel): each shard owns E/m whole
        experts and builds only its (E/m, C, d) dispatch buffer — 1/m of the
        dominant buffer traffic (§Perf lever).
    Contributions psum over the (T,d) combine either way.
    Returns (y, aux_loss_local).
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)  # (T,K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # capacity: cf-limited at scale, lossless for tiny token counts (decode)
    C = int(min(T * K, max(math.ceil(T * K / E * cfg.capacity_factor), 8)))
    ef = eidx.reshape(-1)  # (T*K,)
    gf = gates.reshape(-1)
    tf_ = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(ef)
    es, gs, ts = ef[order], gf[order], tf_[order]
    # rank within expert segment
    rank = jnp.arange(T * K) - jnp.searchsorted(es, es, side="left")
    E_loc = p["w1"].shape[0]  # E (ffn-sharded) or E/m (expert-parallel)
    if model_axis is not None and E_loc < E:
        e0 = jax.lax.axis_index(model_axis) * E_loc
        mine = (es >= e0) & (es < e0 + E_loc)
        el = jnp.where(mine, es - e0, E_loc)  # sentinel row -> dropped
        buf = jnp.zeros((E_loc, C, d), x.dtype)
        buf = buf.at[el, rank].set(xt[ts], mode="drop")
    else:
        el = es
        buf = jnp.zeros((E_loc, C, d), x.dtype)
        buf = buf.at[es, rank].set(xt[ts], mode="drop")
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(x.dtype))
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["w3"].astype(x.dtype))
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(x.dtype))
    keep = (rank < C)[:, None]
    if model_axis is not None and E_loc < E:
        keep = keep & (el < E_loc)[:, None]
        contrib = out_e[jnp.clip(el, 0, E_loc - 1), rank % C] * gs[:, None].astype(x.dtype) * keep
    else:
        contrib = out_e[es, rank % C] * gs[:, None].astype(x.dtype) * keep
    y = jnp.zeros((T, d), x.dtype).at[ts].add(contrib, mode="drop")
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)  # combine first: psum the (T,d) result,
        # not the (E,C,d) buffer — 40x less traffic at top-8/64 capacity 1.25
    # switch-style load-balance aux loss
    frac = jnp.zeros(E, jnp.float32).at[ef].add(1.0) / (T * K)
    imp = probs.mean(0)
    aux = E * jnp.sum(frac * imp)
    # shared experts (deepseek): dense path
    if "shared" in p:
        y = y + mlp(xt[None], {k: v for k, v in p["shared"].items()}, cfg)[0]
    return y.reshape(B, S, d), aux


def moe_ffn(x, p, cfg: ModelConfig):
    """MoE FFN; under a mesh, dispatch runs inside shard_map (tokens local to
    (pod, data); expert FFN dim sharded over model; combine psum'd)."""
    mesh = part.get_mesh()
    if mesh is None:
        return _moe_local(x, p, cfg, None)
    # inside a Manual('pod') region (compressed cross-pod train step) XLA's
    # SPMD partitioner cannot nest another shard_map (CHECK failure); fall
    # back to GSPMD-auto dispatch
    if part.in_manual_region():
        return _moe_local(x, p, cfg, None)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    model_axis = "model" if "model" in mesh.shape else None
    x_spec = P(dp_axes if x.shape[0] % math.prod(mesh.shape[a] for a in dp_axes) == 0 else None, None, None)
    ep_ok = cfg.moe_expert_parallel and model_axis and cfg.n_experts % mesh.shape[model_axis] == 0
    f_ok = model_axis and cfg.d_expert % mesh.shape[model_axis] == 0
    if ep_ok:  # expert-parallel: whole experts per shard
        w_specs = {
            "router": P(None, None),
            "w1": P(model_axis, None, None),
            "w3": P(model_axis, None, None),
            "w2": P(model_axis, None, None),
        }
        f_ok = True  # psum over model still required for the combine
    else:
        w_specs = {
            "router": P(None, None),
            "w1": P(None, None, model_axis) if f_ok else P(None, None, None),
            "w3": P(None, None, model_axis) if f_ok else P(None, None, None),
            "w2": P(None, model_axis, None) if f_ok else P(None, None, None),
        }
    if "shared" in p:
        sh_ok = model_axis and all(v.shape[-1] % mesh.shape[model_axis] == 0 for k, v in p["shared"].items() if k != "w2")
        w_specs["shared"] = {
            "w1": P(None, model_axis) if sh_ok else P(None, None),
            "w3": P(None, model_axis) if sh_ok else P(None, None),
            "w2": P(model_axis, None) if sh_ok else P(None, None),
        }
        if "w3" not in p["shared"]:
            w_specs["shared"].pop("w3")

    def body(xl, pl_):
        with part.no_annotation():  # local arrays: no nested GSPMD constraints
            y, aux = _moe_local(xl, pl_, cfg, model_axis if f_ok else None)
        aux = jax.lax.pmean(aux, dp_axes + ((model_axis,) if model_axis else ()))
        return y, aux

    y, aux = part.shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, w_specs),
        out_specs=(x_spec, P()),
    )(x, {k: p[k] for k in w_specs})
    return y, aux
