"""Model assembly: one parameterized stack covering all 10 assigned archs.

Layers are tiled from cfg.pattern and scanned in *pattern groups* (HLO size
stays O(|pattern|), compile time flat in depth — 88-layer granite compiles
as one scanned group). Heterogeneous patterns (gemma3 5L+1G, recurrentgemma
RRL...) put the whole repeating unit inside the scan body. deepseek's dense
prefix runs as explicit python layers before the scan.

Entry points:
  init_params(cfg, rng)          -> params pytree
  forward(params, cfg, batch)    -> (logits, aux)      [train/eval]
  loss_fn(params, cfg, batch)    -> scalar
  prefill(params, cfg, batch)    -> (logits, cache)
  decode_step(params, cfg, token, pos, cache) -> (logits, cache)
  init_cache(cfg, batch, seq_len) -> cache pytree       [decode entry state]
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.runtime import partitioning as part

from . import rglru as rg
from . import ssm as ssm_mod
from .layers import (
    CDTYPE,
    _dense_init,
    attention,
    attention_decode,
    attention_prefill,
    init_attention,
    init_mlp,
    init_moe,
    mlp,
    moe_ffn,
    rms_norm,
)

# ------------------------------------------------------------------ helpers
def _scan_groups(f, x, xs_tree, cfg: ModelConfig):
    """lax.scan over stacked pattern groups, or a python-unrolled loop when
    cfg.scan_layers=False (used by the dry-run cost probes: compiled
    cost_analysis cannot see inside while-loop bodies)."""
    if cfg.scan_layers:
        return jax.lax.scan(f, x, xs_tree)
    G = jax.tree.leaves(xs_tree)[0].shape[0]
    ys = []
    for g in range(G):
        sl = jax.tree.map(lambda a: a[g], xs_tree)
        x, y = f(x, sl)
        ys.append(y)
    ys_stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return x, ys_stacked


def _layer_kinds(cfg: ModelConfig):
    """(kind, is_moe) per scanned pattern position."""
    out = []
    for k in cfg.pattern:
        is_moe = cfg.n_experts > 0 and k in ("attn", "local")
        out.append((k, is_moe))
    return tuple(out)


def _remat(f, cfg: ModelConfig):
    if not cfg.remat:
        return f
    policy = None
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    elif cfg.remat_policy == "mixer":
        # save each layer's mixer output (small: B,S,d) — backward skips the
        # attention/SSM recompute AND the qkv weight re-gathers it would need
        policy = jax.checkpoint_policies.save_only_these_names("mixer_out")
    return jax.checkpoint(f, prevent_cse=False, policy=policy)


def _d(x):
    return x.astype(CDTYPE)


# ------------------------------------------------------------------ init
def init_block(key, cfg: ModelConfig, kind: str, *, moe: bool, cross: bool = False, dense_ff: int | None = None):
    ks = jax.random.split(key, 6)
    p: dict = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if kind in ("attn", "local", "bidir"):
        p["attn"] = init_attention(ks[0], cfg)
    elif kind == "ssm":
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg)
    elif kind == "rglru":
        p["rglru"] = rg.init_rglru(ks[0], cfg)
    else:
        raise ValueError(kind)
    if cross:
        p["ln_x"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["cross"] = init_attention(ks[1], cfg)
    if kind != "ssm" and (cfg.d_ff > 0 or moe):
        p["ln2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["moe" if moe else "mlp"] = init_moe(ks[2], cfg) if moe else init_mlp(ks[2], cfg, dense_ff)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    params: dict = {
        "embed": _dense_init(ks[0], (cfg.vocab, cfg.d_model), scale=0.02),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(ks[1], (cfg.d_model, cfg.vocab), scale=0.02)
    cross = cfg.enc_layers > 0
    # dense prefix (deepseek first_dense)
    prefix = []
    pk = jax.random.split(ks[2], max(cfg.first_dense, 1))
    for i in range(cfg.first_dense):
        prefix.append(init_block(pk[i], cfg, "attn", moe=False, cross=cross, dense_ff=cfg.d_ff))
    if prefix:
        params["prefix"] = prefix
    # scanned pattern groups
    kinds = _layer_kinds(cfg)
    G = cfg.n_groups
    stack = []
    for p_i, (kind, moe) in enumerate(kinds):
        keys = jax.random.split(jax.random.fold_in(ks[3], p_i), G)
        stack.append(jax.vmap(lambda k: init_block(k, cfg, kind, moe=moe, cross=cross))(keys))
    params["stack"] = stack
    if cfg.enc_layers:  # whisper encoder
        keys = jax.random.split(ks[4], cfg.enc_layers)
        params["enc_stack"] = [jax.vmap(lambda k: init_block(k, cfg, "bidir", moe=False))(keys)]
        params["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return params


# ------------------------------------------------------------------ blocks
def block_apply(x, p, cfg: ModelConfig, kind: str, moe: bool, memory=None):
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("attn", "local", "bidir"):
        m = attention(h, p["attn"], cfg, kind=kind)
    elif kind == "ssm":
        m = ssm_mod.ssm_block(h, p["ssm"], cfg)
    else:
        m = rg.rglru_block(h, p["rglru"], cfg)
    m = checkpoint_name(m, "mixer_out")
    x = x + m
    if "cross" in p and memory is not None:
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        x = x + attention(h, p["cross"], cfg, kind="cross", memory=memory)
    if "moe" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        f, aux = moe_ffn(h, p["moe"], cfg)
        x = x + f
    elif "mlp" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp(h, p["mlp"], cfg)
    x = part.shard(x, "batch", "act_seq", "embed")
    return x, aux


# ------------------------------------------------------------------ forward
def _embed_inputs(params, cfg: ModelConfig, batch):
    tokens = batch["tokens"]
    x = _d(params["embed"])[tokens] * math.sqrt(cfg.d_model)
    if cfg.stub_frontend == "vit" and "img" in batch:
        x = jnp.concatenate([_d(batch["img"]), x], axis=1)
    return part.shard(x, "batch", "act_seq", "embed")


def _encode(params, cfg: ModelConfig, frames):
    """Whisper encoder over stub frame embeddings (B, enc_seq, d)."""
    x = part.shard(_d(frames), "batch", "act_seq", "embed")

    def grp(x, sl):
        x, _ = block_apply(x, sl, cfg, "bidir", False)
        return x, None

    f = _remat(grp, cfg)
    x, _ = _scan_groups(f, x, params["enc_stack"][0], cfg)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _logits(params, cfg: ModelConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    # bf16 operands, fp32 accumulation: the head gather moves bf16 and the
    # MXU accumulates in fp32 — logits numerics unchanged at half the bytes
    logits = jnp.einsum("bsd,dv->bsv", x.astype(CDTYPE), head.astype(CDTYPE),
                        preferred_element_type=jnp.float32)
    return part.shard(logits, "batch", "act_seq", "vocab")


def forward(params, cfg: ModelConfig, batch):
    """Full-sequence forward. Returns (logits (B,S,V), aux loss scalar)."""
    memory = _encode(params, cfg, batch["frames"]) if cfg.enc_layers else None
    x = _embed_inputs(params, cfg, batch)
    aux_total = jnp.zeros((), jnp.float32)
    for p in params.get("prefix", []):
        x, aux = block_apply(x, p, cfg, "attn", False, memory)
        aux_total += aux
    kinds = _layer_kinds(cfg)

    def group_fn(x, slices):
        aux_g = jnp.zeros((), jnp.float32)
        for p_i, (kind, moe) in enumerate(kinds):
            x, aux = block_apply(x, slices[p_i], cfg, kind, moe, memory)
            aux_g += aux
        return x, aux_g

    f = _remat(group_fn, cfg)
    x, auxs = _scan_groups(f, x, tuple(params["stack"]), cfg)
    return _logits(params, cfg, x), aux_total + auxs.sum()


def loss_fn(params, cfg: ModelConfig, batch):
    logits, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    if cfg.stub_frontend == "vit" and "img" in batch:  # image positions carry no loss
        pad = jnp.full(batch["img"].shape[:2], -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    mask = labels >= 0
    lse = jax.nn.logsumexp(logits, axis=-1)
    # label pick via masked reduce (NOT take_along_axis: a gather over the
    # model-sharded vocab axis would replicate the full logits per device)
    iota_v = jnp.arange(logits.shape[-1], dtype=labels.dtype)
    ll = jnp.sum(jnp.where(labels[..., None] == iota_v, logits, 0.0), axis=-1)
    nll = jnp.where(mask, lse - ll, 0.0)
    loss = nll.sum() / jnp.maximum(mask.sum(), 1)
    return loss + cfg.aux_loss_coef * aux


# ------------------------------------------------------------------ caches
def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=CDTYPE):
    """Decode-entry cache: capacity seq_len KV (window for local), states for
    ssm/rglru, precomputed cross-attn KV for enc-dec."""
    Hk, dh = cfg.n_kv_heads, cfg.d_head

    def kv(C):
        if cfg.kv_quant:
            return {
                "k": jnp.zeros((batch, C, Hk, dh), jnp.int8),
                "v": jnp.zeros((batch, C, Hk, dh), jnp.int8),
                "k_scale": jnp.zeros((batch, C, Hk), jnp.float32),
                "v_scale": jnp.zeros((batch, C, Hk), jnp.float32),
            }
        return {"k": jnp.zeros((batch, C, Hk, dh), dtype), "v": jnp.zeros((batch, C, Hk, dh), dtype)}

    def one(kind):
        if kind == "attn":
            c = kv(seq_len)
        elif kind == "local":
            c = kv(min(cfg.window, seq_len))
        elif kind == "ssm":
            c = ssm_mod.ssm_init_cache(cfg, batch, dtype)
        else:
            c = rg.rglru_init_cache(cfg, batch, dtype)
        if cfg.enc_layers:
            c = dict(c, xk=jnp.zeros((batch, cfg.enc_seq, Hk, dh), dtype), xv=jnp.zeros((batch, cfg.enc_seq, Hk, dh), dtype))
        return c

    G = cfg.n_groups
    stack = []
    for kind, _ in _layer_kinds(cfg):
        c = one(kind)
        stack.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (G,) + a.shape), c))
    cache = {"stack": stack}
    if cfg.first_dense:
        cache["prefix"] = [one("attn") for _ in range(cfg.first_dense)]
    return cache


def _block_decode(x1, p, cfg, kind, cache, pos):
    h = rms_norm(x1, p["ln1"], cfg.norm_eps)
    if kind in ("attn", "local"):
        m, new = attention_decode(h, p["attn"], cfg, cache, pos, kind=kind)
        new_cache = dict(cache, **new)
    elif kind == "ssm":
        m, new = ssm_mod.ssm_decode(h, p["ssm"], cfg, {"state": cache["state"], "conv": cache["conv"]})
        new_cache = dict(cache, **new)
    else:
        m, new = rg.rglru_decode(h, p["rglru"], cfg, {"h": cache["h"], "conv": cache["conv"]})
        new_cache = dict(cache, **new)
    x1 = x1 + m
    if "cross" in p:
        h = rms_norm(x1, p["ln_x"], cfg.norm_eps)
        m, _ = attention_decode(h, p["cross"], cfg, {"k": cache["xk"], "v": cache["xv"]}, pos, kind="cross")
        x1 = x1 + m
    if "moe" in p:
        h = rms_norm(x1, p["ln2"], cfg.norm_eps)
        f, _ = moe_ffn(h, p["moe"], cfg)
        x1 = x1 + f
    elif "mlp" in p:
        h = rms_norm(x1, p["ln2"], cfg.norm_eps)
        x1 = x1 + mlp(h, p["mlp"], cfg)
    return x1, new_cache


def decode_step(params, cfg: ModelConfig, token, pos, cache):
    """token: (B,) int32; pos: scalar int32 (slot of the new token).

    Returns (logits (B,V), new cache)."""
    x = _d(params["embed"])[token][:, None] * math.sqrt(cfg.d_model)  # (B,1,d)
    new_prefix = []
    for p, c in zip(params.get("prefix", []), cache.get("prefix", [])):
        x, nc = _block_decode(x, p, cfg, "attn", c, pos)
        new_prefix.append(nc)
    kinds = _layer_kinds(cfg)

    def group_fn(x, sl):
        pslice, cslice = sl
        new_slices = []
        for p_i, (kind, _) in enumerate(kinds):
            x, nc = _block_decode(x, pslice[p_i], cfg, kind, cslice[p_i], pos)
            new_slices.append(nc)
        return x, tuple(new_slices)

    x, new_stack = _scan_groups(group_fn, x, (tuple(params["stack"]), tuple(cache["stack"])), cfg)
    logits = _logits(params, cfg, x)[:, 0]
    new_cache = {"stack": list(new_stack)}
    if new_prefix:
        new_cache["prefix"] = new_prefix
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch, cache_len: int | None = None):
    """Run the context once, returning (last-token logits, decode cache)."""
    memory = _encode(params, cfg, batch["frames"]) if cfg.enc_layers else None
    x = _embed_inputs(params, cfg, batch)
    S = x.shape[1]
    C = cache_len or S

    def block_prefill(x, p, kind):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if kind in ("attn", "local"):
            m, kvc = attention_prefill(h, p["attn"], cfg, kind=kind, cache_len=C)
        elif kind == "ssm":
            m, kvc = ssm_mod.ssm_block(h, p["ssm"], cfg, return_cache=True)
        else:
            m, kvc = rg.rglru_block(h, p["rglru"], cfg, return_cache=True)
        x = x + m
        if "cross" in p and memory is not None:
            h = rms_norm(x, p["ln_x"], cfg.norm_eps)
            x = x + attention(h, p["cross"], cfg, kind="cross", memory=memory)
            kvc = dict(kvc,
                       xk=(memory @ p["cross"]["wk"].astype(x.dtype)).reshape(x.shape[0], -1, cfg.n_kv_heads, cfg.d_head),
                       xv=(memory @ p["cross"]["wv"].astype(x.dtype)).reshape(x.shape[0], -1, cfg.n_kv_heads, cfg.d_head))
        if "moe" in p:
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            f, _ = moe_ffn(h, p["moe"], cfg)
            x = x + f
        elif "mlp" in p:
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + mlp(h, p["mlp"], cfg)
        return x, kvc

    new_prefix = []
    for p in params.get("prefix", []):
        x, c = block_prefill(x, p, "attn")
        new_prefix.append(c)
    kinds = _layer_kinds(cfg)

    def group_fn(x, pslice):
        cs = []
        for p_i, (kind, _) in enumerate(kinds):
            x, c = block_prefill(x, pslice[p_i], kind)
            cs.append(c)
        return x, tuple(cs)

    f = _remat(group_fn, cfg)
    x, stack_caches = _scan_groups(f, x, tuple(params["stack"]), cfg)
    logits = _logits(params, cfg, x[:, -1:])[:, 0]
    cache = {"stack": list(stack_caches)}
    if new_prefix:
        cache["prefix"] = new_prefix
    return logits, cache
