"""Architecture registry: --arch <id> -> ModelConfig."""
from __future__ import annotations

from .base import ModelConfig, active_param_count, param_count  # noqa: F401
from .codeqwen15_7b import CONFIG as _codeqwen
from .deepseek_moe_16b import CONFIG as _deepseek
from .gemma3_12b import CONFIG as _gemma3
from .granite_34b import CONFIG as _granite
from .internvl2_1b import CONFIG as _internvl2
from .mamba2_370m import CONFIG as _mamba2
from .olmoe_1b_7b import CONFIG as _olmoe
from .recurrentgemma_2b import CONFIG as _rgemma
from .whisper_small import CONFIG as _whisper
from .yi_34b import CONFIG as _yi

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _internvl2,
        _whisper,
        _yi,
        _codeqwen,
        _gemma3,
        _granite,
        _mamba2,
        _rgemma,
        _olmoe,
        _deepseek,
    )
}

# input shapes assigned to every LM arch: (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention (DESIGN.md §Arch-applicability)
LONG_CONTEXT_OK = {"mamba2-370m", "recurrentgemma-2b", "gemma3-12b"}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; long_500k only where applicable."""
    for a in ARCHS:
        for s in SHAPES:
            if s == "long_500k" and a not in LONG_CONTEXT_OK and not include_skipped:
                continue
            yield a, s
