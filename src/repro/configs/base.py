"""Model configuration schema covering all 10 assigned architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0               # 0 -> d_model // n_heads
    # layer pattern, tiled to cover n_layers; kinds: attn | local | ssm | rglru
    pattern: tuple = ("attn",)
    window: int = 0               # sliding window for "local" layers
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_expert: int = 0
    first_dense: int = 0          # deepseek-moe: leading dense layers
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    moe_expert_parallel: bool = False  # shard experts (not d_expert) over 'model'
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # RG-LRU (recurrentgemma)
    lru_width: int = 0            # 0 -> d_model
    conv_width: int = 4
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500
    # modality frontend stubs ("" | vit | audio)
    stub_frontend: str = ""
    n_img_tokens: int = 256       # vlm: precomputed patch-embedding tokens
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"
    # train-time knobs (overridable per run)
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots (save matmul outputs)
    scan_layers: bool = True
    attn_chunk: int = 2048  # blockwise-attention tile (0 = naive full scores)
    # serving: error-bounded int8 KV-cache compression (paper technique
    # applied to the decode memory roofline); 0 = off
    kv_quant: int = 0
    # cast fp32 master weights to bf16 *before* the FSDP all-gather (halves
    # weight-gather bytes; grads still accumulate fp32). §Perf lever.
    bf16_params: bool = False
    # sharding policy pins (-1 = auto by param count). The dry-run's reduced
    # depth variants pin these to the full model's decisions.
    force_fsdp: int = -1
    force_seqpar: int = -1

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(self.n_heads, 1))
        assert (self.n_layers - self.first_dense) % len(self.pattern) == 0 or not self.scan_layers, (
            f"{self.name}: n_layers {self.n_layers} (minus {self.first_dense} dense prefix) not divisible "
            f"by pattern {self.pattern}; set scan_layers=False or fix the pattern"
        )

    @property
    def n_groups(self) -> int:
        return (self.n_layers - self.first_dense) // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def lru_dim(self) -> int:
        return self.lru_width or self.d_model

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        base = dict(
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_head=16,
            d_ff=128,
            vocab=512,
            window=min(self.window, 16) if self.window else 0,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            d_expert=32 if self.d_expert else 0,
            n_shared=min(self.n_shared, 1),
            n_layers=2 * len(self.pattern) + self.first_dense,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            lru_width=32 if self.lru_width or "rglru" in self.pattern else 0,
            enc_layers=2 if self.enc_layers else 0,
            enc_seq=32 if self.enc_layers else 1500,
            n_img_tokens=8 if self.stub_frontend == "vit" else self.n_img_tokens,
        )
        base.update(kw)
        return dataclasses.replace(self, **base)


def param_count(cfg: ModelConfig) -> int:
    """Approximate parameter count (embedding + blocks), for roofline math."""
    d, ff, V = cfg.d_model, cfg.d_ff, cfg.vocab
    H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    layers = [cfg.pattern[i % len(cfg.pattern)] for i in range(cfg.n_layers)]
    attn_p = d * (H * dh) + 2 * d * (Hk * dh) + (H * dh) * d
    mlp_p = 3 * d * ff if cfg.act == "silu" else 2 * d * ff
    if cfg.n_experts:
        mlp_p = d * cfg.n_experts + cfg.n_experts * 3 * d * cfg.d_expert + cfg.n_shared * 3 * d * cfg.d_expert
    total = 0
    for kind in layers:
        if kind in ("attn", "local"):
            total += attn_p + mlp_p + 2 * d
        elif kind == "ssm":
            di, N, Hs = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
            total += d * (2 * di + 2 * N + Hs) + di * d + cfg.ssm_conv * (di + 2 * N) + 3 * Hs + 2 * d
        elif kind == "rglru":
            L = cfg.lru_dim
            total += 2 * d * L + L * d + cfg.conv_width * L + 2 * L * L + L + 2 * d
    if cfg.enc_layers:
        total += cfg.enc_layers * (2 * attn_p + mlp_p + 3 * d)  # enc + cross-attn in dec counted roughly
    total += V * d * (1 if cfg.tie_embeddings else 2) + d
    return int(total)


def active_param_count(cfg: ModelConfig) -> int:
    """Per-token active parameters (MoE: top_k + shared experts only)."""
    if not cfg.n_experts:
        return param_count(cfg)
    full = param_count(cfg)
    layers_moe = sum(1 for i in range(cfg.n_layers) if cfg.pattern[i % len(cfg.pattern)] in ("attn", "local") and i >= cfg.first_dense)
    inactive = layers_moe * (cfg.n_experts - cfg.top_k) * 3 * cfg.d_model * cfg.d_expert
    return int(full - inactive)
