"""DeepSeekMoE-16B [arXiv:2401.06066]: 2 shared + 64 routed top-6, first layer dense."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,           # dense prefix layer FFN
    vocab=102400,
    n_experts=64,
    top_k=6,
    n_shared=2,
    d_expert=1408,
    first_dense=1,
)
