"""Gemma3-12B [hf:google/gemma-3 family]: 5 local : 1 global, 128k context."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_head=256,
    d_ff=15360,
    vocab=262144,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024,
    act="gelu",
    rope_theta=1e6,
    tie_embeddings=True,
)
