"""Whisper-small [arXiv:2212.04356]: 12L enc + 12L dec, conv frontend stubbed.

input_specs supplies precomputed mel-frame embeddings (enc_seq x d_model);
positions use RoPE on the backbone (absolute-positional tables are a
tokenizer/frontend artifact; noted in DESIGN.md).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,          # decoder depth; encoder depth below
    enc_layers=12,
    enc_seq=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    act="gelu",
    qkv_bias=True,
    stub_frontend="audio",
)
