"""InternVL2-1B [arXiv:2404.16821]: InternLM2 LM backbone + InternViT frontend.

The ViT is a stub per assignment: input_specs supplies precomputed patch
embeddings (n_img_tokens x d_model) concatenated ahead of the text tokens.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    rope_theta=1e6,
    stub_frontend="vit",
    n_img_tokens=256,
    tie_embeddings=True,
)
