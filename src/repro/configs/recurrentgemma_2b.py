"""RecurrentGemma-2B [arXiv:2402.19427]: RG-LRU + local attention, ~1:2.

26 layers = 2 x 13-layer pattern (RRL RRL RRL RRL R): 18 recurrent + 8 local
attention — the paper's (R,R,A) tiling with the odd tail folded in.
"""
from .base import ModelConfig

_P = ("rglru", "rglru", "local") * 4 + ("rglru",)

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_head=256,
    d_ff=7680,
    vocab=256000,
    pattern=_P,
    window=2048,
    lru_width=2560,
    act="gelu",
    tie_embeddings=True,
)
