"""Mamba2-370m [arXiv:2405.21060]: attention-free SSD (state-space duality)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    d_head=64,
    d_ff=0,
    vocab=50280,
    pattern=("ssm",),
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    tie_embeddings=True,
)
