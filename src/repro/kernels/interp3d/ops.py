"""Jitted host-facing wrapper for the interp3d Pallas kernel.

This is the ``backend="pallas"`` entry point used by
``repro.core.compressor.Compressor``: interpret mode is auto-selected (the
kernel interprets on CPU/GPU hosts and compiles on TPU), so the same spec
flag works across environments.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .interp3d import LANES, interp3d_compress


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def compress_blocks_pallas(blocks: np.ndarray, twoeb: float, steps, anchor_every: int = 16, interpret: bool | None = None):
    """Drop-in for repro.core.predictor.compress_blocks, routed through Pallas.

    blocks: (nb, B, B, B) f32 -> (codes u8, outlier bool, recon f32), (nb, B, B, B).
    interpret=None auto-selects: compiled on TPU, interpreter elsewhere.
    """
    if interpret is None:
        interpret = _default_interpret()
    nb = blocks.shape[0]
    pad = (-nb) % LANES
    if pad:
        blocks = np.concatenate([blocks, np.zeros((pad,) + blocks.shape[1:], blocks.dtype)], 0)
    bt = jnp.asarray(np.moveaxis(blocks, 0, -1))  # (B,B,B,nb')
    codes, outl, recon = interp3d_compress(bt, jnp.float32(twoeb), steps, anchor_every, interpret)
    mv = lambda a: np.moveaxis(np.asarray(a), -1, 0)[:nb]
    return mv(codes), mv(outl).astype(bool), mv(recon)


def compress_blocks_pallas_plan(blocks: np.ndarray, twoeb: float, plan, interpret: bool | None = None):
    """Plan-driven kernel entry: step tables and anchor stride come from a
    ``repro.core.autotune.PredictorPlan`` (interpret and compiled modes both
    honour the plan — pack_steps stacks whatever hierarchy it describes)."""
    return compress_blocks_pallas(blocks, twoeb, plan.steps(blocks.shape[1]), plan.anchor_stride, interpret)
