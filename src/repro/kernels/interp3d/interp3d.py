"""Pallas TPU kernel: fused interpolation-predict + quantize (paper §5.1).

TPU adaptation of cuSZ-Hi's thread-block-per-17^3-chunk CUDA kernel
(DESIGN.md §3): the data-block axis becomes the vector *lane* axis. Each
grid step stages a (17,17,17,LANES) VMEM tile — LANES independent blocks —
and sweeps the 4-level hierarchy. Every 1-D spline interpolation is a
static (17,17) banded-matrix contraction (MXU work), and level masks /
blend weights are small VMEM-resident constant tensors (Pallas forbids
captured array constants, so they ride in as extra inputs), making the
kernel branch-free.

VMEM budget per grid step (LANES=128, fp32): in 2.5 MiB + recon 2.5 MiB +
codes/outl 2.5+0.6 MiB + step tables ~0.6 MiB + transients < 16 MiB v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.predictor import CENTER, _anchor_mask, quantize_pred
from repro.core.stencils import Step

LANES = 128


@functools.lru_cache(maxsize=None)
def pack_steps(steps: tuple[Step, ...], anchor_every: int):
    """Stack step tables into dense arrays + static dispatch metadata.

    Returns (mats (n_ops,B,B) f32, wts (n_ops,B..) f32, masks (n_steps+1,B..) u8,
    meta) where meta[k] = ((dim, op_idx), ...) for step k; masks[0] = anchors.
    """
    B = steps[0].mask.shape[0]
    ndim = steps[0].mask.ndim
    mats, wts, masks, meta = [], [], [_anchor_mask((B,) * ndim, anchor_every).astype(np.uint8)], []
    for st in steps:
        ops = []
        for d, M, w in zip(st.dims, st.matrices, st.weights):
            ops.append((d, len(mats)))
            mats.append(M.astype(np.float32))
            wts.append(w.astype(np.float32))
        masks.append(st.mask.astype(np.uint8))
        meta.append(tuple(ops))
    return (
        np.stack(mats),
        np.stack(wts),
        np.stack(masks),
        tuple(meta),
    )


def _einsum_axis(M: jnp.ndarray, x: jnp.ndarray, axis: int) -> jnp.ndarray:
    eq = {0: "im,mjkl->ijkl", 1: "jm,imkl->ijkl", 2: "km,ijml->ijkl"}[axis]
    return jnp.einsum(eq, M, x, preferred_element_type=jnp.float32)


def _kernel(blocks_ref, twoeb_ref, mats_ref, wts_ref, masks_ref, codes_ref, outl_ref, recon_ref, *, meta):
    orig = blocks_ref[...]  # (B,B,B,L) f32
    twoeb = twoeb_ref[0]
    inv2eb = 1.0 / twoeb
    am = masks_ref[0][..., None] != 0
    recon = jnp.where(am, orig, 0.0)
    codes = jnp.full(orig.shape, CENTER, jnp.int32)
    outl = jnp.zeros(orig.shape, jnp.bool_)
    for k, ops in enumerate(meta):
        pred = jnp.zeros_like(recon)
        for d, oi in ops:
            pred = pred + wts_ref[oi][..., None] * _einsum_axis(mats_ref[oi], recon, d)
        code, is_out, rec = quantize_pred(orig, pred, twoeb, inv2eb)  # shared quantizer
        m = masks_ref[k + 1][..., None] != 0
        recon = jnp.where(m, rec, recon)
        codes = jnp.where(m, code, codes)
        outl = outl | (m & is_out)
    codes_ref[...] = codes.astype(jnp.uint8)
    outl_ref[...] = outl.astype(jnp.uint8)
    recon_ref[...] = recon


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def interp3d_compress(blocks_t: jnp.ndarray, twoeb: jnp.ndarray, steps: tuple[Step, ...], anchor_every: int = 16, interpret: bool = True):
    """blocks_t: (B,B,B, nb_padded) with nb_padded % LANES == 0.

    Returns (codes u8, outlier u8, recon f32), same layout.
    """
    B = blocks_t.shape[0]
    nb = blocks_t.shape[-1]
    assert nb % LANES == 0, "pad the block axis to a LANES multiple"
    mats, wts, masks, meta = pack_steps(steps, anchor_every)
    grid = (nb // LANES,)
    spec = pl.BlockSpec((B, B, B, LANES), lambda i: (0, 0, 0, i))
    fixed = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    out_shapes = (
        jax.ShapeDtypeStruct(blocks_t.shape, jnp.uint8),
        jax.ShapeDtypeStruct(blocks_t.shape, jnp.uint8),
        jax.ShapeDtypeStruct(blocks_t.shape, jnp.float32),
    )
    return pl.pallas_call(
        functools.partial(_kernel, meta=meta),
        grid=grid,
        in_specs=[spec, fixed((1,)), fixed(mats.shape), fixed(wts.shape), fixed(masks.shape)],
        out_specs=(spec, spec, spec),
        out_shape=out_shapes,
        interpret=interpret,
    )(blocks_t, twoeb.reshape(1), jnp.asarray(mats), jnp.asarray(wts), jnp.asarray(masks))
