from .ops import compress_blocks_pallas  # noqa: F401
from .ref import compress_blocks_ref  # noqa: F401
