from .ops import compress_blocks_pallas, compress_blocks_pallas_plan  # noqa: F401
from .ref import compress_blocks_ref  # noqa: F401
