"""Pure-jnp oracle for the interp3d kernel: the core predictor itself."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.predictor import compress_blocks


def compress_blocks_ref(blocks: np.ndarray, twoeb: float, steps, anchor_every: int = 16):
    codes, outl, recon = compress_blocks(jnp.asarray(blocks), jnp.float32(twoeb), steps, anchor_every)
    return np.asarray(codes), np.asarray(outl), np.asarray(recon)
