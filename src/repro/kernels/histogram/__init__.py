from .ops import histogram256_pallas  # noqa: F401
from .ref import histogram256_ref  # noqa: F401
