"""Pure-numpy oracle for histogram256."""
from __future__ import annotations

import numpy as np


def histogram256_ref(data: np.ndarray) -> np.ndarray:
    return np.bincount(np.ascontiguousarray(data, np.uint8).reshape(-1), minlength=256).astype(np.int32)
