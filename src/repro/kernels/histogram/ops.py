"""Wrapper: uint8 stream -> 256-bin histogram via the Pallas kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .histogram import TILE, histogram256_raw


def histogram256_pallas(data: np.ndarray, interpret: bool = True) -> np.ndarray:
    data = np.ascontiguousarray(data, np.uint8).reshape(-1)
    n = data.size
    pad = (-n) % TILE
    if pad:
        data = np.concatenate([data, np.zeros(pad, np.uint8)])
    hist = np.array(histogram256_raw(jnp.asarray(data), interpret))
    if pad:
        hist[0] -= pad  # padding contributed zeros
    return hist
