"""Pallas TPU kernel: 256-bin histogram of uint8 symbols (HF stage input).

One-hot contraction per tile, accumulated across grid steps — the TPU
equivalent of cuSZ's shared-memory privatized histogram: lanes compare
against a broadcast iota, a reduction over the tile axis yields per-bin
counts, and the sequential grid accumulates into the output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 8192  # symbols per grid step; one-hot tile = 8192x256 i32 < 8 MiB VMEM


def _kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)  # (TILE,)
    onehot = (x[:, None] == jnp.arange(256, dtype=jnp.int32)[None, :]).astype(jnp.int32)
    o_ref[...] += onehot.sum(axis=0)


@functools.partial(jax.jit, static_argnums=(1,))
def histogram256_raw(x: jnp.ndarray, interpret: bool = True):
    """x: (n,) u8 with n % TILE == 0 -> (256,) i32 counts."""
    n = x.shape[0]
    return pl.pallas_call(
        _kernel,
        grid=(n // TILE,),
        in_specs=[pl.BlockSpec((TILE,), lambda i: (i,))],
        out_specs=pl.BlockSpec((256,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((256,), jnp.int32),
        interpret=interpret,
    )(x)
