"""Pure-numpy oracle for the bitshuffle kernel (same 1024-byte block size)."""
from __future__ import annotations

import numpy as np

from .bitshuffle import BLOCK, TILE_BLOCKS


def bitshuffle_ref(data: np.ndarray) -> np.ndarray:
    data = np.ascontiguousarray(data, np.uint8)
    n = data.size
    pad = (-n) % (BLOCK * TILE_BLOCKS)
    if pad:
        data = np.concatenate([data, np.zeros(pad, np.uint8)])
    arr = data.reshape(-1, BLOCK)
    bits = np.unpackbits(arr, axis=1).reshape(-1, BLOCK, 8)
    return np.packbits(bits.transpose(0, 2, 1).reshape(arr.shape[0], -1), axis=1).reshape(-1)
