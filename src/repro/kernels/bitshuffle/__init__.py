from .ops import bitshuffle_pallas  # noqa: F401
from .ref import bitshuffle_ref  # noqa: F401
