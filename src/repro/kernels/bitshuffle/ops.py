"""Wrapper: arbitrary byte stream -> bit-plane shuffled stream (Pallas)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .bitshuffle import BLOCK, TILE_BLOCKS, bitshuffle_pallas_raw


def bitshuffle_pallas(data: np.ndarray, interpret: bool = True) -> np.ndarray:
    data = np.ascontiguousarray(data, np.uint8)
    n = data.size
    pad = (-n) % (BLOCK * TILE_BLOCKS)
    if pad:
        data = np.concatenate([data, np.zeros(pad, np.uint8)])
    arr = jnp.asarray(data.reshape(-1, BLOCK))
    out = np.asarray(bitshuffle_pallas_raw(arr, interpret)).reshape(-1)
    return out  # caller keeps n for unpadding on decode
