"""Pallas TPU kernel: BIT1 bit-plane shuffle (paper §5.2.3).

Per 1024-byte block, output plane p holds bit p of every byte. Bits are
extracted with shifts/masks on int32 lanes and re-packed with a (8,)
weight contraction — no byte-addressed scatter, so it maps onto the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024     # bytes per shuffle block
TILE_BLOCKS = 8  # blocks per grid step


def _kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.int32)  # (T, block)
    T, BLOCK = x.shape
    # bit p of each byte, MSB first: (T, 8, BLOCK)
    planes = jnp.stack([(x >> (7 - p)) & 1 for p in range(8)], axis=1)
    # pack each plane's BLOCK bits into BLOCK/8 bytes; weights 2^(7-b) built
    # from iota (Pallas kernels cannot capture array constants)
    w = jnp.left_shift(jnp.int32(1), 7 - jax.lax.iota(jnp.int32, 8))
    g = planes.reshape(T, 8, BLOCK // 8, 8)
    packed = jnp.einsum("tpgb,b->tpg", g, w, preferred_element_type=jnp.int32)
    o_ref[...] = packed.reshape(T, BLOCK).astype(jnp.uint8)


def _inv_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.int32)  # (T, block) plane-major payload
    T, BLOCK = x.shape
    # payload byte (plane p, group q) holds bit p of bytes 8q..8q+7; unpack
    # MSB first with iota-built shifts (Pallas kernels cannot capture
    # array constants), giving bits[t, p, i] = bit p of original byte i
    sh = 7 - jax.lax.iota(jnp.int32, 8)
    g = x.reshape(T, 8, BLOCK // 8)
    bits = ((g[:, :, :, None] >> sh) & 1).reshape(T, 8, BLOCK)
    # re-pack across planes: byte i = sum_p bits[p, i] << (7-p)
    w = jnp.left_shift(jnp.int32(1), 7 - jax.lax.iota(jnp.int32, 8))
    out = jnp.einsum("tpq,p->tq", bits, w, preferred_element_type=jnp.int32)
    o_ref[...] = out.astype(jnp.uint8)


def _pallas_apply(kernel, x, interpret: bool, tile_blocks: int):
    n, block = x.shape
    spec = pl.BlockSpec((tile_blocks, block), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(n // tile_blocks,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.uint8),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnums=(1, 2))
def bitshuffle_pallas_raw(x: jnp.ndarray, interpret: bool = True,
                          tile_blocks: int = TILE_BLOCKS):
    """x: (nblocks, block) u8 with nblocks % tile_blocks == 0.

    The block size is taken from ``x.shape[1]``; the kernel body is shape-
    generic, so the device encoding engine reuses it for the host encoder's
    8192-byte-block layout (``tile_blocks=1``) while the default 1024-byte
    call sites keep their 8-block tiles.
    """
    return _pallas_apply(_kernel, x, interpret, tile_blocks)


@functools.partial(jax.jit, static_argnums=(1, 2))
def bitunshuffle_pallas_raw(x: jnp.ndarray, interpret: bool = True,
                            tile_blocks: int = TILE_BLOCKS):
    """Inverse of :func:`bitshuffle_pallas_raw` (same tiling contract)."""
    return _pallas_apply(_inv_kernel, x, interpret, tile_blocks)
