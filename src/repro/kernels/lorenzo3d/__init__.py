from .ops import lorenzo_encode_pallas  # noqa: F401
from .ref import lorenzo_encode_ref  # noqa: F401
