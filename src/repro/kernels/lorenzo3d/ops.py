"""Jitted wrapper: float field -> Lorenzo uint8 codes via the Pallas kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .lorenzo3d import TILE, lorenzo3d_codes


def lorenzo_encode_pallas(x: np.ndarray, twoeb: float, interpret: bool = True):
    """x: (X,Y,Z) f32. Returns (codes u8, outl bool, cfull i32) on the unpadded shape."""
    pq = np.asarray(jnp.rint(jnp.asarray(x) / jnp.float32(twoeb)).astype(jnp.int32))
    pads = [(0, (-s) % t) for s, t in zip(x.shape, TILE)]
    pqp = np.pad(pq, pads)
    codes, outl, cfull = lorenzo3d_codes(jnp.asarray(pqp), interpret)
    sl = tuple(slice(0, s) for s in x.shape)
    return np.asarray(codes)[sl], np.asarray(outl)[sl].astype(bool), np.asarray(cfull)[sl]
