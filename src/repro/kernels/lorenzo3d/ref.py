"""Pure-jnp oracle: the core Lorenzo encoder."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.lorenzo import lorenzo_encode


def lorenzo_encode_ref(x: np.ndarray, twoeb: float):
    codes, outl, cfull, _ = lorenzo_encode(jnp.asarray(x), jnp.float32(twoeb), 3)
    return np.asarray(codes), np.asarray(outl), np.asarray(cfull)
