"""Pallas TPU kernel: 3-D Lorenzo delta + quantize (cuSZ-L decomposition).

Dual-quant formulation: the host pre-quantizes to integers; this kernel
computes the exact integer Lorenzo difference from 8 shifted views and
narrows to uint8 codes + outlier flags. Shifted views (rather than halo
exchange) keep every BlockSpec a plain disjoint tile — the idiomatic way
to express a 1-cell stencil to the Mosaic compiler.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

RADIUS = 127
CENTER = 128
TILE = (8, 8, 128)


def _kernel(p, px, py, pz, pxy, pxz, pyz, pxyz, codes_ref, outl_ref, cfull_ref):
    c = p[...] - px[...] - py[...] - pz[...] + pxy[...] + pxz[...] + pyz[...] - pxyz[...]
    out = jnp.abs(c) > RADIUS
    codes_ref[...] = jnp.where(out, 0, jnp.clip(c, -RADIUS, RADIUS) + CENTER).astype(jnp.uint8)
    outl_ref[...] = out.astype(jnp.uint8)
    cfull_ref[...] = c


@functools.partial(jax.jit, static_argnums=(1,))
def lorenzo3d_codes(pq: jnp.ndarray, interpret: bool = True):
    """pq: (X,Y,Z) int32 pre-quantized values, dims multiples of TILE.

    Returns (codes u8, outlier u8, full int32 codes)."""
    X, Y, Z = pq.shape
    assert X % TILE[0] == 0 and Y % TILE[1] == 0 and Z % TILE[2] == 0, "pad to tile multiples"

    def shift(ax_mask):
        s = pq
        for ax, m in enumerate(ax_mask):
            if m:
                pad = [(0, 0)] * 3
                pad[ax] = (1, 0)
                s = jnp.pad(s, pad)[tuple(slice(0, -1) if a == ax else slice(None) for a in range(3))]
        return s

    views = [shift(m) for m in
             [(0, 0, 0), (1, 0, 0), (0, 1, 0), (0, 0, 1), (1, 1, 0), (1, 0, 1), (0, 1, 1), (1, 1, 1)]]
    grid = (X // TILE[0], Y // TILE[1], Z // TILE[2])
    spec = pl.BlockSpec(TILE, lambda i, j, k: (i, j, k))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec] * 8,
        out_specs=(spec, spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct(pq.shape, jnp.uint8),
            jax.ShapeDtypeStruct(pq.shape, jnp.uint8),
            jax.ShapeDtypeStruct(pq.shape, jnp.int32),
        ),
        interpret=interpret,
    )(*views)
