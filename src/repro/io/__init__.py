"""repro.io — dataset-level compression facade (enstools-style).

    import repro.io as rio
    ds = rio.Dataset.from_arrays({"t2m": t2m, "u10": u10})
    rio.write(ds, "weather.cszh3", compression="lossy,abs,1e-3,predictor=auto")
    back = rio.read("weather.cszh3")
    one = rio.read_variable("weather.cszh3", "t2m", chunks=(0, 1))

The compression argument is the canonical spec string
(``CompressorSpec.from_string`` grammar) or ``"lossless"``; chunked
multi-variable files ride container v3 frames with per-chunk random
access. See :mod:`repro.io.rw` for the layout.
"""
from .dataset import Dataset, Variable, open_dataset  # noqa: F401
from .rw import manifest, parse_compression, read, read_variable, write  # noqa: F401
