"""Dataset write/read over container v3 frame streams.

On-disk layout: one v3 stream (``CSZH3`` magic, see
:mod:`repro.core.frames`) whose global header carries ``kind="dataset"``,
the dataset attrs, and a per-variable manifest — name, dims, shape,
dtype, chunk grid, compression-spec string, and the index of its first
frame. Each chunk is one frame:

* lossy chunks are complete v1/v2 compressor containers, so every chunk
  decodes independently through :meth:`repro.core.Compressor.decompress`
  — plan caching, engine selection, and the fallback ladder all apply;
* lossless chunks are zlib-deflated raw bytes behind a small serial
  header (``RAWC`` tag), byte-identical on read for *any* dtype.

Random access rides :func:`repro.core.frames.frame_table` /
``read_frame``: reading one chunk of one variable touches exactly that
frame's bytes (plus the 12-byte-per-frame table walk), never the rest of
the file.

The compression argument everywhere is the canonical spec string —
``"lossy,<eb_mode>,<eb>[,key=value...]"`` parsed by
:meth:`repro.core.CompressorSpec.from_string`, or ``"lossless"`` — or an
already-built :class:`~repro.core.CompressorSpec`. A dict maps variable
names to per-variable specs (``None``/missing names use the default).
"""
from __future__ import annotations

import os
import zlib

import numpy as np

from ..core import frames as frames_mod
from ..core.compressor import Compressor, CompressorSpec
from ..core.errors import SpecError
from ..core.serial import pack_obj, unpack_obj
from .dataset import Dataset, Variable, _default_dims

_RAW_TAG = b"RAWC"
FORMAT_VERSION = 1


# ----------------------------------------------------------------- specs
def parse_compression(spec) -> CompressorSpec | None:
    """Normalize a compression argument: spec string or CompressorSpec in,
    ``CompressorSpec`` out — ``None`` meaning lossless (raw chunk frames).
    Typed :class:`~repro.core.errors.SpecError` on bad grammar."""
    if spec is None:
        return None
    if isinstance(spec, CompressorSpec):
        return spec
    if isinstance(spec, str):
        if spec.strip().lower() == "lossless":
            return None
        return CompressorSpec.from_string(spec)
    raise SpecError(f"compression must be a spec string or CompressorSpec, got {type(spec).__name__}")


def _spec_string(spec: CompressorSpec | None) -> str:
    return "lossless" if spec is None else spec.to_string()


# -------------------------------------------------------------- chunking
def _chunk_grid(shape: tuple[int, ...], chunks) -> tuple[int, ...]:
    """Resolve a chunk-shape request against a variable shape. ``None``
    means one chunk for the whole variable; an int applies to every axis;
    a tuple gives per-axis chunk lengths (clamped to the shape)."""
    if not shape:
        return ()
    if chunks is None:
        return tuple(shape)
    if isinstance(chunks, (int, np.integer)):
        chunks = (int(chunks),) * len(shape)
    chunks = tuple(int(c) for c in chunks)
    if len(chunks) != len(shape):
        raise ValueError(f"chunks {chunks} does not match rank of shape {shape}")
    if any(c <= 0 for c in chunks):
        raise ValueError(f"chunk lengths must be positive, got {chunks}")
    return tuple(min(c, s) for c, s in zip(chunks, shape))


def _grid_counts(shape, chunk_shape):
    # a zero-length axis has zero chunks (the variable writes no frames)
    return tuple(-(-s // c) if c else 0 for s, c in zip(shape, chunk_shape))


def _chunk_slices(shape, chunk_shape):
    """Yield (grid_index, slice_tuple) over the chunk grid, C order."""
    counts = _grid_counts(shape, chunk_shape)
    for flat in range(int(np.prod(counts, dtype=np.int64)) if counts else 1):
        idx, rem = [], flat
        for n in reversed(counts):
            idx.append(rem % n)
            rem //= n
        idx = tuple(reversed(idx))
        yield idx, tuple(
            slice(i * c, min((i + 1) * c, s)) for i, c, s in zip(idx, chunk_shape, shape))


# ---------------------------------------------------------- chunk codecs
def _encode_chunk(arr: np.ndarray, spec: CompressorSpec | None, comp: Compressor | None) -> bytes:
    if spec is None:
        hdr = pack_obj({"dtype": str(arr.dtype), "shape": list(arr.shape)})
        raw = zlib.compress(np.ascontiguousarray(arr).tobytes(), 6)
        return _RAW_TAG + len(hdr).to_bytes(4, "little") + hdr + raw
    return comp.compress(arr)


_DECOMPRESSOR = None


def _decompressor() -> Compressor:
    """Shared decode-side Compressor: containers are self-describing, so
    the spec only picks engine defaults; per-call state is thread-local."""
    global _DECOMPRESSOR
    if _DECOMPRESSOR is None:
        _DECOMPRESSOR = Compressor(CompressorSpec())
    return _DECOMPRESSOR


def _decode_chunk(payload) -> np.ndarray:
    payload = bytes(payload)
    if payload[:4] == _RAW_TAG:
        hlen = int.from_bytes(payload[4:8], "little")
        hdr = unpack_obj(payload[8 : 8 + hlen])
        raw = zlib.decompress(payload[8 + hlen :])
        return np.frombuffer(raw, dtype=np.dtype(hdr["dtype"])).reshape(hdr["shape"])
    return _decompressor().decompress(payload)


# ----------------------------------------------------------------- write
def write(dataset, path, *, compression="lossy,abs,1e-3,predictor=auto",
          chunks=None, sync: bool = False) -> dict:
    """Write a dataset to ``path`` as one chunked v3 container.

    ``dataset`` is a :class:`~repro.io.Dataset` or a plain
    name -> ndarray mapping. ``compression`` is a spec string /
    :class:`~repro.core.CompressorSpec` / ``"lossless"``, or a dict of
    per-variable overrides over those. ``chunks`` is a chunk shape
    (``None`` = whole variable, int, or per-axis tuple) or a per-variable
    dict of the same. Returns the manifest (the global header that was
    written), with ``bytes_written`` added.
    """
    if not isinstance(dataset, Dataset):
        dataset = Dataset.from_arrays(dict(dataset))
    if not isinstance(compression, dict):
        compression = {None: compression}
    if not isinstance(chunks, dict):
        chunks = {None: chunks}
    default_spec = parse_compression(compression.get(None, "lossless"))

    manifest = []
    plans = []  # (variable, spec, chunk_shape) in manifest order
    frame_start = 0
    for name, var in dataset.items():
        spec = (parse_compression(compression[name]) if name in compression
                else default_spec)
        req = chunks.get(name, chunks.get(None))
        if (name not in chunks and isinstance(req, (tuple, list))
                and len(req) != var.data.ndim):
            req = None  # dataset-wide chunk shape only applies where ranks match
        cshape = _chunk_grid(var.shape, req)
        counts = _grid_counts(var.shape, cshape) if cshape else ()
        n_chunks = int(np.prod(counts, dtype=np.int64)) if counts else 1
        manifest.append({
            "name": name, "dims": list(var.dims), "shape": list(var.shape),
            "dtype": str(var.dtype), "chunk_shape": list(cshape),
            "chunk_counts": list(counts), "n_chunks": n_chunks,
            "frame_start": frame_start, "spec": _spec_string(spec),
            "attrs": dict(var.attrs),
        })
        plans.append((var, spec, cshape))
        frame_start += n_chunks
    header = {
        "kind": "dataset", "version": FORMAT_VERSION,
        "attrs": dict(dataset.attrs), "variables": manifest,
    }

    with open(path, "wb") as f:
        with frames_mod.FrameWriter(f, header, sync=sync) as w:
            for (var, spec, cshape), meta in zip(plans, manifest):
                comp = Compressor(spec) if spec is not None else None
                if not cshape:  # scalar variable: one frame
                    w.write_frame(_encode_chunk(var.data.reshape(()), spec, comp))
                    continue
                for _, sl in _chunk_slices(var.shape, cshape):
                    w.write_frame(_encode_chunk(
                        np.ascontiguousarray(var.data[sl]), spec, comp))
    out = dict(header)
    out["bytes_written"] = os.path.getsize(path)
    return out


# ------------------------------------------------------------------ read
def _load(path_or_buf):
    if isinstance(path_or_buf, (bytes, bytearray, memoryview)):
        return memoryview(path_or_buf)
    with open(path_or_buf, "rb") as f:
        return memoryview(f.read())


def _manifest(header: dict) -> dict:
    if header.get("kind") != "dataset":
        raise ValueError(
            f"not a repro.io dataset container (kind={header.get('kind')!r}); "
            f"plain compressor containers decode via repro.core.Compressor")
    return {v["name"]: v for v in header["variables"]}


def manifest(path) -> dict:
    """The dataset's global header (attrs + per-variable manifest) without
    touching any chunk payload."""
    buf = _load(path)
    header, _ = frames_mod.frame_table(buf)
    _manifest(header)  # validates kind
    return header


def _assemble(meta: dict, payloads) -> np.ndarray:
    shape = tuple(meta["shape"])
    cshape = tuple(meta["chunk_shape"])
    if not shape or not cshape:
        return _decode_chunk(next(iter(payloads))).reshape(shape)
    out = np.empty(shape, np.dtype(meta["dtype"]))
    for (_, sl), payload in zip(_chunk_slices(shape, cshape), payloads):
        chunk = _decode_chunk(payload)
        out[sl] = chunk.reshape(tuple(s.stop - s.start for s in sl)).astype(out.dtype, copy=False)
    return out


def read_variable(path, name: str, *, chunks=None) -> np.ndarray:
    """Read one variable — or one chunk of it — by random access.

    ``chunks=None`` assembles the full variable. ``chunks=i`` (flat
    index) or ``chunks=(i, j, ...)`` (grid coordinates) reads exactly
    that chunk's frame and returns its array; no other frame's payload is
    read or CRC-checked.
    """
    buf = _load(path)
    header, table = frames_mod.frame_table(buf)
    meta = _manifest(header).get(name)
    if meta is None:
        raise KeyError(f"no variable {name!r}; have {list(_manifest(header))}")
    start, n = meta["frame_start"], meta["n_chunks"]
    if chunks is None:
        payloads = (frames_mod.read_frame(buf, table[start + i]) for i in range(n))
        return _assemble(meta, payloads)
    counts = tuple(meta["chunk_counts"])
    if isinstance(chunks, (int, np.integer)):
        flat = int(chunks)
    else:
        idx = tuple(int(i) for i in chunks)
        if len(idx) != len(counts) or any(not 0 <= i < c for i, c in zip(idx, counts)):
            raise IndexError(f"chunk index {idx} outside grid {counts}")
        flat = 0
        for i, c in zip(idx, counts):
            flat = flat * c + i
    if not 0 <= flat < n:
        raise IndexError(f"chunk {flat} outside [0, {n}) for variable {name!r}")
    chunk = _decode_chunk(frames_mod.read_frame(buf, table[start + flat]))
    return chunk.astype(np.dtype(meta["dtype"]), copy=False)


def read(path) -> Dataset:
    """Read the whole dataset back: every variable assembled from its
    chunk frames, dims and attrs restored from the manifest."""
    buf = _load(path)
    header, table = frames_mod.frame_table(buf)
    _manifest(header)  # validates kind
    ds = Dataset(attrs=dict(header.get("attrs") or {}))
    for meta in header["variables"]:
        start, n = meta["frame_start"], meta["n_chunks"]
        payloads = (frames_mod.read_frame(buf, table[start + i]) for i in range(n))
        data = _assemble(meta, payloads)
        dims = tuple(meta["dims"]) or _default_dims(meta["name"], data.ndim)
        ds[meta["name"]] = Variable(data, dims, dict(meta.get("attrs") or {}))
    return ds
