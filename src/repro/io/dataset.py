"""Dataset model for :mod:`repro.io` — named variables over named dims.

The shape is deliberately the small common denominator of the
netCDF/xarray/zarr family: a :class:`Dataset` is an ordered mapping of
name -> :class:`Variable`, a variable is an array + dimension names +
attributes, and the dataset carries its own attribute dict. That is
enough to round-trip the archival/ensemble workloads the facade targets
without dragging in a dependency; the adapters below convert to/from the
on-disk shapes we can actually open in this environment (npz always,
HDF5 when ``h5py`` is importable, zarr's directory layout read-only).
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np


def _default_dims(name: str, ndim: int) -> tuple[str, ...]:
    return tuple(f"{name}_d{i}" for i in range(ndim))


@dataclasses.dataclass
class Variable:
    """One named array: data + dimension names + attributes."""

    data: np.ndarray
    dims: tuple[str, ...] = ()
    attrs: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.data = np.asarray(self.data)
        if not self.dims:
            self.dims = _default_dims("dim", self.data.ndim)
        self.dims = tuple(str(d) for d in self.dims)
        if len(self.dims) != self.data.ndim:
            raise ValueError(
                f"{len(self.dims)} dims for a {self.data.ndim}-d array: {self.dims}")

    @property
    def shape(self) -> tuple[int, ...]:
        return self.data.shape

    @property
    def dtype(self) -> np.dtype:
        return self.data.dtype


class Dataset:
    """An ordered mapping of variable name -> :class:`Variable` + attrs.

    Construct directly from arrays (dims auto-named), from Variables, or
    through the adapters (:meth:`from_npz`, :meth:`from_hdf5`,
    :meth:`from_zarr`). Mapping-style access: ``ds["t2m"]`` is the
    Variable, ``ds.arrays()`` the plain name -> ndarray view.
    """

    def __init__(self, variables: dict | None = None, attrs: dict | None = None):
        self.variables: dict[str, Variable] = {}
        self.attrs: dict = dict(attrs or {})
        for name, v in (variables or {}).items():
            self[name] = v

    # ------------------------------------------------------------- mapping
    def __setitem__(self, name: str, v) -> None:
        if not isinstance(v, Variable):
            arr = np.asarray(v)
            v = Variable(arr, _default_dims(name, arr.ndim))
        self.variables[str(name)] = v

    def __getitem__(self, name: str) -> Variable:
        return self.variables[name]

    def __contains__(self, name: str) -> bool:
        return name in self.variables

    def __iter__(self):
        return iter(self.variables)

    def __len__(self) -> int:
        return len(self.variables)

    def keys(self):
        return self.variables.keys()

    def items(self):
        return self.variables.items()

    def arrays(self) -> dict[str, np.ndarray]:
        return {k: v.data for k, v in self.variables.items()}

    def __repr__(self) -> str:
        vs = ", ".join(
            f"{k}{list(v.shape)}:{v.dtype}" for k, v in self.variables.items())
        return f"Dataset({vs})"

    # ------------------------------------------------------------ adapters
    @classmethod
    def from_arrays(cls, arrays: dict, attrs: dict | None = None) -> "Dataset":
        return cls(dict(arrays), attrs)

    @classmethod
    def from_npz(cls, path) -> "Dataset":
        """An ``np.savez`` archive as a Dataset (dims auto-named)."""
        with np.load(path) as z:
            return cls({k: np.asarray(z[k]) for k in z.files})

    def to_npz(self, path) -> None:
        np.savez(path, **self.arrays())

    @classmethod
    def from_hdf5(cls, path) -> "Dataset":
        """Every dataset in an HDF5 file (recursively), with HDF5 attrs
        and dimension labels carried over. Needs ``h5py``."""
        h5py = _require("h5py")
        ds = cls()
        with h5py.File(path, "r") as f:
            ds.attrs = {k: _plain(v) for k, v in f.attrs.items()}

            def visit(name, obj):
                if isinstance(obj, h5py.Dataset):
                    dims = tuple(
                        d.label or f"{name}_d{i}" for i, d in enumerate(obj.dims)
                    ) if obj.ndim else ()
                    ds[name] = Variable(obj[()], dims or _default_dims(name, obj.ndim),
                                        {k: _plain(v) for k, v in obj.attrs.items()})

            f.visititems(visit)
        return ds

    def to_hdf5(self, path) -> None:
        h5py = _require("h5py")
        with h5py.File(path, "w") as f:
            for k, v in self.attrs.items():
                f.attrs[k] = v
            for name, var in self.variables.items():
                d = f.create_dataset(name, data=var.data)
                for i, dim in enumerate(var.dims):
                    d.dims[i].label = dim
                for k, v in var.attrs.items():
                    d.attrs[k] = v

    @classmethod
    def from_zarr(cls, path) -> "Dataset":
        """A zarr group as a Dataset. Uses the ``zarr`` package when
        importable; raises a clear error otherwise (the environment this
        repo targets does not ship it)."""
        zarr = _require("zarr")
        g = zarr.open_group(str(path), mode="r")
        ds = cls(attrs=dict(g.attrs))
        for name, arr in g.arrays():
            dims = tuple(arr.attrs.get("_ARRAY_DIMENSIONS", ())) or None
            ds[name] = Variable(np.asarray(arr), dims or _default_dims(name, arr.ndim),
                                {k: v for k, v in arr.attrs.items()
                                 if k != "_ARRAY_DIMENSIONS"})
        return ds


def _require(mod: str):
    try:
        return __import__(mod)
    except ImportError as e:  # pragma: no cover - depends on environment
        raise ImportError(
            f"Dataset adapter needs the optional '{mod}' package, which is not "
            f"installed in this environment; use the npz adapter or install it."
        ) from e


def _plain(v):
    """HDF5 attr values into serial-codec-safe plain Python."""
    if isinstance(v, np.generic):
        return v.item()
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, bytes):
        return v.decode("utf-8", "replace")
    return v


def open_dataset(path) -> Dataset:
    """Open ``path`` by extension: ``.npz`` / ``.h5``/``.hdf5`` / a zarr
    directory. The repro container format itself is handled by
    :func:`repro.io.read`, not here."""
    p = str(path)
    if os.path.isdir(p):
        return Dataset.from_zarr(p)
    ext = os.path.splitext(p)[1].lower()
    if ext == ".npz":
        return Dataset.from_npz(p)
    if ext in (".h5", ".hdf5", ".nc"):
        return Dataset.from_hdf5(p)
    raise ValueError(f"don't know how to open {p!r}; expected .npz/.h5/.hdf5 or a zarr dir")
