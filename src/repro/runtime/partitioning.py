"""Logical-axis partitioning (maxtext-style rules, simplified).

Model code annotates activations with *logical* axis names via shard();
the runtime installs a mesh + a logical->mesh mapping. With no mesh
installed (unit tests, single host) every annotation is a no-op, so the
same model code runs anywhere. Rules are also the §Perf hillclimb lever:
the dry-run re-lowers under alternative rule sets.
"""
from __future__ import annotations

import math
from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# logical axis -> mesh axis (str | tuple | None)
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_kv": None,
    "embed": None,
    "act_seq": None,          # residual-stream sequence axis (seq-parallel lever)
    "heads": "model",
    "kv_heads": "model",
    "kv_seq": None,           # decode KV cache length
    "ffn": "model",
    "vocab": "model",
    "experts": None,
    "expert_ffn": "model",
    "lru": "model",
    "ssm_heads": "model",
}

_STATE: dict = {"mesh": None, "rules": dict(DEFAULT_RULES), "off": 0}


def shard_map(f, mesh, in_specs, out_specs):
    """Version-portable shard_map with replication checking disabled.

    Newer jax exposes jax.shard_map(check_vma=...); older releases only have
    jax.experimental.shard_map.shard_map(check_rep=...).
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
        except TypeError:  # intermediate releases expose jax.shard_map with check_rep
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


def in_manual_region() -> bool:
    """True when tracing inside a Manual (shard_map) mesh region, where XLA
    cannot nest another shard_map. Best-effort across jax versions."""
    try:
        ctx = jax.sharding.get_abstract_mesh()
        return ctx is not None and not ctx.empty and any(
            t == jax.sharding.AxisType.Manual for t in ctx.axis_types
        )
    except Exception:  # pragma: no cover - older jax lacks the probes
        return False


@contextmanager
def no_annotation():
    """Disable shard() annotations (e.g. inside shard_map bodies)."""
    _STATE["off"] += 1
    try:
        yield
    finally:
        _STATE["off"] -= 1


def set_mesh(mesh: Mesh | None, rules: dict | None = None) -> None:
    _STATE["mesh"] = mesh
    _STATE["rules"] = dict(DEFAULT_RULES, **(rules or {}))


def get_mesh() -> Mesh | None:
    return _STATE["mesh"]


def get_rules() -> dict:
    return _STATE["rules"]


@contextmanager
def mesh_rules(mesh: Mesh | None, rules: dict | None = None):
    old = (_STATE["mesh"], _STATE["rules"])
    set_mesh(mesh, rules)
    try:
        yield
    finally:
        _STATE["mesh"], _STATE["rules"] = old


def _axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, str):
        return mesh.shape[ax] if ax in mesh.shape else 0
    return math.prod(_axis_size(mesh, a) for a in ax)


def resolve(names: tuple, shape: tuple, mesh: Mesh | None = None, rules: dict | None = None) -> P:
    """Logical names -> PartitionSpec, dropping axes that don't divide."""
    mesh = mesh or _STATE["mesh"]
    rules = rules or _STATE["rules"]
    spec = []
    used: set = set()
    for i, nm in enumerate(names):
        ax = rules.get(nm) if nm else None
        size = _axis_size(mesh, ax) if mesh is not None else 0
        flat = (ax,) if isinstance(ax, str) else tuple(ax or ())
        if ax is None or size == 0 or shape[i] % size != 0 or any(a in used for a in flat):
            spec.append(None)
        else:
            spec.append(ax)
            used.update(flat)
    return P(*spec)


def shard(x: jax.Array, *names: str | None) -> jax.Array:
    """Annotate activation x with logical axes (no-op without a mesh)."""
    mesh = _STATE["mesh"]
    if mesh is None or _STATE["off"]:
        return x
    assert len(names) == x.ndim, f"shard(): {len(names)} names for rank-{x.ndim} array"
    spec = resolve(tuple(names), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*names: str, shape: tuple) -> NamedSharding | None:
    mesh = _STATE["mesh"]
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve(tuple(names), shape, mesh))
