"""Parameter sharding rules: param path + shape -> PartitionSpec.

Policy (baseline; §Perf re-lowers under variants):
  * tensor parallelism over 'model': FFN hidden dim, attention heads (when
    head counts divide), vocab/embedding, expert FFN dim, LRU width;
  * FSDP (ZeRO-3) over 'data' for archs above a parameter threshold: the
    non-TP matrix dim is sharded; optimizer state mirrors parameters;
  * parameters are replicated across 'pod' (cross-pod sync is the explicit
    — optionally compressed — gradient exchange in runtime/steps.py).
Archs whose head counts don't divide the model axis (yi 56H, internvl2 14H,
whisper 12H, recurrentgemma 10H) keep attention weights model-replicated and
parallelize attention over the sequence instead (activation rules).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, param_count

FSDP_THRESHOLD = 2_000_000_000
SEQPAR_THRESHOLD = 8_000_000_000  # residual-stream sequence parallelism


def _ok(dim: int, mesh: Mesh, ax: str | None):
    return ax if ax and ax in mesh.shape and dim % mesh.shape[ax] == 0 else None


def use_fsdp(cfg: ModelConfig) -> bool:
    if cfg.force_fsdp >= 0:
        return bool(cfg.force_fsdp)
    return param_count(cfg) >= FSDP_THRESHOLD


def use_seqpar(cfg: ModelConfig) -> bool:
    if cfg.force_seqpar >= 0:
        return bool(cfg.force_seqpar)
    return param_count(cfg) >= SEQPAR_THRESHOLD


def activation_rules(cfg: ModelConfig, mesh: Mesh) -> dict:
    """Logical-axis rules for this arch (see runtime.partitioning)."""
    m = mesh.shape.get("model", 1)
    heads_ok = cfg.n_heads % m == 0
    kv_ok = cfg.n_kv_heads % m == 0
    big = use_seqpar(cfg)
    rules = {
        "heads": "model" if heads_ok else None,
        "kv_heads": "model" if kv_ok else None,
        # sequence-TP fallback for attention when heads don't divide
        "seq": None if heads_ok else "model",
        "seq_kv": None,  # KV never seq-sharded in train: blockwise tiles slice freely
        "kv_seq": "model",
        "ffn": "model",
        "vocab": "model",
        "expert_ffn": "model",
        "ssm_heads": "model",
        "batch": ("pod", "data") if "pod" in mesh.shape else ("data",),
        # sequence parallelism on the saved residual stream: bounds the
        # per-device remat carries of deep/wide archs (yi, granite, ...)
        "act_seq": "model" if big else None,
        "embed": None,
        "lru": "model",
        "experts": None,
    }
    return rules


def param_pspec(path: str, shape: tuple, cfg: ModelConfig, mesh: Mesh) -> P:
    fsdp = "data" if use_fsdp(cfg) else None
    m = mesh.shape.get("model", 1)
    heads_ok = cfg.n_heads % m == 0
    kv_ok = cfg.n_kv_heads % m == 0
    name = path.split("/")[-1]
    in_attn = "/attn/" in path or "/cross/" in path
    in_moe = "/moe/" in path and "/shared/" not in path
    lead = (None,) * (len(shape) - 2)  # stacked group axes / expert axis prefix

    def spec(*tail):
        # drop axes that don't divide
        full = lead + tail
        fixed = []
        for ax, dim in zip(full, shape):
            fixed.append(_ok(dim, mesh, ax) if isinstance(ax, str) else None if ax is None else ax)
        return P(*fixed)

    if name == "embed":
        return P(_ok(shape[0], mesh, "model"), _ok(shape[1], mesh, fsdp))
    if name == "lm_head":
        return P(_ok(shape[0], mesh, fsdp), _ok(shape[1], mesh, "model"))
    if in_moe:
        if name == "router":
            return spec(None, None)
        ep = cfg.moe_expert_parallel and cfg.n_experts % max(m, 1) == 0
        if name in ("w1", "w3"):
            # (E, d, f): expert-parallel shards E; else TP on f
            return P(_ok(shape[0], mesh, "model"), _ok(shape[1], mesh, fsdp), None) if ep else spec(fsdp, "model")
        if name == "w2":
            return P(_ok(shape[0], mesh, "model"), None, _ok(shape[2], mesh, fsdp)) if ep else spec("model", fsdp)
    if in_attn:
        # projections are 2-axis sharded regardless of head divisibility:
        # storage is FSDP-style; GSPMD gathers on use when heads don't divide
        if name == "wq":
            return spec(fsdp, "model")
        if name in ("wk", "wv"):
            return spec(fsdp, "model" if kv_ok or not heads_ok else "model")
        if name in ("bq", "bk", "bv"):
            return spec("model")
        if name == "wo":
            return spec("model", fsdp)
    if "/mlp/" in path or "/shared/" in path:
        if name in ("w1", "w3"):
            return spec(fsdp, "model")
        if name == "w2":
            return spec("model", fsdp)
    if "/ssm/" in path:
        if name == "in_proj":
            return spec(fsdp, None)
        if name == "out_proj":
            return spec("model", fsdp)
        return spec(*([None] * len(shape)))
    if "/rglru/" in path:
        if name in ("w_gelu", "w_x"):
            return spec(fsdp, "model")
        if name in ("w_r", "w_i"):
            return spec("model", None)
        if name == "w_out":
            return spec("model", fsdp)
        if name == "conv_w":
            return spec(None, "model")
        return spec(*([None] * len(shape)))
    # norms, biases, scalars
    return spec(*([None] * len(shape)))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/" + "/".join(parts)


def tree_pspecs(tree, cfg: ModelConfig, mesh: Mesh):
    """Pytree of PartitionSpecs matching `tree` (works on ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(_path_str(path), leaf.shape, cfg, mesh), tree
    )


def tree_shardings(tree, cfg: ModelConfig, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_pspecs(tree, cfg, mesh))


def cache_pspec(path: str, shape: tuple, cfg: ModelConfig, mesh: Mesh, rules: dict) -> P:
    """KV-cache / recurrent-state sharding for serve steps."""
    name = path.split("/")[-1]
    dp = rules.get("batch", ("data",))
    dp = dp if isinstance(dp, tuple) else (dp,)
    bs = 1
    for a in dp:
        bs *= mesh.shape.get(a, 1)
    batch_ax = dp if shape[0] % max(bs, 1) == 0 and bs > 1 else None
    if name in ("k", "v", "xk", "xv") and len(shape) == 4:
        # (B, T, Hk, dh): prefer cache-length sharding, else kv heads
        t_ax = _ok(shape[1], mesh, "model")
        h_ax = _ok(shape[2], mesh, "model") if t_ax is None else None
        return P(batch_ax, t_ax, h_ax, None)
    if name == "state" and len(shape) == 4:  # (B, H, N, P)
        return P(batch_ax, _ok(shape[1], mesh, "model"), None, None)
    if name == "h":  # (B, L)
        return P(batch_ax, _ok(shape[1], mesh, "model"))
    if name == "conv":
        return P(batch_ax, *([None] * (len(shape) - 1)))
    return P(batch_ax, *([None] * (len(shape) - 1)))


def cache_pspecs(tree, cfg: ModelConfig, mesh: Mesh, rules: dict):
    def one(path, leaf):
        ps = _path_str(path)
        shp = leaf.shape
        if len(shp) >= 1 and "/stack/" in ps:  # stacked group axis leads
            inner = cache_pspec(ps, shp[1:], cfg, mesh, rules)
            return P(None, *inner)
        return cache_pspec(ps, shp, cfg, mesh, rules)

    return jax.tree_util.tree_map_with_path(one, tree)
