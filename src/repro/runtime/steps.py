"""Jittable train / prefill / serve steps with explicit shardings.

make_train_step: loss -> grads -> clip -> [cross-pod compressed exchange]
-> AdamW. Within a pod, gradient reduction and FSDP gathers are GSPMD's
(overlapped by the latency-hiding scheduler); across pods the exchange is
the explicit int8 error-feedback collective from repro.optim.grad_compress,
running inside jax.shard_map manual over the 'pod' axis only.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_params, loss_fn, prefill
from repro.optim import adamw_update, clip_by_global_norm, init_opt
from repro.optim.adamw import OptState
from repro.runtime import sharding_rules as rules_mod


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: OptState
    resid: Any  # error-feedback residuals, leading 'pod' axis; None-like if off


def make_train_state(cfg: ModelConfig, rng, *, npods: int = 0):
    params = init_params(cfg, rng)
    opt = init_opt(params)
    resid = ()
    if npods:
        resid = jax.tree.map(lambda p: jnp.zeros((npods,) + p.shape, jnp.float32), params)
    return TrainState(params=params, opt=opt, resid=resid)


def state_pspecs(state_shapes, cfg: ModelConfig, mesh: Mesh):
    """PartitionSpecs for a TrainState of ShapeDtypeStructs."""
    p_spec = rules_mod.tree_pspecs(state_shapes.params, cfg, mesh)
    m_spec = rules_mod.tree_pspecs(state_shapes.opt.m, cfg, mesh)
    v_spec = rules_mod.tree_pspecs(state_shapes.opt.v, cfg, mesh)
    if isinstance(state_shapes.resid, tuple) and state_shapes.resid == ():
        r_spec = ()
    else:
        r_spec = jax.tree.map(lambda ps: P("pod", *ps), p_spec)
    return TrainState(
        params=p_spec,
        opt=OptState(m=m_spec, v=v_spec, step=P()),
        resid=r_spec,
    )


def batch_pspecs(batch_shapes, mesh: Mesh):
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)

    def one(leaf):
        size = 1
        for a in dp:
            size *= mesh.shape[a]
        ax = dp if leaf.shape and leaf.shape[0] % size == 0 else None
        return P(ax, *([None] * (len(leaf.shape) - 1)))

    return jax.tree.map(one, batch_shapes)


def make_train_step(cfg: ModelConfig, mesh: Mesh | None, *, lr=3e-4, grad_clip=1.0, compress_pods=False):
    """Returns step(state, batch) -> (state, metrics). Call under part.mesh_rules."""

    def _cast_params(params):
        """bf16_params: cast fp32 matrices to bf16 pinned to their sharding,
        so FSDP all-gathers move bf16, not fp32 (gather-after-cast)."""
        if not cfg.bf16_params:
            return params

        specs = rules_mod.tree_pspecs(params, cfg, mesh) if mesh is not None else jax.tree.map(lambda _: None, params)

        def one(p, s):
            if hasattr(p, "dtype") and p.dtype == jnp.float32 and p.ndim >= 2:
                c = p.astype(jnp.bfloat16)
                if s is not None:
                    c = jax.lax.with_sharding_constraint(c, NamedSharding(mesh, s))
                return c
            return p

        return jax.tree.map(one, params, specs)

    def loss_of(params, batch):
        return loss_fn(_cast_params(params), cfg, batch)

    def plain_step(state: TrainState, batch):
        loss, grads = jax.value_and_grad(loss_of)(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        new_params, new_opt = adamw_update(state.params, grads, state.opt, lr)
        return TrainState(new_params, new_opt, state.resid), {"loss": loss, "grad_norm": gnorm}

    if not (compress_pods and mesh is not None and "pod" in mesh.shape):
        return plain_step

    # Compressed cross-pod exchange without manual regions ("vmap islands"):
    # the batch gets a leading pod axis sharded over 'pod'; vmap(grad) then
    # yields PER-POD gradients (no automatic cross-pod psum). Each pod
    # quantizes its gradient (+ error-feedback residual) to int8 with a
    # per-tensor scale; replicating the int8 tree over 'pod' lowers to an
    # int8 all-gather — the 4x-smaller wire format — and every device forms
    # the average locally. Pure GSPMD: XLA schedules/overlaps the gathers.
    npods = mesh.shape["pod"]

    def _pod_spec(leaf) -> NamedSharding:
        return NamedSharding(mesh, P("pod", *([None] * (leaf.ndim - 1))))

    def compressed_step(state: TrainState, batch):
        bb = jax.tree.map(lambda x: x.reshape((npods, x.shape[0] // npods) + x.shape[1:]), batch)
        bb = jax.tree.map(lambda x: jax.lax.with_sharding_constraint(x, _pod_spec(x)), bb)
        losses, grads_p = jax.vmap(lambda b: jax.value_and_grad(loss_of)(state.params, b))(bb)

        def exchange(g, r):
            g = jax.lax.with_sharding_constraint(g.astype(jnp.float32), _pod_spec(g))
            t = g + r
            axes = tuple(range(1, t.ndim))
            scale = jnp.maximum(jnp.max(jnp.abs(t), axis=axes, keepdims=True), 1e-30) / 127.0
            q = jnp.clip(jnp.rint(t / scale), -127, 127).astype(jnp.int8)
            new_r = t - q.astype(jnp.float32) * scale
            # replicate int8 payload across pods == all-gather on the wire
            q_rep = jax.lax.with_sharding_constraint(q, NamedSharding(mesh, P(*([None] * q.ndim))))
            s_rep = jax.lax.with_sharding_constraint(scale, NamedSharding(mesh, P(*([None] * scale.ndim))))
            avg = jnp.mean(q_rep.astype(jnp.float32) * s_rep, axis=0)
            return avg, new_r

        flat_g, tdef = jax.tree.flatten(grads_p)
        flat_r = tdef.flatten_up_to(state.resid)
        pairs = [exchange(g, r) for g, r in zip(flat_g, flat_r)]
        grads = tdef.unflatten([p[0] for p in pairs])
        new_resid = tdef.unflatten([p[1] for p in pairs])
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        new_params, new_opt = adamw_update(state.params, grads, state.opt, lr)
        return TrainState(new_params, new_opt, new_resid), {"loss": losses.mean(), "grad_norm": gnorm}

    return compressed_step


def make_prefill_step(cfg: ModelConfig):
    def step(params, batch):
        return prefill(params, cfg, batch)

    return step


def make_serve_step(cfg: ModelConfig):
    def step(params, cache, token, pos):
        return decode_step(params, cfg, token, pos, cache)

    return step
