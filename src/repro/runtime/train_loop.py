"""Fault-tolerant training loop.

* checkpoint/restart: restores the latest checkpoint on start, saves
  (optionally cuSZ-Hi-compressed) snapshots asynchronously every
  save_every steps, final synchronous save on exit/preemption;
* preemption: SIGTERM flips a flag; the loop finishes the in-flight step,
  saves synchronously, and exits cleanly (simulated in tests);
* straggler mitigation: per-step wall-time EWMA; steps slower than
  `straggler_factor` x EWMA are logged and counted — the deployment hook
  (on_straggler) can re-shard input or alert the scheduler. NaN losses
  trigger a rollback to the last checkpoint (skip-and-continue).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable

import jax
import numpy as np

from repro import checkpoint as ckpt


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    save_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_eb: float = 0.0            # >0: error-bounded compressed checkpoints
    straggler_factor: float = 3.0
    ewma: float = 0.9
    log_every: int = 10


class Trainer:
    def __init__(self, step_fn: Callable, state, data_iter, cfg: LoopConfig, *, log=print):
        self.step_fn = step_fn
        self.state = state
        self.data = data_iter
        self.cfg = cfg
        self.log = log
        self.preempted = False
        self.stragglers = 0
        self.step = 0
        self.losses: list[float] = []
        self._saver = ckpt.AsyncCheckpointer(cfg.ckpt_dir, eb=cfg.ckpt_eb)
        self._restore()

    def _restore(self):
        last = ckpt.latest_step(self.cfg.ckpt_dir)
        if last is not None:
            shapes = jax.eval_shape(lambda: self.state)
            self.state, manifest = ckpt.restore(shapes, self.cfg.ckpt_dir, last)
            self.step = manifest["step"]
            self.log(f"[trainer] restored step {self.step} (ckpt CR {manifest.get('cr')})")

    def _handle_sigterm(self, *_):
        self.preempted = True

    def run(self):
        old = signal.signal(signal.SIGTERM, self._handle_sigterm)
        ewma_t = None
        try:
            while self.step < self.cfg.total_steps and not self.preempted:
                batch = next(self.data)
                t0 = time.time()
                self.state, metrics = self.step_fn(self.state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                self.step += 1
                if not np.isfinite(loss):
                    self.log(f"[trainer] step {self.step}: non-finite loss, rolling back")
                    self._restore()
                    continue
                self.losses.append(loss)
                if ewma_t is not None and dt > self.cfg.straggler_factor * ewma_t:
                    self.stragglers += 1
                    self.on_straggler(self.step, dt, ewma_t)
                if self.step > 1:  # exclude the jit-compile step from the EWMA
                    ewma_t = dt if ewma_t is None else self.cfg.ewma * ewma_t + (1 - self.cfg.ewma) * dt
                if self.step % self.cfg.log_every == 0:
                    self.log(f"[trainer] step {self.step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
                if self.step % self.cfg.save_every == 0:
                    self._saver.submit(self.state, self.step)
            # drain async saver, then final synchronous save (preemption/completion)
            self._saver.close()
            ckpt.save(self.state, self.cfg.ckpt_dir, self.step, eb=self.cfg.ckpt_eb)
        finally:
            signal.signal(signal.SIGTERM, old)
        return self.state

    def on_straggler(self, step: int, dt: float, ewma_t: float):
        self.log(f"[trainer] straggler at step {step}: {dt:.2f}s vs EWMA {ewma_t:.2f}s")
