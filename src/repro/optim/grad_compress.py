"""Cross-pod error-bounded gradient compression with error feedback.

The paper's quantizer at fixed rate: each pod quantizes its (already
data/model-sharded) gradient shard to int8 with a per-tensor scale
(absolute error bound = scale/2, i.e. value-range-relative eb ~ 1/254 —
Eq. 1's contract on the gradient tensor), exchanges the 4x-smaller payload
across pods (all_gather over 'pod'), dequantizes and averages. The
quantization residual is fed back into the next step (error feedback), so
compression error accumulates O(1), not O(steps).

Variable-length entropy stages can't ride a jit'd collective (data-
dependent sizes) — they apply on the checkpoint/field paths instead
(DESIGN.md §7.4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_shard(t: jnp.ndarray):
    scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-30) / 127.0
    q = jnp.clip(jnp.rint(t / scale), -127, 127).astype(jnp.int8)
    return q, scale


def pod_allreduce_compressed(grads, residuals, axis: str = "pod"):
    """Inside shard_map(manual over `axis`): error-feedback int8 all-reduce.

    grads/residuals: pytrees of pod-local f32 leaves. Returns (avg_grads,
    new_residuals)."""
    npods = jax.lax.axis_size(axis)

    def one(g, r):
        g = g.astype(jnp.float32)
        t = g + r
        q, scale = quantize_shard(t)
        deq = q.astype(jnp.float32) * scale
        new_r = t - deq
        q_all = jax.lax.all_gather(q, axis)          # (npods, ...) int8 on the wire
        s_all = jax.lax.all_gather(scale, axis)
        avg = jnp.tensordot(s_all, q_all.astype(jnp.float32), axes=((0,), (0,))) / npods
        return avg, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    avg = tdef.unflatten([o[0] for o in out])
    new_res = tdef.unflatten([o[1] for o in out])
    return avg, new_res
