"""Cross-pod error-bounded gradient compression with error feedback.

The paper's quantizer at fixed rate: each pod quantizes its (already
data/model-sharded) gradient shard to int8 with a per-tensor scale
(absolute error bound = scale/2, i.e. value-range-relative eb ~ 1/254 —
Eq. 1's contract on the gradient tensor), exchanges the 4x-smaller payload
across pods (all_gather over 'pod'), dequantizes and averages. The
quantization residual is fed back into the next step (error feedback), so
compression error accumulates O(1), not O(steps).

Variable-length entropy stages can't ride a jit'd collective (data-
dependent sizes) — inside jit they apply on the checkpoint/field paths
instead (DESIGN.md §7.4). For host-relayed links (DCN pod exchange,
parameter-server push, gradient spooling to disk), :func:`pack_quantized`
/ :func:`unpack_quantized` run the int8 shard through the lossless
orchestrator (``pipeline="auto"`` picks the best-fit registered pipeline
per shard and records it in the payload header), shrinking the wire
bytes well below the 4x of plain int8 when gradients are sparse or
low-entropy. :func:`pack_quantized_sharded` is the device-sharded form:
each addressable device shard is packed as its own container-v3 frame
(repro.core.frames) straight off its device — no host gather of the
global tensor — with per-shard pipeline choices and slice metadata for
(partial) reassembly.
"""
from __future__ import annotations

import struct

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lossless import encode_auto, pipelines
from repro.core.serial import pack_obj, unpack_obj


def quantize_shard(t: jnp.ndarray):
    scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-30) / 127.0
    q = jnp.clip(jnp.rint(t / scale), -127, 127).astype(jnp.int8)
    return q, scale


def pod_allreduce_compressed(grads, residuals, axis: str = "pod"):
    """Inside shard_map(manual over `axis`): error-feedback int8 all-reduce.

    grads/residuals: pytrees of pod-local f32 leaves. Returns (avg_grads,
    new_residuals)."""
    npods = jax.lax.axis_size(axis)

    def one(g, r):
        g = g.astype(jnp.float32)
        t = g + r
        q, scale = quantize_shard(t)
        deq = q.astype(jnp.float32) * scale
        new_r = t - deq
        q_all = jax.lax.all_gather(q, axis)          # (npods, ...) int8 on the wire
        s_all = jax.lax.all_gather(scale, axis)
        avg = jnp.tensordot(s_all, q_all.astype(jnp.float32), axes=((0,), (0,))) / npods
        return avg, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    avg = tdef.unflatten([o[0] for o in out])
    new_res = tdef.unflatten([o[1] for o in out])
    return avg, new_res


# ------------------------------------------------- host-relay lossless path
def pack_quantized(q, scale, pipeline: str = "auto") -> bytes:
    """Serialize an int8-quantized shard through the lossless orchestrator.

    The int8 stream is re-biased to offset-128 uint8 (zero-centered
    gradients land on 128, matching the quantization-code law the stage
    cost hooks were built for). ``pipeline="auto"`` records the chosen
    pipeline in the header; any registered pipeline name is also accepted.

    ``q`` may be a device (jax) array: the re-bias then runs on device and
    the stream feeds the device encoding engine through the pipelines fast
    path — bytes are identical to the host path (the engine contract).
    """
    if pipelines._is_jax(q):
        import jax.lax
        import jax.numpy as jnp

        qd = q if q.dtype == jnp.int8 else q.astype(jnp.int8)
        shape = qd.shape
        stream = jax.lax.bitcast_convert_type(qd.reshape(-1), jnp.uint8) ^ np.uint8(0x80)
    else:
        qd = np.ascontiguousarray(np.asarray(q, np.int8))
        shape = qd.shape
        stream = (qd.reshape(-1).view(np.uint8) ^ np.uint8(0x80))
    if pipeline == "auto":
        # portable pipelines only: the payload may be decoded on another pod
        # or archived, so it must never require an optional codec
        payload, record = encode_auto(stream, portable_only=True)
        name = record["pipeline"]
    else:
        payload = pipelines.encode(stream, pipeline)
        name = pipeline
    hb = pack_obj({"shape": list(shape), "scale": float(scale), "pipeline": name})
    return struct.pack("<I", len(hb)) + hb + payload


def unpack_quantized(buf):
    """Inverse of :func:`pack_quantized`: returns ``(q int8, scale)``.

    ``buf`` is any bytes-like object; the payload is decoded from a
    zero-copy view (the sharded reader hands frames through as
    memoryviews)."""
    mv = buf if isinstance(buf, memoryview) else memoryview(buf)
    (hlen,) = struct.unpack_from("<I", mv, 0)
    hdr = unpack_obj(mv[4 : 4 + hlen])
    stream = pipelines.decode(mv[4 + hlen :])
    q = (stream ^ np.uint8(0x80)).view(np.int8).reshape(hdr["shape"])
    return q, hdr["scale"]


def pack_quantized_sharded(q, scale, pipeline: str = "auto") -> bytes:
    """Per-device :func:`pack_quantized`, with no host gather of ``q``.

    ``q``: a device-sharded jax array (int8). Each *addressable* shard is
    packed as its own container-v3 frame through the lossless orchestrator
    — the shard stream stays device-resident through the encoding engine
    (never the assembled global array, and not even the per-shard raw
    stream, crosses to host; only encoded frame payloads do), so every
    device shard keeps its own best-fit pipeline choice. Replicated
    placements are deduped by shard index. The global header records each
    frame's slice of the full tensor; :func:`unpack_quantized_sharded`
    reassembles (a subset of frames reassembles a partial tensor).
    """
    import io

    from repro.core.frames import FrameWriter

    seen: dict[tuple, object] = {}
    for s in q.addressable_shards:
        key = tuple((sl.start or 0, sl.stop if sl.stop is not None else dim)
                    for sl, dim in zip(s.index, q.shape))
        seen.setdefault(key, s.data)
    order = sorted(seen)
    sink = io.BytesIO()
    header = {
        "kind": "gradq",
        "shape": list(q.shape),
        "scale": float(scale),
        "slices": [[list(b) for b in key] for key in order],
    }
    with FrameWriter(sink, header) as w:
        for key in order:
            # the shard stays a device array: pack_quantized re-biases it on
            # device and the encoding engine emits the frame payload directly —
            # the raw quantized stream never crosses to host
            w.write_frame(pack_quantized(seen[key], scale, pipeline))
    return sink.getvalue()


def unpack_quantized_sharded(buf: bytes, frames=None):
    """Inverse of :func:`pack_quantized_sharded`: ``(q int8, scale)``.

    ``frames``: optional frame indices — only those shards are filled
    (the rest of the tensor is zero), for partial/streamed reassembly.
    """
    from repro.core.frames import frame_table, read_frame

    header, table = frame_table(buf)
    if header.get("kind") != "gradq":
        raise ValueError(f"not a sharded gradient payload (kind={header.get('kind')!r})")
    out = np.zeros(tuple(header["shape"]), np.int8)
    idx = range(len(table)) if frames is None else frames
    for i in idx:
        q_s, _ = unpack_quantized(read_frame(buf, table[i]))
        sl = tuple(slice(a, b) for a, b in header["slices"][i])
        out[sl] = q_s
    return out, header["scale"]
