"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, peak=3e-4, warmup=100, total=10000, floor=0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)
