"""AdamW with fully-sharded states (m/v mirror parameter shardings)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    m: Any
    v: Any
    step: jnp.ndarray


def init_opt(params) -> OptState:
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(m=jax.tree.map(z, params), v=jax.tree.map(z, params), step=jnp.zeros((), jnp.int32))


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(params, grads, opt: OptState, lr, *, b1=0.9, b2=0.95, eps=1e-8, wd=0.1):
    step = opt.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1**t
    c2 = 1.0 - b2**t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / c1) / (jnp.sqrt(v / c2) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt.m, opt.v)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(m=new_m, v=new_v, step=step)
