from .adamw import OptState, adamw_update, clip_by_global_norm, init_opt  # noqa: F401
from .grad_compress import pod_allreduce_compressed, quantize_shard  # noqa: F401
from .schedule import cosine_with_warmup  # noqa: F401
