"""Fault-tolerant checkpointing.

Layout: <dir>/step_<N>/  one file per leaf + manifest.json; writes go to a
temp directory first, fsync'd, then atomically renamed — a crash mid-save
never corrupts the latest checkpoint. Checkpoints are mesh-agnostic
(leaves saved unsharded-logical); restore reshards onto any mesh (elastic
rescale). Async save runs on a daemon thread with a single-slot queue so
training never blocks more than one pending snapshot.
"""
from __future__ import annotations

import json
import os
import pathlib
import queue
import shutil
import threading
import uuid

import jax
import numpy as np

from .codec import decode_tensor, encode_tensor_to

_MANIFEST = "manifest.json"


def _leaf_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "_".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
        )
        out.append((key, leaf))
    return out


def save(tree, directory: str | os.PathLike, step: int, *, eb: float = 0.0) -> dict:
    """Synchronous atomic save. Returns the manifest."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    # unique tmp dir: concurrent savers (async worker + final sync save)
    # must never stomp each other's in-flight files
    tmp = directory / f".tmp_step_{step:08d}_{uuid.uuid4().hex[:8]}"
    tmp.mkdir(parents=True)
    manifest = {"step": int(step), "leaves": {}, "format": 1}
    raw_total = comp_total = 0
    for key, leaf in _leaf_paths(tree):
        arr = np.asarray(leaf)
        fn = f"{key}.bin"
        # error-bounded leaves stream v3 frames into the file as each chunk
        # encodes, so OS writeback of earlier frames overlaps the encode of
        # later ones; one fsync per leaf seals the file
        with open(tmp / fn, "wb") as f:
            meta = encode_tensor_to(f, arr, eb=eb)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"][key] = dict(meta, file=fn)
        raw_total += arr.nbytes
        comp_total += meta["bytes"]
    manifest["raw_bytes"] = int(raw_total)
    manifest["compressed_bytes"] = int(comp_total)
    manifest["cr"] = round(raw_total / max(comp_total, 1), 3)
    with open(tmp / _MANIFEST, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    return manifest


def latest_step(directory: str | os.PathLike) -> int | None:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = []
    for d in directory.iterdir():
        if d.name.startswith("step_") and (d / _MANIFEST).exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(tree_like, directory: str | os.PathLike, step: int | None = None, *, shardings=None):
    """Restore into the structure of `tree_like` (ShapeDtypeStructs ok).

    `shardings`: optional pytree of NamedSharding — leaves are placed
    shard-by-shard onto the (possibly different) mesh: elastic restore."""
    directory = pathlib.Path(directory)
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / _MANIFEST).read_text())
    keys = [k for k, _ in _leaf_paths(tree_like)]
    flat_sh = [None] * len(keys)
    if shardings is not None:
        flat_sh = [s for _, s in _leaf_paths(shardings)]
    leaves = []
    for key, sh in zip(keys, flat_sh):
        meta = manifest["leaves"][key]
        payload = (d / meta["file"]).read_bytes()
        arr = decode_tensor(payload, meta)
        leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


class AsyncCheckpointer:
    """Single-slot background saver: at most one pending snapshot, newer
    requests replace queued ones (training never waits on I/O).

    Worker-thread failures are never silently parked until a later
    ``submit``: :meth:`wait` (drain) and :meth:`close` (the sync point
    before a final synchronous save) both re-raise the stored exception
    *object*, so the original worker-thread traceback is preserved on it.
    """

    def __init__(self, directory: str | os.PathLike, *, eb: float = 0.0):
        self.directory = pathlib.Path(directory)
        self.eb = eb
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._err: Exception | None = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                tree, step = item
                save(tree, self.directory, step, eb=self.eb)
            except Exception as e:  # noqa: BLE001 - stored with its traceback, re-raised on wait/close
                self._err = e
            finally:
                self._q.task_done()

    def _raise_pending(self):
        if self._err:
            err, self._err = self._err, None
            raise err  # the exception object still carries the worker traceback

    def submit(self, tree, step: int):
        self._raise_pending()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot now
        try:
            self._q.put_nowait((host_tree, step))
        except queue.Full:
            try:
                self._q.get_nowait()  # drop the stale pending snapshot
                self._q.task_done()
            except queue.Empty:
                pass
            self._q.put_nowait((host_tree, step))

    def wait(self):
        """Block until every submitted snapshot is saved (or failed), then
        surface any worker exception with its original traceback."""
        self._q.join()
        self._raise_pending()

    def close(self):
        self._q.put(None)
        self._thread.join(timeout=60)
        self._raise_pending()
