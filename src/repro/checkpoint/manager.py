"""Fault-tolerant checkpointing.

Layout: <dir>/step_<N>/  one file per leaf + manifest.json; writes go to a
temp directory first, fsync'd, then atomically renamed — a crash mid-save
never corrupts the latest checkpoint. Orphaned ``.tmp_step_*`` dirs from a
killed earlier process are swept on the next :func:`save` (live tmp dirs
of *this* process are tracked and never touched, so the async worker and a
final sync save cannot stomp each other). Checkpoints are mesh-agnostic
(leaves saved unsharded-logical); restore reshards onto any mesh (elastic
rescale). Async save runs on a daemon thread with a single-slot queue so
training never blocks more than one pending snapshot.

Damage model: every leaf file carries a whole-payload CRC32 in the
manifest (manifest ``format`` 2; format-1 checkpoints restore unchanged,
just without the pre-decode check). ``restore(..., strict=False)`` turns a
damaged checkpoint into the best state still on disk instead of an
exception: each corrupt leaf falls back to the newest earlier step whose
copy of that leaf verifies and decodes, and a leaf with no surviving copy
is reconstructed as zeros (or the template's value when ``tree_like``
carries concrete arrays). What happened per leaf is reported under
``manifest["salvage"]``.
"""
from __future__ import annotations

import json
import os
import pathlib
import queue
import shutil
import threading
import uuid
import zlib

import jax
import numpy as np

from repro.core.errors import CheckpointDamageError
from repro.core.retry import retry_call

from .codec import decode_tensor, encode_tensor_to

_MANIFEST = "manifest.json"

# tmp dirs owned by in-flight save() calls in this process; the stale
# sweep skips these so concurrent savers (async worker + a final sync
# save) never delete each other's work
_live_tmp: set[str] = set()
_live_tmp_lock = threading.Lock()


def _sweep_stale_tmp(directory: pathlib.Path) -> list[str]:
    """Remove orphaned ``.tmp_step_*`` dirs left by a crashed/killed save.

    A tmp dir not registered by this process is assumed dead: the layout
    is single-writer-per-directory by design (the atomic rename publish
    relies on that already), so anything unregistered belongs to a
    process that no longer exists. Returns the removed dir names.
    """
    removed = []
    with _live_tmp_lock:
        live = set(_live_tmp)
    for d in directory.glob(".tmp_step_*"):
        if str(d) in live or not d.is_dir():
            continue
        shutil.rmtree(d, ignore_errors=True)
        removed.append(d.name)
    return removed


def _leaf_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "_".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
        )
        out.append((key, leaf))
    return out


def save(tree, directory: str | os.PathLike, step: int, *, eb: float = 0.0) -> dict:
    """Synchronous atomic save. Returns the manifest."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    _sweep_stale_tmp(directory)
    final = directory / f"step_{step:08d}"
    # unique tmp dir: concurrent savers (async worker + final sync save)
    # must never stomp each other's in-flight files
    tmp = directory / f".tmp_step_{step:08d}_{uuid.uuid4().hex[:8]}"
    tmp.mkdir(parents=True)
    with _live_tmp_lock:
        _live_tmp.add(str(tmp))
    try:
        manifest = {"step": int(step), "leaves": {}, "format": 2}
        raw_total = comp_total = 0
        for key, leaf in _leaf_paths(tree):
            arr = np.asarray(leaf)
            fn = f"{key}.bin"
            # error-bounded leaves stream v3 frames into the file as each chunk
            # encodes, so OS writeback of earlier frames overlaps the encode of
            # later ones; one fsync per leaf seals the file
            with open(tmp / fn, "wb") as f:
                meta = encode_tensor_to(f, arr, eb=eb)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"][key] = dict(meta, file=fn)
            raw_total += arr.nbytes
            comp_total += meta["bytes"]
        manifest["raw_bytes"] = int(raw_total)
        manifest["compressed_bytes"] = int(comp_total)
        manifest["cr"] = round(raw_total / max(comp_total, 1), 3)
        with open(tmp / _MANIFEST, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)  # don't leak the partial save
        raise
    finally:
        with _live_tmp_lock:
            _live_tmp.discard(str(tmp))
    return manifest


def available_steps(directory: str | os.PathLike) -> list[int]:
    """All steps with a manifest on disk, ascending."""
    directory = pathlib.Path(directory)
    if not directory.exists():
        return []
    steps = []
    for d in directory.iterdir():
        if d.name.startswith("step_") and (d / _MANIFEST).exists():
            steps.append(int(d.name.split("_")[1]))
    return sorted(steps)


def latest_step(directory: str | os.PathLike) -> int | None:
    steps = available_steps(directory)
    return steps[-1] if steps else None


def _load_leaf(step_dir: pathlib.Path, meta: dict) -> np.ndarray:
    """Read + CRC-verify + decode one leaf file; raises on any damage."""
    payload = (step_dir / meta["file"]).read_bytes()
    want = meta.get("crc32")
    if want is not None:
        got = zlib.crc32(payload) & 0xFFFFFFFF
        if got != int(want):
            raise CheckpointDamageError(
                f"{meta['file']}: payload crc32 mismatch (expected {int(want):#010x}, got {got:#010x})"
            )
    return decode_tensor(payload, meta)


def _zeros_like_meta(meta: dict) -> np.ndarray:
    return np.zeros(tuple(meta["shape"]), np.dtype(meta["dtype"]))


def restore(tree_like, directory: str | os.PathLike, step: int | None = None, *,
            shardings=None, strict: bool = True):
    """Restore into the structure of `tree_like` (ShapeDtypeStructs ok).

    `shardings`: optional pytree of NamedSharding — leaves are placed
    shard-by-shard onto the (possibly different) mesh: elastic restore.

    ``strict=True`` (default): any damaged leaf — CRC mismatch, truncated
    file, undecodable container — raises
    :class:`repro.core.errors.CheckpointDamageError` (or the underlying
    decode error). ``strict=False``: restore degrades per leaf instead.
    Each damaged leaf falls back to the newest *earlier* step whose copy
    of that leaf verifies; a leaf with no surviving copy anywhere is
    reconstructed as zeros (or the template's own value when ``tree_like``
    holds concrete arrays). The returned manifest then carries a
    ``"salvage"`` report::

        {"damaged": {key: reason, ...},        # leaves bad at the requested step
         "fallback_steps": {key: step, ...},   # where each damaged leaf came from
         "lost": [key, ...]}                   # leaves with no surviving copy
    """
    directory = pathlib.Path(directory)
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    older = [s for s in available_steps(directory) if s < step]

    d = directory / f"step_{step:08d}"
    try:
        manifest = json.loads((d / _MANIFEST).read_text())
    except (OSError, ValueError) as e:
        if strict or not older:
            raise
        # the requested step's manifest itself is gone/corrupt: restore the
        # newest earlier step wholesale and report the demotion
        prev = older[-1]
        tree, manifest = restore(tree_like, directory, prev, shardings=shardings, strict=False)
        salvage = manifest.setdefault("salvage", {"damaged": {}, "fallback_steps": {}, "lost": []})
        salvage["damaged"]["<manifest>"] = f"step {step} manifest unreadable: {e!r}"
        salvage["fallback_steps"]["<manifest>"] = prev
        return tree, manifest

    template = _leaf_paths(tree_like)
    keys = [k for k, _ in template]
    flat_sh = [None] * len(keys)
    if shardings is not None:
        flat_sh = [s for _, s in _leaf_paths(shardings)]
    salvage = {"damaged": {}, "fallback_steps": {}, "lost": []}
    leaves = []
    for (key, tmpl), sh in zip(template, flat_sh):
        meta = manifest["leaves"][key]
        try:
            arr = _load_leaf(d, meta)
        except Exception as e:  # noqa: BLE001 - every damage mode funnels into the salvage path
            if strict:
                raise
            salvage["damaged"][key] = repr(e)
            arr = None
            for prev in reversed(older):  # newest surviving copy wins
                pd = directory / f"step_{prev:08d}"
                try:
                    pmanifest = json.loads((pd / _MANIFEST).read_text())
                    arr = _load_leaf(pd, pmanifest["leaves"][key])
                except Exception:  # noqa: BLE001 - that step's copy is damaged too; keep walking back
                    continue
                salvage["fallback_steps"][key] = prev
                break
            if arr is None:
                salvage["lost"].append(key)
                if hasattr(tmpl, "shape") and not isinstance(tmpl, jax.ShapeDtypeStruct):
                    arr = np.asarray(tmpl)
                else:
                    arr = _zeros_like_meta(meta)
        leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
    if salvage["damaged"]:
        manifest = dict(manifest, salvage=salvage)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


class AsyncCheckpointer:
    """Single-slot background saver: at most one pending snapshot, newer
    requests replace queued ones (training never waits on I/O).

    Worker-thread failures are never silently parked until a later
    ``submit``: :meth:`wait` (drain) and :meth:`close` (the sync point
    before a final synchronous save) both re-raise the stored exception
    *object*, so the original worker-thread traceback is preserved on it.
    Saves are retried through :func:`repro.core.retry.retry_call` — a
    transient ``OSError`` (NFS blip, ENOSPC race) costs a backoff, not
    the snapshot; the partial tmp dir of a failed attempt is swept by the
    retry's own :func:`save`.
    """

    def __init__(self, directory: str | os.PathLike, *, eb: float = 0.0):
        self.directory = pathlib.Path(directory)
        self.eb = eb
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._err: Exception | None = None
        self._submit_lock = threading.Lock()
        self._closed = False
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                tree, step = item
                retry_call(lambda: save(tree, self.directory, step, eb=self.eb))
            except Exception as e:  # noqa: BLE001 - stored with its traceback, re-raised on wait/close
                self._err = e
            finally:
                self._q.task_done()

    def _raise_pending(self):
        if self._err:
            err, self._err = self._err, None
            raise err  # the exception object still carries the worker traceback

    def submit(self, tree, step: int):
        self._raise_pending()
        if self._closed:
            raise RuntimeError("submit() on a closed AsyncCheckpointer")
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot now
        # serialize submitters: the old drop-then-put could race two callers
        # into a Full queue (both drop, both put, second put explodes) or
        # drop the snapshot a concurrent caller just queued without
        # replacing it
        with self._submit_lock:
            while True:
                try:
                    self._q.put_nowait((host_tree, step))
                    return
                except queue.Full:
                    try:
                        self._q.get_nowait()  # drop the stale pending snapshot
                        self._q.task_done()
                    except queue.Empty:
                        pass  # the worker grabbed it first; slot is free now

    def wait(self):
        """Block until every submitted snapshot is saved (or failed), then
        surface any worker exception with its original traceback."""
        self._q.join()
        self._raise_pending()

    def close(self, timeout: float = 60.0):
        """Drain, stop the worker, surface any stored error. Idempotent —
        a second close is a no-op (beyond re-raising a pending error).
        Raises :class:`TimeoutError` if the worker fails to exit within
        ``timeout`` seconds instead of silently abandoning the join."""
        if not self._closed:
            self._closed = True
            self._q.put(None)
            self._thread.join(timeout=timeout)
            if self._thread.is_alive():
                raise TimeoutError(
                    f"AsyncCheckpointer worker did not exit within {timeout}s; "
                    "a save may still be in flight"
                )
        self._raise_pending()
