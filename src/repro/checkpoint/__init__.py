from .codec import decode_tensor, encode_tensor  # noqa: F401
from .manager import AsyncCheckpointer, available_steps, latest_step, restore, save  # noqa: F401
