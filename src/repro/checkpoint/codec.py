"""Tensor (de)serialization through the cuSZ-Hi codec.

Two modes per tensor:
  * lossless: raw bytes + zstd (bit-exact; default for optimizer state and
    anything integer/small) — falls back to stdlib zlib when the optional
    ``zstandard`` package is absent, and records which codec was used in
    the manifest so restore dispatches correctly;
  * error-bounded: the paper's full pipeline (plan-driven ``predictor=
    "auto"`` interpolation + orchestrated ``pipeline="auto"`` lossless
    stack) on float tensors reshaped to a 2-D field — weights are not
    spatially smooth like simulation data, so both tuners pick the
    best-fit configuration per tensor; CR is reported honestly in the
    manifest.

Error-bounded tensors are written as *chunked container v3 frames*
(``mode="cuszhi3"``): the 2-D field is split along its leading axis into
~``_FRAME_TARGET_BYTES`` chunks and each chunk becomes an independently
decodable frame with its own plan + pipeline choice. With more than one
jax device the frames are encoded device-parallel
(:func:`repro.core.distributed.shard_compress`), where the default
``CompressorSpec(engine="auto")`` now keeps each shard's quantized codes
device-resident through the lossless stages
(:mod:`repro.core.lossless.engine`) — the sink receives ready-to-write
frame payloads and raw code streams never cross to host; either way
:func:`encode_tensor_to` streams frames into the sink as they are
produced, so the saver's fsync/writeback overlaps the encode of the next
frame instead of waiting for the whole tensor.

The pipeline name and the chosen ``PredictorPlan`` are recorded in the
tensor meta (the plan also lives in the container header, which is what
decode actually replays), so checkpoints written under an older default
(e.g. the previous hardcoded "tp" pipeline, the fixed cubic/md steps, or
the pre-chunking single-container ``mode="cuszhi"``) keep restoring after
a default change.
"""
from __future__ import annotations

import io
import os
import zlib

import numpy as np

try:  # optional dependency; zlib fallback keeps checkpoints working without it
    import zstandard
except ImportError:  # pragma: no cover - depends on the environment
    zstandard = None

from repro.core import Compressor, CompressorSpec
from repro.core import distributed as dist
from repro.core.lossless import portable_pipelines
from repro.core.retry import RetryingWriter

_ZSTD_LEVEL = 3
_ZLIB_LEVEL = 6
_EB_PIPELINE = "auto"  # orchestrated per-tensor pipeline selection
_LEGACY_EB_PIPELINE = "tp"  # checkpoints written before meta recorded the name
_FRAME_TARGET_BYTES = 4 << 20  # ~4 MiB of raw field per v3 frame


def _as_field(x: np.ndarray) -> np.ndarray:
    """Reshape an arbitrary tensor to >=2-D for the block predictor."""
    flat = x.reshape(-1)
    n = flat.size
    w = 1
    for cand in (4096, 2048, 1024, 512, 256, 128, 64):
        if n % cand == 0:
            w = cand
            break
    return flat.reshape(-1, w) if w > 1 else flat.reshape(1, -1)


def default_ckpt_spec(eb: float) -> str:
    """The canonical spec string the checkpoint codec compresses with at a
    given bound: plan-driven predictor, orchestrated pipeline, portable
    candidates only — a checkpoint must restore on machines without the
    optional codecs installed here (e.g. zstandard)."""
    cands = ":".join(portable_pipelines())
    return (f"lossy,rel,{eb:g},predictor=auto,pipeline={_EB_PIPELINE},"
            f"pipeline_candidates={cands}")


def _resolve_spec(eb: float, spec) -> CompressorSpec | None:
    """The error-bounded spec for this tensor, or ``None`` for lossless.

    Precedence: explicit ``spec=`` (spec string or CompressorSpec — also
    opts a tensor into error-bounded encoding on its own) > the
    ``REPRO_CKPT_SPEC`` env var (overrides *how* tensors already selected
    via ``eb > 0`` are compressed, never which) > the default built from
    ``eb``. Spec strings parse through ``CompressorSpec.from_string``."""
    if spec is not None:
        if isinstance(spec, str):
            spec = CompressorSpec.from_string(spec)
        return spec
    if eb <= 0:
        return None
    env = os.environ.get("REPRO_CKPT_SPEC")
    if env:
        return CompressorSpec.from_string(env)
    return CompressorSpec.from_string(default_ckpt_spec(eb))


def _n_frames(field: np.ndarray) -> int:
    return int(max(1, min(field.shape[0], -(-field.nbytes // _FRAME_TARGET_BYTES))))


class _CountingSink:
    """Counts bytes and folds a running CRC32 over everything written —
    the per-leaf integrity record ``restore(strict=False)`` checks before
    attempting a decode."""

    def __init__(self, f):
        self._f = f
        self.nbytes = 0
        self.crc32 = 0

    def write(self, b):
        self._f.write(b)
        self.nbytes += len(b)
        self.crc32 = zlib.crc32(b, self.crc32) & 0xFFFFFFFF

    def flush(self):
        if hasattr(self._f, "flush"):
            self._f.flush()


def encode_tensor_to(f, x: np.ndarray, *, eb: float = 0.0, spec=None, retry: bool = True,
                     compressd: str | None = None) -> dict:
    """Encode ``x`` into file-like ``f``; returns the manifest meta (with
    ``bytes`` and a whole-payload ``crc32``). eb = 0 -> lossless; eb > 0
    -> value-range-relative bound. ``spec`` (a canonical spec string or
    :class:`~repro.core.CompressorSpec`) selects the full error-bounded
    configuration instead — the ``REPRO_CKPT_SPEC`` env var does the same
    for every ``eb > 0`` tensor without touching call sites; the spec
    string used lands in the manifest meta.

    The error-bounded path streams v3 frames into ``f`` as each chunk's
    encode completes (see module docstring) — with per-frame sync markers,
    so a damaged leaf file salvages at O(damage) with exact chunk indices
    — and the lossless path writes one blob. ``retry=True`` (default)
    wraps ``f`` in :class:`repro.core.retry.RetryingWriter`: transient
    ``OSError`` from a flaky filesystem is retried with exponential
    backoff + jitter instead of killing the save; the retry count lands
    in the returned meta (``io_retries``) when nonzero.

    ``compressd`` (or the ``REPRO_COMPRESSD`` env var) routes the
    error-bounded encode through a :mod:`repro.launch.compressd` daemon at
    that address: checkpoints repeat the same tensor shapes every save, so
    the daemon's shared plan cache skips re-autotuning from the second
    save on. Daemon leaves are written as one single-container payload
    (``mode="cuszhi"``) — restore needs no daemon and uses the normal
    :func:`decode_tensor` path.
    """
    meta = {"shape": list(x.shape), "dtype": str(x.dtype)}
    rf = RetryingWriter(f) if retry else f
    sink = _CountingSink(rf)
    compressd = compressd or os.environ.get("REPRO_COMPRESSD") or None
    sp = _resolve_spec(eb, spec)
    if sp is not None and x.dtype in (np.float32, np.float64) and x.size >= 4096:
        spec_str = sp.to_string()
        meta_eb = sp.eb if eb <= 0 else eb
        field = _as_field(x.astype(np.float32))
        if compressd:
            from repro.launch.compressd import CompressdClient

            with CompressdClient(compressd, stream="checkpoint") as client:
                buf = client.compress(field, spec=spec_str)
                info = client.last_info or {}
            sink.write(buf)
            meta.update(mode="cuszhi", eb=meta_eb, field_shape=list(field.shape),
                        pipeline=sp.pipeline, predictor=sp.predictor, spec=spec_str,
                        bytes=sink.nbytes, crc32=sink.crc32,
                        compressd={"plan_cache": info.get("plan_cache"),
                                   "pipeline": info.get("pipeline")})
            if retry and rf.retries:
                meta["io_retries"] = rf.retries
            return meta
        comp = Compressor(sp)
        n_frames = _n_frames(field)
        import jax

        if jax.device_count() > 1 and field.shape[0] % jax.device_count() == 0:
            # device-parallel frames: one shard per device
            dist.shard_compress(field, compressor=comp, out=sink, sync=True)
            n_frames = jax.device_count()
        else:
            dist.chunk_compress(field, n_chunks=n_frames, compressor=comp, out=sink, sync=True)
        plan = comp.last_plan  # last frame's plan (full per-frame plans ride the container)
        meta.update(mode="cuszhi3", eb=meta_eb, field_shape=list(field.shape),
                    pipeline=sp.pipeline, predictor=sp.predictor, spec=spec_str,
                    n_frames=n_frames, bytes=sink.nbytes, crc32=sink.crc32,
                    plan=None if plan is None else plan.to_header())
        if retry and rf.retries:
            meta["io_retries"] = rf.retries
        return meta
    raw = np.ascontiguousarray(x).tobytes()
    if zstandard is not None:
        meta.update(mode="zstd")
        sink.write(zstandard.ZstdCompressor(level=_ZSTD_LEVEL).compress(raw))
    else:
        meta.update(mode="zlib")
        sink.write(zlib.compress(raw, _ZLIB_LEVEL))
    meta["bytes"] = sink.nbytes
    meta["crc32"] = sink.crc32
    if retry and rf.retries:
        meta["io_retries"] = rf.retries
    return meta


def encode_tensor(x: np.ndarray, *, eb: float = 0.0, spec=None) -> tuple[bytes, dict]:
    """In-memory :func:`encode_tensor_to`: returns ``(payload, meta)``."""
    bio = io.BytesIO()
    meta = encode_tensor_to(bio, x, eb=eb, spec=spec)
    return bio.getvalue(), meta


def decode_tensor(payload: bytes, meta: dict, *, device: bool = False) -> np.ndarray:
    """Inverse of :func:`encode_tensor`. ``device=True`` restores
    error-bounded tensors straight to a device array (the v3 frames decode
    through the engine's device twins, bit-identical to the host path);
    losslessly-stored tensors decode on host either way."""
    shape = tuple(meta["shape"])
    dtype = np.dtype(meta["dtype"])
    if meta["mode"] in ("cuszhi", "cuszhi3"):  # v3 frames decode through the same path
        pipeline = meta.get("pipeline", _LEGACY_EB_PIPELINE)
        comp = Compressor(CompressorSpec(eb=meta["eb"], pipeline=pipeline, autotune=False))
        # f64 tensors restore on host: jax's default x64-disabled mode
        # cannot hold the target dtype
        use_dev = device and dtype != np.float64
        field = comp.decompress(payload, out="device" if use_dev else "numpy")
        return field.reshape(-1)[: int(np.prod(shape))].reshape(shape).astype(dtype)
    if meta["mode"] == "zlib":
        raw = zlib.decompress(payload)
    else:
        if zstandard is None:
            raise ImportError(
                "checkpoint tensor was written with the optional 'zstandard' package; install it to restore"
            )
        raw = zstandard.ZstdDecompressor().decompress(payload)
    return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()
