"""Tensor (de)serialization through the cuSZ-Hi codec.

Two modes per tensor:
  * lossless: raw bytes + zstd (bit-exact; default for optimizer state and
    anything integer/small) — falls back to stdlib zlib when the optional
    ``zstandard`` package is absent, and records which codec was used in
    the manifest so restore dispatches correctly;
  * error-bounded: the paper's full pipeline (plan-driven ``predictor=
    "auto"`` interpolation + orchestrated ``pipeline="auto"`` lossless
    stack) on float tensors reshaped to a 2-D field — weights are not
    spatially smooth like simulation data, so both tuners pick the
    best-fit configuration per tensor; CR is reported honestly in the
    manifest.

The pipeline name and the chosen ``PredictorPlan`` are recorded in the
tensor meta (the plan also lives in the container header, which is what
decode actually replays), so checkpoints written under an older default
(e.g. the previous hardcoded "tp" pipeline, or the fixed cubic/md steps)
keep restoring after a default change.
"""
from __future__ import annotations

import zlib

import numpy as np

try:  # optional dependency; zlib fallback keeps checkpoints working without it
    import zstandard
except ImportError:  # pragma: no cover - depends on the environment
    zstandard = None

from repro.core import Compressor, CompressorSpec
from repro.core.lossless import portable_pipelines

_ZSTD_LEVEL = 3
_ZLIB_LEVEL = 6
_EB_PIPELINE = "auto"  # orchestrated per-tensor pipeline selection
_LEGACY_EB_PIPELINE = "tp"  # checkpoints written before meta recorded the name


def _as_field(x: np.ndarray) -> np.ndarray:
    """Reshape an arbitrary tensor to >=2-D for the block predictor."""
    flat = x.reshape(-1)
    n = flat.size
    w = 1
    for cand in (4096, 2048, 1024, 512, 256, 128, 64):
        if n % cand == 0:
            w = cand
            break
    return flat.reshape(-1, w) if w > 1 else flat.reshape(1, -1)


def encode_tensor(x: np.ndarray, *, eb: float = 0.0) -> tuple[bytes, dict]:
    """eb = 0 -> lossless; eb > 0 -> value-range-relative error bound."""
    meta = {"shape": list(x.shape), "dtype": str(x.dtype)}
    if eb > 0 and x.dtype in (np.float32, np.float64) and x.size >= 4096:
        # portable candidates only: a checkpoint must restore on machines
        # without the optional codecs installed here (e.g. zstandard)
        comp = Compressor(CompressorSpec(eb=eb, predictor="auto", pipeline=_EB_PIPELINE,
                                         pipeline_candidates=tuple(portable_pipelines())))
        field = _as_field(x.astype(np.float32))
        payload = comp.compress(field)
        plan = comp.last_plan  # same dict the container header carries, no re-parse
        meta.update(mode="cuszhi", eb=eb, field_shape=list(field.shape), pipeline=_EB_PIPELINE,
                    predictor="auto", plan=None if plan is None else plan.to_header())
        return payload, meta
    raw = np.ascontiguousarray(x).tobytes()
    if zstandard is not None:
        meta.update(mode="zstd")
        return zstandard.ZstdCompressor(level=_ZSTD_LEVEL).compress(raw), meta
    meta.update(mode="zlib")
    return zlib.compress(raw, _ZLIB_LEVEL), meta


def decode_tensor(payload: bytes, meta: dict) -> np.ndarray:
    shape = tuple(meta["shape"])
    dtype = np.dtype(meta["dtype"])
    if meta["mode"] == "cuszhi":
        pipeline = meta.get("pipeline", _LEGACY_EB_PIPELINE)
        comp = Compressor(CompressorSpec(eb=meta["eb"], pipeline=pipeline, autotune=False))
        field = comp.decompress(payload)
        return field.reshape(-1)[: int(np.prod(shape))].reshape(shape).astype(dtype)
    if meta["mode"] == "zlib":
        raw = zlib.decompress(payload)
    else:
        if zstandard is None:
            raise ImportError(
                "checkpoint tensor was written with the optional 'zstandard' package; install it to restore"
            )
        raw = zstandard.ZstdDecompressor().decompress(payload)
    return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()
