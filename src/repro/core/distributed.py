"""Device-parallel, streaming compression (container v3 producer).

``shard_compress`` splits a field along one axis into per-device chunks and
runs the lossy half of the compressor — block gather, interpolation
prediction (jax or Pallas backend), quantized-code emission — *on the
devices*, under :func:`repro.runtime.partitioning.shard_map`, and then —
new with the device encoding engine (:mod:`repro.core.lossless.engine`) —
keeps the per-shard quantized codes device-resident through block
scatter, level reorder, and the entropy-encoding pipeline. The raw uint8
code stream never crosses to host: what comes back per shard is the
*encoded* frame payload, the (tiny) anchor grid, and the outlier values
(gathered per-shard from the device-resident padded field, never the
field itself), so the ``FrameWriter`` receives ready-to-write frames.
The PR 2/3 orchestration still runs per chunk — each chunk keeps its own
``PredictorPlan`` and lossless-pipeline choice (the orchestrator's
histogram rides the device engine by default) — and the result is framed
as container v3 (:mod:`repro.core.frames`): one complete v1/v2 container
per chunk, independently decodable, CRC-guarded.
``CompressorSpec(engine="numpy")`` opts back into the host reference
encoders (identical bytes either way — the engine's bit-identity
contract).

Bit-identity contract: every frame equals ``Compressor.compress`` of the
same chunk, byte for byte. The per-chunk error bound (rel mode), the
tuning sample (gathered shard-side at exactly the indices the in-process
tuner would draw), the predictor arithmetic, and the container packing all
replicate the single-host path, so ``shard_compress(x)[i]`` ==
``compress(x[i*k:(i+1)*k])`` and any mix of sharded writers and
single-host readers (or vice versa) round-trips.

``chunk_compress`` is the host-sequential twin (same v3 output, no mesh
needed) used as the fallback — non-divisible axes, 1-device hosts,
predictors without a device path — and as the checkpoint codec's
streaming producer. ``shard_decompress`` reads any v3 chunk stream,
optionally with a thread pool (frames decode independently, so decode
parallelism is embarrassing).
"""
from __future__ import annotations

import io

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from . import blocks as blk
from . import frames
from .autotune import levels_for_stride, legacy_sample_indices, plan_sample_indices
from . import compressor as _compressor_mod
from .compressor import Compressor, CompressorSpec, _sections_pack
from .predictor import compress_blocks
from .stencils import build_steps

_AXIS = "shards"


def default_mesh(devices=None) -> Mesh:
    """1-D compression mesh over the host's devices (axis ``"shards"``)."""
    devices = jax.devices() if devices is None else list(devices)
    return Mesh(np.array(devices), (_AXIS,))


def _resolve_compressor(spec, compressor, kw) -> Compressor:
    if compressor is not None:
        return compressor
    return Compressor(spec, **kw) if spec is not None or kw else Compressor(CompressorSpec())


def _chunk_header(x_shape, axis: int, sizes, spec: CompressorSpec) -> dict:
    return {
        "kind": "chunks",
        "version": 3,
        "shape": list(x_shape),
        "axis": int(axis),
        "chunk_sizes": [int(s) for s in sizes],
        "eb_mode": spec.eb_mode,
    }


# ------------------------------------------------------------- device helpers
def _pad_field_batch_jnp(xb, stride: int):
    """jnp twin of blocks.pad_field_batch (edge-replicate to the block grid)."""
    tgt = blk.padded_shape(xb.shape[1:], stride)
    pads = [(0, 0)] + [(0, t - s) for s, t in zip(xb.shape[1:], tgt)]
    if all(p == (0, 0) for p in pads[1:]):
        return xb
    return jnp.pad(xb, pads, mode="edge")


# moved to blocks.py so the decompress device tail shares it; the old name
# stays importable for existing callers
_gather_blocks_jnp = blk.gather_blocks_batch_jnp


def _fold_chunk(chunk):
    """jnp twin of Compressor._spatial_view: fold to (batch, spatial<=3)."""
    nd = min(chunk.ndim, 3)
    spatial = chunk.shape[chunk.ndim - nd :]
    batch = int(np.prod(chunk.shape[: chunk.ndim - nd], dtype=np.int64)) if chunk.ndim > nd else 1
    return chunk.reshape((batch,) + spatial), spatial


def _predict_codes(blocks, twoeb, steps, stride: int, ndim: int, backend: str):
    """Fused predict+quantize on the device shard (jax or Pallas kernel)."""
    if backend == "pallas" and ndim == 3:
        from repro.kernels.interp3d.interp3d import LANES, interp3d_compress

        nbk = blocks.shape[0]
        lane_pad = (-nbk) % LANES
        if lane_pad:
            blocks = jnp.concatenate([blocks, jnp.zeros((lane_pad,) + blocks.shape[1:], blocks.dtype)], 0)
        bt = jnp.moveaxis(blocks, 0, -1)  # (B,B,B,nb') — block axis on lanes
        interpret = jax.default_backend() != "tpu"
        codes, _, _ = interp3d_compress(bt, twoeb, steps, stride, interpret)
        return jnp.moveaxis(codes, -1, 0)[:nbk]
    codes, _, _ = compress_blocks(blocks, twoeb, steps, stride)
    return codes


def _shard_slices(arr) -> dict:
    """Map chunk index (along dim 0 of a P('shards')-sharded array) ->
    single-device shard data, deduping replicated placements."""
    out = {}
    for s in arr.addressable_shards:
        start = s.index[0].start or 0
        out.setdefault(start, s.data)
    return out


def _gather_flat(dev_arr, oi: np.ndarray) -> np.ndarray:
    """Pull only ``oi`` positions of a device-resident array to host."""
    if oi.size == 0:
        return np.zeros(0, np.float32)
    vals = jnp.asarray(dev_arr).reshape(-1)[jnp.asarray(oi)]
    return np.asarray(vals, np.float32)


# ------------------------------------------------------------ host fallback
def chunk_compress(x, *, axis: int = 0, n_chunks: int | None = None,
                   spec: CompressorSpec | None = None, compressor: Compressor | None = None,
                   out=None, sync: bool = False, **kw) -> bytes | int:
    """Host-sequential v3 producer: split along ``axis``, one container
    frame per chunk (``Compressor.compress`` of the chunk, bit for bit).

    ``out``: optional file-like sink — frames are written (and flushed) as
    each chunk's encode completes, so a slow sink overlaps the next
    chunk's encode; returns the frame count then. Without ``out`` returns
    the packed v3 bytes. ``sync=True`` writes per-frame sync markers +
    sequence numbers (O(damage) resync, exact surviving-frame indices —
    see :mod:`repro.core.frames`); the default layout is unchanged. If the
    encode of a chunk fails mid-stream, the writer *aborts* (no trailer),
    so the partial stream reads as truncated instead of complete.
    """
    comp = _resolve_compressor(spec, compressor, kw)
    x = np.asarray(x)
    n = x.shape[axis]
    n_chunks = max(1, min(n, n_chunks if n_chunks is not None else 1))
    bounds = np.linspace(0, n, n_chunks + 1).astype(np.int64)
    sizes = np.diff(bounds)
    sink = out if out is not None else io.BytesIO()
    hold, comp._telemetry_hold = comp._telemetry_hold, True
    if not hold:  # a holding caller (shard fallback) keeps its records
        comp.last_telemetry = None
    try:
        with frames.FrameWriter(sink, _chunk_header(x.shape, axis, sizes, comp.spec), sync=sync) as w:
            sl = [slice(None)] * x.ndim
            for lo, hi in zip(bounds[:-1], bounds[1:]):
                sl[axis] = slice(int(lo), int(hi))
                w.write_frame(comp.compress(x[tuple(sl)]))
        nf = w.close()
    finally:
        comp._telemetry_hold = hold
    return nf if out is not None else sink.getvalue()


# ------------------------------------------------------------ sharded path
def shard_compress(x, mesh: Mesh | None = None, *, axis: int = 0,
                   spec: CompressorSpec | None = None, compressor: Compressor | None = None,
                   out=None, sync: bool = False, **kw):
    """Device-parallel v3 producer (see module docstring).

    ``x``: array (numpy or jax, possibly already device-sharded) or a
    pytree of arrays — a pytree maps to a same-structure pytree of v3
    containers. ``mesh``: a 1-D mesh; defaults to all local devices.
    Chunks = equal splits of ``x.shape[axis]`` across the mesh. Falls back
    to :func:`chunk_compress` (identical container format) when the axis
    doesn't split evenly, the mesh is a single device, or the spec's
    predictor has no device path — and, new with the resilience layer,
    when the device passes themselves *fail* (a lowering error, a dead
    mesh) before any frame was emitted: the host path re-runs the whole
    field and the fallback is recorded in the compressor's
    ``last_telemetry``, so a transient accelerator fault degrades
    throughput instead of killing the save. ``out``: optional file-like
    sink, frames stream to it as encoded (returns the frame count).
    ``sync=True`` adds per-frame sync markers (see
    :mod:`repro.core.frames`).
    """
    if not isinstance(x, (np.ndarray, jnp.ndarray)):
        if out is not None:
            raise ValueError("out= takes a single container; it cannot hold a pytree of leaves — "
                             "stream each leaf separately")

        def one(leaf):
            arr = np.asarray(leaf)
            if arr.ndim == 0:  # scalar leaves (step counters, ...) are not fields
                raise TypeError(
                    f"shard_compress pytree leaves must be arrays with ndim >= 1, got "
                    f"{type(leaf).__name__} shaped {arr.shape}; filter scalar leaves out first"
                )
            return shard_compress(arr, mesh, axis=axis, spec=spec, compressor=compressor,
                                  sync=sync, **kw)

        return jax.tree.map(one, x)
    comp = _resolve_compressor(spec, compressor, kw)
    sp = comp.spec
    mesh = mesh if mesh is not None else default_mesh()
    if len(mesh.axis_names) != 1:
        raise ValueError(f"shard_compress needs a 1-D mesh, got axes {mesh.axis_names}")
    ndev = int(np.prod(mesh.devices.shape))
    n = int(x.shape[axis])
    if ndev == 1 or n % ndev != 0 or sp.predictor not in ("interp", "auto"):
        return chunk_compress(np.asarray(x), axis=axis, n_chunks=min(n, max(ndev, 1)),
                              compressor=comp, out=out, sync=sync)
    k = n // ndev
    chunk_shape = tuple(k if d == axis else s for d, s in enumerate(x.shape))
    header = _chunk_header(x.shape, axis, [k] * ndev, sp)
    hold, comp._telemetry_hold = comp._telemetry_hold, True
    if not hold:
        comp.last_telemetry = None
    try:
        # _shard_compress_frames is a generator: the device passes run up
        # front, but each chunk's host tail (scatter/orchestrate/encode)
        # yields its frame as soon as it is packed, so sink writeback
        # overlaps the next chunk's encode. Pulling the first frame before
        # opening the writer keeps the engine-failure fallback clean: if
        # the device passes die, nothing was written yet and the whole
        # field replays through the host path (identical container).
        gen = _shard_compress_frames(x, mesh, axis, ndev, k, chunk_shape, comp)
        try:
            first = next(gen, None)
        except Exception as e:
            comp._record_fallback("shard", "shard_map", "chunk_compress", e)
            return chunk_compress(np.asarray(x), axis=axis, n_chunks=ndev,
                                  compressor=comp, out=out, sync=sync)
        sink = out if out is not None else io.BytesIO()
        with frames.FrameWriter(sink, header, sync=sync) as w:
            if first is not None:
                w.write_frame(first)
                for fr in gen:
                    w.write_frame(fr)
        nf = w.close()
    finally:
        comp._telemetry_hold = hold
    return nf if out is not None else sink.getvalue()


def _shard_compress_frames(x, mesh, axis, ndev, k, chunk_shape, comp):
    sp = comp.spec
    aname = mesh.axis_names[0]
    spec_sharded = P(*(aname if d == axis else None for d in range(len(chunk_shape))))
    sharding = NamedSharding(mesh, spec_sharded)
    xd = jax.device_put(jnp.asarray(x, jnp.float32), sharding)
    scalar_spec = P(aname)
    scalar_sharding = NamedSharding(mesh, scalar_spec)
    from repro.runtime.partitioning import shard_map

    # static per-chunk geometry (chunks are uniform)
    nd = min(len(chunk_shape), 3)
    spatial = chunk_shape[len(chunk_shape) - nd :]
    cb = int(np.prod(chunk_shape[: len(chunk_shape) - nd], dtype=np.int64)) if len(chunk_shape) > nd else 1
    padded_shapes = blk.padded_shape(spatial, blk.ANCHOR_STRIDE)
    nblocks = cb * int(np.prod(blk.block_grid(padded_shapes, blk.ANCHOR_STRIDE)))
    tune = sp.predictor == "auto" or (sp.predictor == "interp" and sp.autotune)
    sample_idx = (plan_sample_indices if sp.predictor == "auto" else legacy_sample_indices)(nblocks)

    # ---- pass A: per-chunk range (rel eb) + shard-side tuning sample
    def body_a(chunk):
        xb, _ = _fold_chunk(chunk)
        mn = jnp.min(xb).reshape(1) if xb.size else jnp.zeros(1)
        mx = jnp.max(xb).reshape(1) if xb.size else jnp.zeros(1)
        padded = _pad_field_batch_jnp(xb, blk.ANCHOR_STRIDE)
        blocks = _gather_blocks_jnp(padded, blk.ANCHOR_STRIDE)
        sample = blocks[jnp.asarray(sample_idx)] if tune else jnp.zeros((1,) + blocks.shape[1:])
        return mn, mx, sample

    fa = shard_map(body_a, mesh, in_specs=(spec_sharded,), out_specs=(scalar_spec,) * 3)
    mn, mx, samples = jax.jit(fa)(xd)
    mn, mx = np.asarray(mn), np.asarray(mx)
    # non-finite ingest: NaN/Inf anywhere in a chunk poisons its min/max
    # (jnp reductions propagate), so this one check covers the whole
    # field. Raising before the first yield routes the caller onto
    # chunk_compress, whose per-chunk Compressor.compress runs the
    # nfsafe canonicalization (bitmap + fill) — recorded as a shard
    # fallback in last_telemetry, never silent.
    if not (np.isfinite(mn).all() and np.isfinite(mx).all()):
        raise ValueError(
            "non-finite values (NaN/Inf) in the field; the device shard path has no "
            "nfsafe pass — falling back to chunk_compress for canonicalized ingest")
    samples = np.asarray(samples)
    ns = sample_idx.size if tune else 1

    # ---- per-chunk eb + tuning (host; the sample is all it needs)
    eb_abs = np.empty(ndev, np.float64)
    tuned = []
    for i in range(ndev):
        if sp.eb_mode == "abs":
            eb_abs[i] = float(sp.eb)
        else:
            # f64 subtraction: a float32 mx-mn of an extreme-range chunk
            # overflows to inf and poisons the bound
            eb_abs[i] = float(sp.eb) * (float(mx[i]) - float(mn[i]))
        if eb_abs[i] == 0.0:
            tuned.append(None)  # constant chunk: framed via the const path
            continue
        if tune:
            chunk_sample = samples[i * ns : (i + 1) * ns]
            tuned.append(comp._tune_interp(chunk_sample, eb_abs[i], cb, padded_shapes,
                                           presampled_of=nblocks))
        else:
            levels = levels_for_stride(sp.anchor_stride)
            tuned.append((sp.anchor_stride, tuple(sp.splines[: len(levels)]), tuple(sp.schemes[: len(levels)])))

    # ---- pass B: predict+quantize per plan group (static step tables).
    # Step tables are static to the trace, so shards whose tuners picked
    # different plans cannot share one shard_map call: each distinct plan
    # re-runs the pass over the whole mesh and keeps only its members'
    # outputs. Homogeneous data (the common case) is a single pass; N
    # heterogeneous plans cost N passes — acceptable for now, revisit with
    # stacked per-shard step operands if mixed-plan fields become hot.
    groups: dict[tuple, list[int]] = {}
    for i, t in enumerate(tuned):
        if t is not None:
            groups.setdefault(t, []).append(i)
    use_dev = sp.engine != "numpy"  # auto/device: codes never visit host
    codes_np = None if use_dev else np.empty((ndev * nblocks,) + (blk.BLOCK,) * nd, np.uint8)
    codes_dev: dict[int, object] = {}
    anc_np: dict[int, np.ndarray] = {}
    padded_shards: dict[int, object] = {}
    for (stride, splines, schemes), members in groups.items():
        steps = build_steps(nd, blk.BLOCK, levels_for_stride(stride), splines, schemes)
        twoeb = np.ones(ndev, np.float32)
        for i in members:
            twoeb[i] = np.float32(2.0 * eb_abs[i])

        def body_b(chunk, t2):
            xb, _ = _fold_chunk(chunk)
            padded = _pad_field_batch_jnp(xb, blk.ANCHOR_STRIDE)
            blocks = _gather_blocks_jnp(padded, blk.ANCHOR_STRIDE)
            codes = _predict_codes(blocks, t2[0], steps, stride, nd, sp.backend)
            anc_sl = (slice(None),) + tuple(slice(None, None, stride) for _ in range(nd))
            return codes.astype(jnp.uint8), padded[anc_sl], padded

        fb = shard_map(body_b, mesh, in_specs=(spec_sharded, scalar_spec),
                       out_specs=(scalar_spec,) * 3)
        td = jax.device_put(jnp.asarray(twoeb), scalar_sharding)
        codes_g, anc_g, padded_g = jax.jit(fb)(xd, td)
        anc_host = np.asarray(anc_g)
        pslices = _shard_slices(padded_g)
        per_anc = anc_host.shape[0] // ndev
        if use_dev:
            cslices = _shard_slices(codes_g)  # per-shard device arrays
        else:
            codes_host = np.asarray(codes_g)
        for i in members:
            if use_dev:
                codes_dev[i] = cslices.get(i * nblocks)
            else:
                codes_np[i * nblocks : (i + 1) * nblocks] = codes_host[i * nblocks : (i + 1) * nblocks]
            anc_np[i] = anc_host[i * per_anc : (i + 1) * per_anc]
            padded_shards[i] = pslices.get(i * cb)

    # ---- per-chunk tail: scatter + level reorder + entropy encode run on
    # the shard's device under engine="auto"/"device" (the raw uint8 code
    # stream never crosses to host — only the encoded frame payload does,
    # via _pack_interp); engine="numpy" replays the host reference path.
    # Frames are yielded one at a time so the caller can write frame i
    # while frame i+1 encodes.
    for i in range(ndev):
        base_hdr = {
            "shape": list(chunk_shape),
            "predictor": sp.predictor,
            "eb_abs": eb_abs[i],
            "anchor_stride": sp.anchor_stride,
        }
        if tuned[i] is None:  # constant chunk — value fetched from the shard
            yield _sections_pack(dict(base_hdr, mode="const"),
                                 [np.float32(_first_value(xd, i, k, axis)).tobytes()])
            continue
        stride, splines, schemes = tuned[i]
        if use_dev:
            cgrid = blk.scatter_blocks_batch_jnp(jnp.asarray(codes_dev[i]), cb,
                                                 padded_shapes, blk.ANCHOR_STRIDE)
            if _compressor_mod._CODE_FAULT is not None:
                # test-only encoder-fault hook (see testing.faults): worth a
                # device round trip only when armed
                cgrid = jnp.asarray(comp._maybe_fault_codes(np.asarray(cgrid)))
            oi = np.asarray(jnp.flatnonzero(cgrid.reshape(-1) == 0)).astype(np.int64)
        else:
            cgrid = blk.scatter_blocks_batch(codes_np[i * nblocks : (i + 1) * nblocks],
                                             cb, padded_shapes, blk.ANCHOR_STRIDE)
            cgrid = comp._maybe_fault_codes(cgrid)
            oi = np.flatnonzero(cgrid.reshape(-1) == 0).astype(np.int64)  # code 0 == outlier
        ov = _gather_flat(padded_shards[i], oi)
        fr = comp._pack_interp(base_hdr, cgrid=cgrid, anc=anc_np[i], oi=oi, ov=ov,
                               stride=stride, splines=splines, schemes=schemes)
        if sp.verify != "off":
            # the bound check the host path runs inside compress(): decode
            # the fresh frame and verify against this chunk's bound; a
            # violation repairs through the host re-encode ladder (frame
            # stays a valid standalone container) or raises the typed
            # BoundViolationError. The chunk slice crosses to host only
            # under verify — engine residency is unchanged otherwise.
            sl = tuple(slice(i * k, (i + 1) * k) if d == axis else slice(None)
                       for d in range(xd.ndim))
            chunk_host = np.ascontiguousarray(np.asarray(xd[sl]), np.float32)
            fr = comp._verify_repair(chunk_host, fr, bound=float(eb_abs[i]), rel=False)
        yield fr


def _first_value(xd, i: int, k: int, axis: int) -> float:
    """First element of chunk ``i`` (the const-mode fill), fetched without
    pulling the chunk to host."""
    if any(d == 0 for d in xd.shape):
        return 0.0
    idx = tuple(i * k if d == axis else 0 for d in range(xd.ndim))
    return float(jnp.asarray(xd[idx]))


# --------------------------------------------------------------- decompress
def _decode_workers() -> int:
    """Frame-decode thread count: REPRO_DECODE_WORKERS env override, else 1.

    The default stays sequential (thread fan-out is a policy the caller or
    the environment opts into — CI pins the env for determinism); any
    positive value sizes the per-call thread pool in shard_decompress.
    """
    import os

    try:
        env = int(os.environ.get("REPRO_DECODE_WORKERS", "0"))
    except ValueError:
        env = 0
    return env if env > 0 else 1


def shard_decompress(buf, frames_sel=None, *, workers: int | None = None,
                     on_error: str = "raise", fill_value: float = 0.0,
                     compressor: Compressor | None = None, out: str = "numpy"):
    """Decode a v3 chunk stream; ``frames_sel`` selects a subset (any order).

    ``workers > 1`` decodes frames on a thread pool — frames are
    independent containers, so decode parallelism needs no coordination;
    with ``out="device"`` each worker decodes its frame straight onto the
    device (host I/O and device decode overlap across frames) and the
    chunks concatenate device-side. ``workers=None`` reads the
    ``REPRO_DECODE_WORKERS`` env override (default 1, sequential).

    ``on_error="skip"``/``"fill"``: salvage decode of damaged streams,
    same semantics as :meth:`Compressor.decompress` — damaged chunks are
    dropped or filled, intact chunks decode normally. Pass your own
    ``compressor`` to read the damage mask back from its ``last_damage``.
    """
    comp = compressor if compressor is not None else Compressor(CompressorSpec())
    if workers is None:
        workers = _decode_workers()
    if workers <= 1:
        return comp.decompress(buf, frames=frames_sel, on_error=on_error,
                               fill_value=fill_value, out=out)
    comp.last_damage = None
    header, payloads, report = comp._salvage_payloads(buf, on_error)
    if header.get("kind") != "chunks":
        raise ValueError(f"v3 container kind {header.get('kind')!r} is not a compressor chunk stream")
    n_chunks = len(header["chunk_sizes"])
    idx = list(range(n_chunks)) if frames_sel is None else [int(i) for i in frames_sel]
    if not idx:
        raise ValueError("frames_sel selected no frames; pass at least one index (or None for all)")
    from concurrent.futures import ThreadPoolExecutor

    from .errors import ContainerError

    # per-call telemetry is thread-local: each worker's decompress records
    # into its own thread state, so worker-side fallbacks are collected
    # explicitly and merged into the caller's record after the join
    # (list.append/extend are atomic under the GIL — no lock needed)
    worker_fallbacks: list = []

    def _one(i: int):
        p = payloads.get(i)
        if p is None:
            if on_error == "raise":
                raise ContainerError(f"frame {i} missing from v3 container")
            return None
        try:
            return comp.decompress(p, out=out)
        except Exception as e:
            if on_error == "raise":
                raise
            report.add("decode", -1, index=i, detail=repr(e))
            report.frames_damaged += 1
            return None
        finally:
            tel = comp.last_telemetry
            if tel and tel.get("fallbacks"):
                worker_fallbacks.extend(tel["fallbacks"])

    hold, comp._telemetry_hold = comp._telemetry_hold, True
    if not hold:
        comp.last_telemetry = None
    try:
        with ThreadPoolExecutor(max_workers=workers) as ex:
            raw = list(ex.map(_one, idx))
    finally:
        comp._telemetry_hold = hold
    if worker_fallbacks:
        comp._telemetry()["fallbacks"].extend(worker_fallbacks)
    mask = [p is not None for p in raw]
    parts = []
    for i, p in zip(idx, raw):
        if p is not None:
            parts.append(p)
        elif on_error == "fill":
            parts.append(np.full(Compressor._chunk_shape(header, i), np.float32(fill_value), np.float32))
    if not report.ok:
        comp.last_damage = {"report": report, "chunks_ok": mask, "on_error": on_error}
    if not parts:
        raise ContainerError(f"no decodable frames in damaged v3 container ({report.summary()})")
    axis = int(header.get("axis", 0))
    if len(parts) == 1:
        result = parts[0]
    else:
        result = jnp.concatenate(parts, axis=axis) if out == "device" else np.concatenate(parts, axis=axis)
    if out == "device" and isinstance(result, np.ndarray):
        result = jnp.asarray(result)
    return result
