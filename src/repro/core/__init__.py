"""repro.core — cuSZ-Hi: synergistic lossy-lossless compression in JAX."""
from .autotune import PredictorPlan, autotune_plan, plan_signature, stats_bucket  # noqa: F401
from .distributed import chunk_compress, default_mesh, shard_compress, shard_decompress  # noqa: F401
from .errors import (  # noqa: F401
    BoundViolationError,
    CheckpointDamageError,
    ContainerError,
    DamageReport,
    DeadlineExceededError,
    FrameCRCError,
    FrameSyncError,
    RequestTooLargeError,
    ServiceError,
    ServiceOverloadedError,
    ServiceProtocolError,
    SpecError,
    TruncatedContainerError,
)
from .plancache import PlanCache  # noqa: F401
from .frames import FrameReader, FrameWriter, scan_frames  # noqa: F401
from .retry import RetryPolicy, RetryingWriter, retry_call  # noqa: F401
from .compressor import (  # noqa: F401
    Compressor,
    CompressorSpec,
    cusz_hi_auto,
    cusz_hi_autoplan,
    cusz_hi_cr,
    cusz_hi_crz,
    cusz_hi_tp,
    cusz_i,
    cusz_l,
    cuszp2_like,
    fzgpu_like,
)
from .metrics import (  # noqa: F401
    bit_rate,
    compression_ratio,
    max_abs_err,
    max_rel_err,
    nonfinite_count,
    psnr,
    quality_report,
    spectral_error,
    ssim,
)
