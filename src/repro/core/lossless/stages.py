"""Lossless stage registry — the extension point of the encoding layer.

A *stage* is one lossless transform in a pipeline (Huffman, run-reduction,
bit-plane shuffle, ...). Each stage is self-describing:

* ``encode(data) -> (payload, header)`` / ``decode(payload, header)`` —
  the transform itself over a uint8 stream; ``header`` is a small dict of
  scalars the decoder needs.
* ``pack_header`` / ``unpack_header`` — a compact binary serialization of
  that dict, embedded in the pipeline stream (repro.core.lossless.pipelines)
  so stage metadata costs a handful of bytes, not JSON. Stages that don't
  provide packers fall back to JSON bytes.
* ``estimate(stats) -> float`` — a cheap cost hook: predicted output bytes
  per input byte given sampled stream statistics (see
  repro.core.lossless.orchestrate.stream_stats). The orchestrator uses
  these to rank candidate pipelines before trial-encoding.
* ``encode_device(data) -> (payload, header)`` — optional device twin of
  ``encode`` taking a ``jax.Array`` uint8 stream and returning a *device*
  uint8 payload, byte-identical to ``encode``'s (the engine contract, see
  repro.core.lossless.engine). Stages without one fall back to the numpy
  path when a pipeline runs device-resident.
* ``decode_device(payload, header) -> jax.Array`` — optional device twin
  of ``decode`` under the same bit-identity contract, accepting host
  bytes-like or device uint8 payloads and returning a *device* uint8
  stream. Stages without one pull the stream to host when a pipeline
  decodes device-resident.

Third-party stages register with :func:`register_stage` and are immediately
usable in :func:`repro.core.lossless.pipelines.register_pipeline` — core
never needs to know their names. Name collisions raise at registration
(pass ``overwrite=True`` to replace deliberately).
"""
from __future__ import annotations

import dataclasses
import json
import struct
from typing import Callable

import numpy as np

from . import bitshuffle as _bit
from . import huffman as _hf
from . import rre as _rre
from . import tcms as _tcms


@dataclasses.dataclass(frozen=True)
class Stage:
    name: str
    encode: Callable[[np.ndarray], tuple]
    decode: Callable[[bytes, dict], np.ndarray]
    estimate: Callable[[dict], float]
    pack_header: Callable[[dict], bytes]
    unpack_header: Callable[[bytes], dict]
    # portable: decoding never needs an optional dependency. Durable artifacts
    # (checkpoints, relayed gradients) restrict auto-selection to portable
    # pipelines so they stay restorable on any machine.
    portable: bool = True
    # device twins (bit-identity contract); None = host-only direction
    encode_device: Callable | None = None
    decode_device: Callable | None = None


_REGISTRY: dict[str, Stage] = {}


def _json_pack(hdr: dict) -> bytes:
    return json.dumps(hdr).encode()


def _json_unpack(raw: bytes) -> dict:
    return json.loads(raw.decode())


def register_stage(
    name: str,
    encode: Callable,
    decode: Callable,
    *,
    estimate: Callable[[dict], float] | None = None,
    pack_header: Callable[[dict], bytes] | None = None,
    unpack_header: Callable[[bytes], dict] | None = None,
    portable: bool = True,
    encode_device: Callable | None = None,
    decode_device: Callable | None = None,
    overwrite: bool = False,
) -> Stage:
    """Register a lossless stage under ``name``.

    Raises ``ValueError`` on collision unless ``overwrite=True``, listing
    the registered names so typos fail loudly at registration time.
    """
    if name in _REGISTRY and not overwrite:
        raise ValueError(
            f"stage {name!r} is already registered "
            f"(registered stages: {', '.join(sorted(_REGISTRY))}); "
            "pass overwrite=True to replace it"
        )
    stage = Stage(
        name=name,
        encode=encode,
        decode=decode,
        estimate=estimate or (lambda stats: 1.0),
        pack_header=pack_header or _json_pack,
        unpack_header=unpack_header or _json_unpack,
        portable=portable,
        encode_device=encode_device,
        decode_device=decode_device,
    )
    _REGISTRY[name] = stage
    return stage


def get_stage(name: str) -> Stage:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown lossless stage {name!r}; "
            f"registered stages: {', '.join(sorted(_REGISTRY))}"
        ) from None


def registered_stages() -> dict[str, Stage]:
    return dict(_REGISTRY)


# ------------------------------------------------------------ built-in stages
# Binary header packers: each built-in stage's decode metadata is a few
# fixed-width integers, so headers pack to <= 17 bytes.

def _pack_hf(h):
    # versioned: the bare 8-byte form predates the per-chunk byte-offset
    # table ("offs", see huffman.offset_table) and still decodes — streams
    # without it just lose the device decoder's parallel chunk entry points.
    offs = h.get("offs")
    if offs is None:
        return struct.pack("<Q", h["n"])
    return struct.pack("<QB", h["n"], 1) + offs


def _unpack_hf(raw):
    if len(raw) == 8:
        return {"n": struct.unpack_from("<Q", raw)[0]}
    n, ver = struct.unpack_from("<QB", raw)
    out = {"n": n}
    if ver == 1:
        out["offs"] = bytes(raw[9:])
    return out


def _pack_rre(h):
    return struct.pack("<QQB", h["n"], h["nsym"], h["k"])


def _unpack_rre(raw):
    n, nsym, k = struct.unpack_from("<QQB", raw)
    return {"n": n, "nsym": nsym, "k": k}


def _pack_tcms(h):
    return struct.pack("<QB", h["n"], h["k"])


def _unpack_tcms(raw):
    n, k = struct.unpack_from("<QB", raw)
    return {"n": n, "k": k}


def _pack_bit(h):
    return struct.pack("<QI", h["n"], h["block"])


def _unpack_bit(raw):
    n, block = struct.unpack_from("<QI", raw)
    return {"n": n, "block": block}


def _pack_zstd(h):
    return struct.pack("<B", 1 if h.get("c", "zstd") == "zlib" else 0)


def _unpack_zstd(raw):
    return {"c": "zlib" if raw[0] else "zstd"}


# Cost hooks: predicted output fraction (bytes out per byte in) from the
# sampled stats dict {n, entropy, zero_frac, run_frac, outlier_frac}. These
# are deliberately crude — they ignore how earlier stages reshape the stream
# — because the orchestrator refines the ranking with a trial encode; their
# job is a cheap, monotone-ish pre-score.

def _est_hf(s):
    n = max(int(s.get("n", 1)), 1)
    # 256B lens + per chunk: 2B payload size + 4B header byte-offset entry
    table = (256.0 + 6.0 * (n // _hf.CHUNK + 1)) / n
    return min(1.0, s["entropy"] / 8.0 + table)


def _est_rre(k):
    def est(s):
        kept = 1.0 - float(s["run_frac"]) ** k
        return min(1.0, kept + 1.0 / (8.0 * k))

    return est


def _est_rze(k):
    def est(s):
        kept = 1.0 - float(s["zero_frac"]) ** k
        return min(1.0, kept + 1.0 / (8.0 * k))

    return est


def _est_unit(s):
    return 1.0  # bijective reshuffles (tcms, bit1) pay off downstream


def _est_zstd(s):
    return max(0.02, 0.85 * s["entropy"] / 8.0)


def _zstd_encode(data: np.ndarray):
    # zstandard is an optional dependency: fall back to stdlib zlib and
    # record the codec actually used so decode dispatches correctly
    try:
        import zstandard

        return zstandard.ZstdCompressor(level=6).compress(data.tobytes()), {"c": "zstd"}
    except ImportError:
        import zlib

        return zlib.compress(data.tobytes(), 6), {"c": "zlib"}


def _zstd_decode(payload: bytes, header: dict) -> np.ndarray:
    if header.get("c", "zstd") == "zlib":
        import zlib

        return np.frombuffer(zlib.decompress(payload), np.uint8)
    try:
        import zstandard
    except ImportError as e:
        raise ImportError(
            "this stream was compressed with the optional 'zstandard' package; install it to decode"
        ) from e
    return np.frombuffer(zstandard.ZstdDecompressor().decompress(payload), np.uint8)


# Device twins resolve the engine lazily: repro.core.lossless.engine pulls
# in jax, which host-only consumers of this module never need.

def _dev(fn_name: str, **fixed):
    def call(data, _fn=fn_name, _fixed=fixed):
        from . import engine

        return getattr(engine, _fn)(data, **_fixed)

    return call


def _devd(fn_name: str):
    # decode twins take a uniform (payload, header) signature — any stage
    # parameter (k, block) already rides in the header
    def call(payload, header, _fn=fn_name):
        from . import engine

        return getattr(engine, _fn)(payload, header)

    return call


def _register_builtins() -> None:
    register_stage("hf", _hf.encode, _hf.decode, estimate=_est_hf,
                   pack_header=_pack_hf, unpack_header=_unpack_hf,
                   encode_device=_dev("hf_encode_device"),
                   decode_device=_devd("hf_decode_device"))
    register_stage("bit1", _bit.bitshuffle_encode, _bit.bitshuffle_decode,
                   estimate=_est_unit, pack_header=_pack_bit, unpack_header=_unpack_bit,
                   encode_device=_dev("bit1_encode_device"),
                   decode_device=_devd("bit1_decode_device"))
    # not portable: when zstandard is installed at encode time, decoding the
    # stream needs it too (the zlib fallback only engages when it's absent);
    # also host-only — no device twins
    register_stage("zstd", _zstd_encode, _zstd_decode, estimate=_est_zstd,
                   pack_header=_pack_zstd, unpack_header=_unpack_zstd, portable=False)
    for k in (1, 2, 4, 8):
        register_stage(f"rre{k}", (lambda d, k=k: _rre.rre_encode(d, k)), _rre.rre_decode,
                       estimate=_est_rre(k), pack_header=_pack_rre, unpack_header=_unpack_rre,
                       encode_device=_dev("rre_encode_device", k=k),
                       decode_device=_devd("rre_decode_device"))
        register_stage(f"rze{k}", (lambda d, k=k: _rre.rze_encode(d, k)), _rre.rze_decode,
                       estimate=_est_rze(k), pack_header=_pack_rre, unpack_header=_unpack_rre,
                       encode_device=_dev("rze_encode_device", k=k),
                       decode_device=_devd("rze_decode_device"))
        register_stage(f"tcms{k}", (lambda d, k=k: _tcms.tcms_encode(d, k)), _tcms.tcms_decode,
                       estimate=_est_unit, pack_header=_pack_tcms, unpack_header=_unpack_tcms,
                       encode_device=_dev("tcms_encode_device", k=k),
                       decode_device=_devd("tcms_decode_device"))


_register_builtins()
