"""TCMS-k: two's-complement -> magnitude-sign symbol transform (§5.2.3).

Bijective on all k-byte patterns: non-negative symbols pass through;
negative symbols become MSB | ~x (small negative magnitudes get small
sign-magnitude patterns), so streams clustered around zero concentrate
their set bits in the low bit-planes — feeding BIT/RRE/RZE stages.
"""
from __future__ import annotations

import numpy as np

_DTYPES = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _view(data: np.ndarray, k: int):
    n = data.size
    pad = (-n) % k
    if pad:
        data = np.concatenate([data, np.zeros(pad, np.uint8)])
    return data.view(_DTYPES[k]), n


def tcms_encode(data: np.ndarray, k: int):
    data = np.ascontiguousarray(data, np.uint8)
    x, n = _view(data, k)
    bits = 8 * k
    msb = _DTYPES[k](1 << (bits - 1)) if bits < 64 else np.uint64(1 << 63)
    neg = (x & msb) != 0
    out = np.where(neg, (~x) ^ msb, x).astype(_DTYPES[k])
    # (~x) has MSB 0 when x is negative; ^msb sets it -> MSB flags sign.
    return out.view(np.uint8).tobytes(), {"n": int(n), "k": int(k)}


def tcms_decode(payload: bytes, header: dict) -> np.ndarray:
    k = header["k"]
    x = np.frombuffer(payload, np.uint8).view(_DTYPES[k])
    bits = 8 * k
    msb = _DTYPES[k](1 << (bits - 1)) if bits < 64 else np.uint64(1 << 63)
    neg = (x & msb) != 0
    out = np.where(neg, ~(x ^ msb), x).astype(_DTYPES[k])
    return out.view(np.uint8)[: header["n"]].copy()
