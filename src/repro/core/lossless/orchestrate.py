"""Adaptive lossless-pipeline orchestration (paper §5.2's exploration).

cuSZ-Hi's second contribution is that the best-fit lossless encoding stack
depends on the data: dense high-entropy code streams want Huffman-first
(CR pipeline), sparse/run-heavy streams want shuffle+run-reduction (TP/FZ),
near-incompressible streams want store-through. This module reproduces
that exploration *online*, per field:

1. sample the quantization-code stream (a few contiguous slices, so run
   structure survives — a strided sample would destroy it);
2. compute cheap stream statistics — byte-histogram entropy, zero-run
   density, outlier rate. For device-array inputs the histogram comes from
   the device engine by default (the Pallas histogram256 kernel compiled
   on TPU — repro.kernels.histogram — via repro.core.lossless.engine); the
   ``histogram`` hook overrides it, and the numpy bincount default for
   host arrays is the same integer arithmetic;
3. pre-score every registered pipeline with the per-stage ``estimate``
   cost hooks, then trial-encode the sample through the top candidates
   and pick the smallest output.

The winner and the sampled statistics are recorded per field in the
container header, so decode never re-infers anything — the pipeline stream
is self-describing and the record is for observability and reproducibility.
"""
from __future__ import annotations

import numpy as np

from .pipelines import PIPELINES, _is_jax, encode, get_pipeline
from .stages import get_stage

DEFAULT_SAMPLE_BYTES = 1 << 16
_N_SLICES = 4


def sample_stream(data, sample_bytes: int = DEFAULT_SAMPLE_BYTES):
    """Contiguous multi-slice sample: _N_SLICES evenly spaced windows.

    Windows never overlap for data larger than the sample budget, and the
    slices stay contiguous so repeat/run statistics are representative.
    Device arrays sample on device (pure slicing) and stay device-resident.
    """
    if _is_jax(data):
        from . import engine

        data = engine.as_device_u8(data)
        n = data.size
        if n <= sample_bytes:
            return data
        import jax.numpy as jnp

        per = sample_bytes // _N_SLICES
        starts = [(n - per) * i // (_N_SLICES - 1) for i in range(_N_SLICES)]
        return jnp.concatenate([data[s : s + per] for s in starts])
    data = np.ascontiguousarray(data, np.uint8).reshape(-1)
    n = data.size
    if n <= sample_bytes:
        return data
    per = sample_bytes // _N_SLICES
    starts = [(n - per) * i // (_N_SLICES - 1) for i in range(_N_SLICES)]
    return np.concatenate([data[s : s + per] for s in starts])


def stream_stats(sample, n_total: int | None = None, histogram=None) -> dict:
    """Cheap per-stream statistics driving the stage cost hooks.

    ``histogram``: optional callable mapping a uint8 array to 256 counts
    (e.g. the Pallas histogram256 kernel); when the sample is a device
    array it defaults to :func:`repro.core.lossless.engine.
    histogram256_device` (the Pallas kernel compiled on TPU), otherwise to
    ``np.bincount``. The counts — and therefore every derived statistic
    and the orchestrator's pipeline choice — are identical either way:
    histogram counts are integers and run_frac is computed as an exact
    integer ratio.
    """
    if _is_jax(sample):
        from . import engine

        sample = engine.as_device_u8(sample)
        if histogram is None:
            histogram = engine.histogram256_device
        # exact integer ratio: matches np.mean's float64 arithmetic
        run_frac = (
            float(int((sample[1:] == sample[:-1]).sum())) / (sample.size - 1)
            if sample.size > 1 else 0.0
        )
        hist = np.asarray(histogram(sample), np.int64)
    else:
        sample = np.ascontiguousarray(sample, np.uint8).reshape(-1)
        run_frac = float(np.mean(sample[1:] == sample[:-1])) if sample.size > 1 else 0.0
        hist = np.asarray(
            histogram(sample) if histogram is not None else np.bincount(sample, minlength=256),
            np.int64,
        )
    m = int(hist.sum())
    if m > 0:
        p = hist[hist > 0].astype(np.float64) / m
        entropy = float(-(p * np.log2(p)).sum())
        zero_frac = float(hist[0]) / m
        # outliers: codes far from the 128-centered quantization band
        outlier_frac = float(hist[:64].sum() + hist[192:].sum()) / m
    else:
        entropy = zero_frac = outlier_frac = 0.0
    return {
        "n": int(n_total if n_total is not None else sample.size),
        "sample_n": int(sample.size),
        "entropy": entropy,
        "zero_frac": zero_frac,
        "run_frac": run_frac,
        "outlier_frac": outlier_frac,
    }


def estimate_pipeline(stages, stats: dict) -> float:
    """Predicted compressed fraction: product of per-stage cost hooks.

    Crude (stage interactions are ignored) but cheap; used only to rank
    candidates before the trial encode refines the choice.
    """
    frac = 1.0
    for name in stages:
        frac *= min(1.0, float(get_stage(name).estimate(stats)))
    return frac


def portable_pipelines() -> list[str]:
    """Registered pipelines whose every stage decodes with no optional deps.

    Durable artifacts (checkpoints, relayed gradient payloads) restrict the
    orchestrator to these, so a stream written on a machine with optional
    codecs installed (e.g. zstandard) never becomes unreadable elsewhere.
    """
    return sorted(
        nm for nm, stages in PIPELINES.items()
        if all(get_stage(s).portable for s in stages)
    )


def _choose(
    data: np.ndarray,
    candidates=None,
    *,
    sample_bytes: int = DEFAULT_SAMPLE_BYTES,
    max_trials: int | None = None,
    histogram=None,
    portable_only: bool = False,
):
    if candidates is not None:
        names = sorted(candidates)
    elif portable_only:
        names = portable_pipelines()
    else:
        names = sorted(PIPELINES)
    for nm in names:
        get_pipeline(nm)  # raises with the registered list on typos
    if _is_jax(data):
        from . import engine

        data = engine.as_device_u8(data)  # trials ride the device fast path
    else:
        data = np.ascontiguousarray(data, np.uint8).reshape(-1)
    sample = sample_stream(data, sample_bytes)
    stats = stream_stats(sample, n_total=data.size, histogram=histogram)
    est = {nm: estimate_pipeline(get_pipeline(nm), stats) for nm in names}
    order = sorted(names, key=lambda nm: (est[nm], nm))
    if max_trials is not None:
        order = order[: max(1, max_trials)]
    bufs = {nm: encode(sample, nm) for nm in order}
    trial = {nm: len(b) for nm, b in bufs.items()}
    best = min(order, key=lambda nm: (trial[nm], nm))
    record = {
        "pipeline": best,
        "stats": stats,
        "estimates": est,
        "trial_bytes": trial,
    }
    # sample_stream returns the stream itself when it fits the budget; the
    # winning trial encoding IS the final encoding then — reuse it
    full = bufs[best] if sample.size == data.size else None
    return best, record, full


def choose_pipeline(data: np.ndarray, candidates=None, **kw) -> tuple[str, dict]:
    """Pick the best-fit registered pipeline for ``data``.

    Returns ``(name, record)`` where ``record`` carries the sampled stats,
    the per-pipeline estimates, and the trial-encode sizes — everything the
    container header needs to make the choice reproducible. ``candidates``
    narrows the search; ``portable_only=True`` restricts it to
    :func:`portable_pipelines`; ``max_trials`` caps the trial encodes to
    the estimate-ranked top candidates.
    """
    best, record, _ = _choose(data, candidates, **kw)
    return best, record


def encode_auto(data: np.ndarray, **kw) -> tuple[bytes, dict]:
    """Orchestrated encode: choose the best-fit pipeline, then encode.

    Returns ``(stream, record)``; the stream is self-describing, so decode
    is plain :func:`repro.core.lossless.pipelines.decode`. Streams no
    larger than the sample budget are encoded exactly once (the winning
    trial encoding is returned directly).
    """
    best, record, full = _choose(data, **kw)
    if full is not None:
        return full, record
    return encode(data, best), record
