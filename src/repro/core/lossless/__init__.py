from .pipelines import PIPELINES, decode, encode  # noqa: F401
