from .orchestrate import (  # noqa: F401
    choose_pipeline,
    encode_auto,
    portable_pipelines,
    stream_stats,
)
from .pipelines import (  # noqa: F401
    PIPELINES,
    decode,
    encode,
    encode_v1,
    get_pipeline,
    register_pipeline,
    registered_pipelines,
)
from .stages import Stage, get_stage, register_stage, registered_stages  # noqa: F401
