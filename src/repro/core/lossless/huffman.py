"""Canonical Huffman coding over uint8 symbols (the HF stage, §5.2).

GPU/TPU mapping (DESIGN.md §3): the histogram and per-symbol code lookup are
device-vectorized (see repro.kernels.histogram); the 256-leaf tree build is
O(256 log 256) scalar work and runs host-side. The bitstream is chunked
(1024 symbols, byte-aligned per chunk) like cuSZ's coarse-grained layout so
decode parallelizes across chunks.

Hot-path architecture (vectorized word-level packing, cuSZ's reduce-merge
idea recast for numpy):

* **Codes are length-limited to 16 bits** (gentle Kraft repair that only
  lengthens the rarest codes), which keeps every per-symbol quantity in
  32-bit lanes — wide-integer elementwise numpy is several times slower on
  commodity hosts — and makes a complete (length, symbol) prefix LUT
  affordable.
* **encode**: one table gather yields ``len<<16 | code`` per symbol and a
  uint16 view splits the fields without shift/mask passes; a wrapping
  uint16 cumsum gives within-chunk bit offsets (per-chunk sums are < 2^14,
  so mod-2^16 differences are exact); adjacent symbols are reduce-merged
  into <=32-bit pairs; each pair is shifted into its one or two 32-bit
  big-endian output words; colliding word contributions are disjoint-bit,
  so OR == ADD and a segmented sum (cumsum + boundary gathers) materializes
  the words with no per-bit scatter and no ``ufunc.at``. The bit layout is
  identical to the historical per-bit ``np.packbits`` path. Large inputs
  are split at chunk boundaries across a small thread pool (numpy releases
  the GIL on these array passes); slab payloads concatenate byte-exactly.
* **decode** is vectorized across chunks: one aligned big-endian uint32
  window pair per step gives a 32-bit peek; a uint16 LUT over the top
  ``maxlen`` bits returns ``len<<8 | symbol`` directly (canonical codes of
  length l own the contiguous range ``[first_code[l] << (LB-l),
  (first_code[l]+count[l]) << (LB-l))``), and because codes are <=16 bits
  the same peek also resolves a *second* symbol (``ls1 + maxlen <= 32``) —
  two symbols per window gather. Output rows are written transposed so the
  per-step stores stay contiguous. Decode stays single-threaded: its per
  step vectors are chunk-count sized, too small to amortize GIL handoffs.
* the section header is compact binary (256 raw code-length bytes + one u16
  of payload bytes per chunk) carried inside the payload; the JSON header
  holds only ``{"n": ...}``.  Legacy hex-in-JSON headers (4096-symbol
  chunks, codes up to 24 bits) still decode via a generic slow path.
"""
from __future__ import annotations

import heapq
import os
from concurrent.futures import ThreadPoolExecutor

import numpy as np

CHUNK = 1024
MAXLEN = 16  # length-limit so the (len,sym) LUT + 32-bit lanes cover every code
_LEGACY_CHUNK = 4096
_LEGACY_MAXLEN = 24
_TABLE = _LEGACY_MAXLEN  # canonical tables sized for the legacy maximum

_U0, _U1, _U5, _U8, _U16, _U31, _U32 = (np.uint32(x) for x in (0, 1, 5, 8, 16, 31, 32))

def _nworkers() -> int:
    """Slab-encode worker count: REPRO_HF_WORKERS overrides the cpu-based
    default (useful to pin benchmarks or to serialize under oversubscribed
    schedulers); invalid or non-positive values fall back to the default."""
    try:
        env = int(os.environ.get("REPRO_HF_WORKERS", "0"))
    except ValueError:
        env = 0
    return env if env > 0 else max(1, min(4, os.cpu_count() or 1))


_NWORKERS = _nworkers()
_PAR_MIN = 1 << 20  # encode bytes below this stay single-threaded
_SLAB_SYMS = 1 << 26  # keeps per-slab bit offsets < 2^30 (int32-view-safe)
_DECODE_GROUP_BYTES = 1 << 28  # payload span per u32-cursor decode group
_pool = None


def _executor() -> ThreadPoolExecutor:
    global _pool
    if _pool is None:
        _pool = ThreadPoolExecutor(max_workers=_NWORKERS)
    return _pool


def _reset_pool() -> None:
    """Drop the inherited pool in forked children: its worker threads do not
    survive fork, so reusing it would deadlock the next threaded encode.

    Registered via os.register_at_fork below — callers never need to (and
    must not be relied upon to) invoke this themselves; any fork started
    by any library picks up the cleanup automatically. The worker count is
    also re-read so a child can resize via REPRO_HF_WORKERS before its
    first encode."""
    global _pool, _NWORKERS
    _pool = None
    _NWORKERS = _nworkers()


if hasattr(os, "register_at_fork"):  # pragma: no branch - posix
    os.register_at_fork(after_in_child=_reset_pool)


def code_lengths(hist: np.ndarray) -> np.ndarray:
    """Huffman code length per symbol (0 for absent symbols), <= MAXLEN."""
    sym = np.flatnonzero(hist)
    if sym.size == 0:
        return np.zeros(256, np.uint8)
    if sym.size == 1:
        out = np.zeros(256, np.uint8)
        out[sym[0]] = 1
        return out
    heap = [(int(hist[s]), int(s), (int(s),)) for s in sym]
    heapq.heapify(heap)
    tick = 256
    depth = {int(s): 0 for s in sym}
    while len(heap) > 1:
        fa, _, la = heapq.heappop(heap)
        fb, _, lb = heapq.heappop(heap)
        for s in la + lb:
            depth[s] += 1
        heapq.heappush(heap, (fa + fb, tick, la + lb))
        tick += 1
    out = np.zeros(256, np.uint8)
    for s, d in depth.items():
        out[s] = d
    if out.max() > MAXLEN:
        out = _fix_kraft(out)
    return out


def _fix_kraft(lens: np.ndarray) -> np.ndarray:
    """Length-limit to MAXLEN: lengthen the rarest (longest) codes until the
    Kraft sum fits. Only sub-MAXLEN codes grow, and the longest such code
    belongs to the least frequent symbols, so the CR impact is minimal."""
    lens = np.minimum(lens.astype(np.int64), MAXLEN)
    used = lens > 0
    kraft = float(np.sum(np.where(used, 2.0 ** (-lens.astype(float)), 0.0)))
    while kraft > 1.0 + 1e-12:
        cand = np.where(used & (lens < MAXLEN), lens, -1)
        i = int(np.argmax(cand))
        kraft -= 2.0 ** (-float(lens[i]) - 1)
        lens[i] += 1
    return lens.astype(np.uint8)


def canonical_codes(lens: np.ndarray):
    """MSB-first canonical codewords: (codes u32, lens, first_code[l], sym_table, offsets[l])."""
    order = np.lexsort((np.arange(256), lens.astype(np.int64)))
    order = order[lens[order] > 0]
    codes = np.zeros(256, np.uint32)
    first_code = np.zeros(_TABLE + 2, np.uint32)
    counts = np.bincount(lens[lens > 0].astype(np.int64), minlength=_TABLE + 2)
    c = 0
    for l in range(1, _TABLE + 1):
        first_code[l] = c
        c = (c + int(counts[l])) << 1
    nxt = {l: int(first_code[l]) for l in range(1, _TABLE + 1)}
    for s in order:
        l = int(lens[s])
        codes[s] = nxt[l]
        nxt[l] += 1
    sym_table = order.astype(np.uint8)  # symbols sorted by (len, sym) == canonical order
    offsets = np.zeros(_TABLE + 2, np.int64)
    offsets[1:] = np.cumsum(counts)[:-1][: _TABLE + 1]
    return codes, lens, first_code, sym_table, offsets, counts


# --------------------------------------------------------------------- encode
def _encode_slab(d: np.ndarray, tbl: np.ndarray):
    """Encode one slab (any length; chunk grid local to the slab).

    Returns (payload bytes, chunk_bytes u16).
    """
    m0 = d.size
    nck = max(1, -(-m0 // CHUNK))
    m = nck * CHUNK
    half = CHUNK // 2
    if m != m0:  # pad to a full chunk grid; padded lanes carry zero-length codes
        d = np.concatenate([d, np.zeros(m - m0, np.uint8)])
    e = tbl[d]  # u32: len<<16 | code
    if m != m0:
        e[m0:] = 0
    # reduce-merge adjacent symbols into <=32-bit pairs (CHUNK is even, so
    # pairs never straddle a chunk boundary); ep rows = [c0, l0, c1, l1]
    ep = e.view("<u2").reshape(-1, 4)
    v2 = (ep[:, 0].astype(np.uint32) << ep[:, 3]) | ep[:, 2]
    l2 = ep[:, 1] + ep[:, 3]  # u16, <= 32
    # within-chunk bit offsets from the wrapping u16 pair-length cumsum
    # (per-chunk sums < 2^14, so mod-2^16 differences are exact)
    cum2 = np.cumsum(l2, dtype=np.uint16).reshape(nck, half)
    cbase = np.empty(nck, np.uint16)
    cbase[0] = 0
    cbase[1:] = cum2[:-1, -1]
    chunk_bytes = ((cum2[:, -1] - cbase).astype(np.int64) + 7) >> 3
    byte_off = np.zeros(nck + 1, np.int64)
    np.cumsum(chunk_bytes, out=byte_off[1:])
    total = int(byte_off[-1])
    s2rel = np.empty((nck, half), np.uint16)
    s2rel[:, 0] = 0
    s2rel[:, 1:] = cum2[:, :-1] - cbase[:, None]  # exclusive offset of pair j
    bitpos = s2rel.astype(np.uint32)
    bitpos += (byte_off[:-1, None] << 3).astype(np.uint32)
    bitpos = bitpos.reshape(-1)
    # word-level scatter: pair i covers bits [bitpos, bitpos+l2) of the
    # big-endian u32 word stream -> one or two word contributions
    sh = (bitpos & _U31) + l2
    spill = sh > 32
    s_left = _U0 - sh  # (32-sh) % 32 == (64-sh) % 32 once masked below
    s_left &= _U31
    sh &= _U31  # == sh-32 for spill lanes (sh <= 63); junk elsewhere, masked out
    lo = np.left_shift(v2, s_left, out=s_left)  # spill lanes: bits for word w+1
    hi = np.right_shift(v2, sh, out=sh)  # spill lanes: bits for word w
    np.copyto(hi, lo, where=~spill)  # non-spill lanes fit word w entirely
    np.copyto(lo, _U0, where=~spill)
    nwords = (total + 3) >> 2
    # word w holds pairs with bitpos in [32w, 32w+32); bitpos is sorted
    w32 = np.right_shift(bitpos, _U5, out=bitpos).view(np.int32)
    bounds = np.zeros(nwords + 1, np.int64)
    # zero-length pad pairs may sit one word past the end; dropping their
    # (zero) contributions is exact
    np.cumsum(np.bincount(w32, minlength=nwords)[:nwords], out=bounds[1:])
    words = _segment_sum(hi, bounds)
    words[1:] |= _segment_sum(lo, bounds)[:-1]  # lo lands one word later
    return words.astype(">u4").tobytes()[:total], chunk_bytes.astype("<u2")


def _segment_sum(vals: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    """Per-segment sums of u32 `vals` split at `bounds` (prefix-sum diff).

    Contributions within a word occupy disjoint bit ranges, so sums never
    carry (OR == ADD) and mod-2^32 prefix differences are exact."""
    csum = np.empty(vals.size + 1, np.uint32)
    csum[0] = 0
    np.cumsum(vals, out=csum[1:])
    g = csum[bounds]
    return g[1:] - g[:-1]


def encode(data: np.ndarray):
    """data: uint8 array. Returns (payload bytes, header dict).

    Payload = [256B code lengths][u16 payload bytes per chunk][bitstream];
    the JSON-visible header carries only the symbol count.
    """
    data = np.ascontiguousarray(data, dtype=np.uint8).reshape(-1)
    n = data.size
    nchunks = max(1, -(-n // CHUNK))
    nslabs = 1
    if n >= _PAR_MIN:
        nslabs = max(_NWORKERS, -(-n // _SLAB_SYMS))
    ck_per = -(-nchunks // nslabs)
    cuts = [min(i * ck_per * CHUNK, n) for i in range(nslabs + 1)]
    slabs = [data[cuts[i] : cuts[i + 1]] for i in range(nslabs) if cuts[i] < cuts[i + 1]] or [data]
    if len(slabs) > 1:
        hists = list(_executor().map(lambda s: np.bincount(s, minlength=256), slabs))
        hist = np.sum(hists, axis=0)
    else:
        hist = np.bincount(data, minlength=256)
    lens = code_lengths(hist)
    codes, lens, *_ = canonical_codes(lens)
    tbl = (lens.astype(np.uint32) << _U16) | codes
    if len(slabs) > 1:
        parts = list(_executor().map(lambda s: _encode_slab(s, tbl), slabs))
    else:
        parts = [_encode_slab(slabs[0], tbl)]
    bits = b"".join(p[0] for p in parts)
    chunk_bytes = np.concatenate([p[1] for p in parts])
    blob = lens.tobytes() + chunk_bytes.tobytes()
    return blob + bits, dict({"n": int(n)}, **offset_table(chunk_bytes))


def offset_table(chunk_bytes: np.ndarray) -> dict:
    """Per-chunk byte-offset header extension from the chunk sizes.

    ``{"offs": <u4 exclusive byte offset per chunk>}`` — the random-access
    table the device decoder gathers against (every chunk's bitstream
    start, so all chunks decode in parallel without replaying the size
    prefix sum serially). Omitted for payloads past the u32 range; headers
    without it (legacy streams) decode through the host reference path.
    """
    cum = np.cumsum(chunk_bytes.astype(np.int64))
    if cum.size and cum[-1] >= 1 << 32:
        return {}
    offs = np.zeros(cum.size, "<u4")
    offs[1:] = cum[:-1]
    return {"offs": offs.tobytes()}


# --------------------------------------------------------------------- decode
def _be32(bits: np.ndarray):
    """(be, beS1): native u32 views of the big-endian payload words with zero
    slack; beS1 is the next word pre-shifted right once, so the window
    combine `(be[q] << r) | (beS1[q] >> (31-r))` never needs a 32-bit shift."""
    pad = 8 + (-(bits.size + 8)) % 4
    buf = np.concatenate([bits, np.zeros(pad, np.uint8)])
    be = buf.view(">u4").astype(np.uint32)
    return be, be[1:] >> _U1


def _pair_lut(first_code, counts, sym_table, offsets, maxlen: int) -> np.ndarray:
    """uint16 LUT over the top `maxlen` peek bits: entry = len<<8 | symbol."""
    lut = np.zeros(1 << maxlen, np.uint16)
    for l in range(1, maxlen + 1):
        fc, cnt = int(first_code[l]), int(counts[l])
        if cnt == 0:
            continue
        syms = sym_table[int(offsets[l]) : int(offsets[l]) + cnt]
        ent = (np.uint16(l) << np.uint16(8)) | syms.astype(np.uint16)
        lut[fc << (maxlen - l) : (fc + cnt) << (maxlen - l)] = np.repeat(ent, 1 << (maxlen - l))
    return lut


def _span_pairs(be, beS1, cursors, outT, t0, t1, lut, shift_lut):
    """Decode symbols t0..t1-1 into transposed rows outT[t] (in place).

    One aligned u32 window pair per step yields a 32-bit peek; the LUT
    resolves (len, sym) for two consecutive symbols per peek (valid because
    maxlen <= 16, so ls1 + maxlen <= 32)."""
    t = t0
    while t < t1:
        q = cursors >> _U5
        r = cursors & _U31
        peek = (be[q] << r) | (beS1[q] >> (_U31 - r))
        e1 = lut[peek >> shift_lut]
        outT[t] = e1  # truncating store keeps the symbol byte
        ls1 = e1 >> _U8
        if t + 1 < t1:
            e2 = lut[(peek << ls1) >> shift_lut]
            outT[t + 1] = e2
            cursors += ls1 + (e2 >> _U8)
            t += 2
        else:
            cursors += ls1
            t += 1


def _span_generic(be, beS1, cursors, outT, t0, t1, lengths, base, sym_table):
    """One-symbol-per-step decode for legacy streams (codes up to 24 bits)."""
    t = t0
    while t < t1:
        q = cursors >> _U5
        r = cursors & _U31
        peek = (be[q] << r) | (beS1[q] >> (_U31 - r))
        ls = lengths(peek)
        cw = (peek >> (_U32 - ls.astype(np.uint32))).astype(np.int64)
        outT[t] = sym_table[base[ls] + cw]
        cursors += ls.astype(np.uint32)
        t += 1


def _length_lookup(first_code, counts, maxlen: int):
    """f(peek: 32-bit MSB-aligned u32) -> code length, for the legacy path."""
    # limit[l] = (first_code[l]+count[l]) << (32-l) is monotone over l; u64
    # because a complete tree has first_code[maxlen]+count[maxlen] == 2^maxlen,
    # so the top limit is exactly 2^32
    limits = np.zeros(maxlen, np.uint64)
    for l in range(1, maxlen + 1):
        limits[l - 1] = np.uint64((int(first_code[l]) + int(counts[l])) << (32 - l))
    return lambda peek: 1 + np.searchsorted(limits, peek.astype(np.uint64), side="right").astype(np.int64)


def decode(payload: bytes, header: dict) -> np.ndarray:
    n = int(header["n"])
    if n == 0:
        return np.zeros(0, np.uint8)
    legacy = "lens" in header
    if legacy:  # hex-in-JSON header from seed containers
        chunk = _LEGACY_CHUNK
        lens = np.frombuffer(bytes.fromhex(header["lens"]), np.uint8).copy()
        chunk_bytes = np.frombuffer(bytes.fromhex(header["chunk_bytes"]), np.uint32).astype(np.int64)
        bits = np.frombuffer(payload, np.uint8)
    else:
        chunk = CHUNK
        nchunks = max(1, -(-n // CHUNK))
        buf = np.frombuffer(payload, np.uint8)
        lens = buf[:256].copy()
        chunk_bytes = buf[256 : 256 + 2 * nchunks].view("<u2").astype(np.int64)
        bits = buf[256 + 2 * nchunks :]
    codes, lens, first_code, sym_table, offsets, counts = canonical_codes(lens)
    maxlen = int(lens.max())
    nchunks = chunk_bytes.size
    byte_off = np.zeros(nchunks + 1, np.int64)
    np.cumsum(chunk_bytes, out=byte_off[1:])
    be, beS1 = _be32(bits)
    if maxlen <= MAXLEN:
        lut = _pair_lut(first_code, counts, sym_table, offsets, maxlen)
        shift_lut = np.uint32(32 - maxlen)

        def span(bv, bsv, cur, o, t0, t1):
            _span_pairs(bv, bsv, cur, o, t0, t1, lut, shift_lut)

    else:  # legacy deep tree
        lengths = _length_lookup(first_code, counts, maxlen)
        base = offsets - first_code.astype(np.int64)

        def span(bv, bsv, cur, o, t0, t1):
            _span_generic(bv, bsv, cur, o, t0, t1, lengths, base, sym_table)

    n_last = n - chunk * (nchunks - 1)
    outT = np.zeros((chunk, nchunks), np.uint8)  # transposed: row store per step
    # the hot loop keeps bit cursors in u32; chunk groups whose payload span
    # exceeds the 32-bit cursor range are rebased onto a word-aligned origin
    # and decoded from offset views (one group for payloads < 256 MiB)
    group_bytes = _DECODE_GROUP_BYTES
    a = 0
    while a < nchunks:
        word0 = byte_off[a] >> 2  # aligned origin at/below the group start
        b = a + 1
        while b < nchunks and byte_off[b + 1] - (word0 << 2) <= group_bytes:
            b += 1
        cur = (byte_off[a:b] * 8 - (word0 << 5)).astype(np.uint32)
        bv, bsv, oT = be[word0:], beS1[word0:], outT[:, a:b]
        if b == nchunks:
            span(bv, bsv, cur, oT, 0, n_last)
            if b - a > 1 and n_last < chunk:
                span(bv, bsv, cur[:-1], oT[:, :-1], n_last, chunk)
        else:
            span(bv, bsv, cur, oT, 0, chunk)
        a = b
    out = np.ascontiguousarray(outT.T)
    if n_last == chunk:
        return out.reshape(-1)
    return np.concatenate([out[:-1].reshape(-1), out[-1, :n_last]])
