"""Canonical Huffman coding over uint8 symbols (the HF stage, §5.2).

GPU/TPU mapping (DESIGN.md §3): the histogram and per-symbol code lookup are
device-vectorized (see repro.kernels.histogram); the 256-leaf tree build is
O(256 log 256) scalar work and runs host-side. The bitstream is chunked
(4096 symbols, byte-aligned per chunk) exactly like cuSZ's coarse-grained
layout so decode parallelizes across chunks — our decoder is vectorized
across chunks with numpy.
"""
from __future__ import annotations

import heapq

import numpy as np

CHUNK = 4096
MAXLEN = 24  # refuse longer codes (rebalance by flooring tiny freqs)


def code_lengths(hist: np.ndarray) -> np.ndarray:
    """Huffman code length per symbol (0 for absent symbols)."""
    sym = np.flatnonzero(hist)
    if sym.size == 0:
        return np.zeros(256, np.uint8)
    if sym.size == 1:
        out = np.zeros(256, np.uint8)
        out[sym[0]] = 1
        return out
    heap = [(int(hist[s]), int(s), (int(s),)) for s in sym]
    heapq.heapify(heap)
    tick = 256
    depth = {int(s): 0 for s in sym}
    while len(heap) > 1:
        fa, _, la = heapq.heappop(heap)
        fb, _, lb = heapq.heappop(heap)
        for s in la + lb:
            depth[s] += 1
        heapq.heappush(heap, (fa + fb, tick, la + lb))
        tick += 1
    out = np.zeros(256, np.uint8)
    for s, d in depth.items():
        out[s] = d
    if out.max() > MAXLEN:  # pathological skew: flatten tail lengths
        out = np.minimum(out, MAXLEN)
        out = _fix_kraft(out)
    return out


def _fix_kraft(lens: np.ndarray) -> np.ndarray:
    """Length-limited repair: increase short codes until Kraft sum <= 1."""
    lens = lens.astype(np.int64).copy()
    used = lens > 0
    while np.sum(np.where(used, 2.0 ** (-lens.astype(float)), 0.0)) > 1.0 + 1e-12:
        i = np.argmin(np.where(used & (lens < MAXLEN), lens, 1 << 30))
        lens[i] += 1
    return lens.astype(np.uint8)


def canonical_codes(lens: np.ndarray):
    """MSB-first canonical codewords: (codes u32, lens, first_code[l], sym_table, offsets[l])."""
    order = np.lexsort((np.arange(256), lens.astype(np.int64)))
    order = order[lens[order] > 0]
    codes = np.zeros(256, np.uint32)
    first_code = np.zeros(MAXLEN + 2, np.uint32)
    counts = np.bincount(lens[lens > 0].astype(np.int64), minlength=MAXLEN + 2)
    c = 0
    firsts = {}
    for l in range(1, MAXLEN + 1):
        firsts[l] = c
        first_code[l] = c
        c = (c + int(counts[l])) << 1
    nxt = {l: int(first_code[l]) for l in range(1, MAXLEN + 1)}
    for s in order:
        l = int(lens[s])
        codes[s] = nxt[l]
        nxt[l] += 1
    sym_table = order.astype(np.uint8)  # symbols sorted by (len, sym) == canonical order
    offsets = np.zeros(MAXLEN + 2, np.int64)
    offsets[1:] = np.cumsum(counts)[:-1][: MAXLEN + 1]
    return codes, lens, first_code, sym_table, offsets, counts


def encode(data: np.ndarray):
    """data: uint8 array. Returns (payload bytes, header dict)."""
    data = np.ascontiguousarray(data, dtype=np.uint8)
    n = data.size
    hist = np.bincount(data, minlength=256)
    lens = code_lengths(hist)
    codes, lens, *_ = canonical_codes(lens)
    sym_lens = lens[data].astype(np.int64)
    nchunks = max(1, -(-n // CHUNK))
    # per-chunk bit counts -> byte-aligned chunk layout
    pad_n = nchunks * CHUNK
    sl = np.zeros(pad_n, np.int64)
    sl[:n] = sym_lens
    chunk_bits = sl.reshape(nchunks, CHUNK).sum(1)
    chunk_bytes = (chunk_bits + 7) >> 3
    chunk_byte_off = np.zeros(nchunks + 1, np.int64)
    np.cumsum(chunk_bytes, out=chunk_byte_off[1:])
    total_bytes = int(chunk_byte_off[-1])
    out_bits = np.zeros(total_bytes * 8, np.uint8)
    # global bit position per symbol
    within = sl.reshape(nchunks, CHUNK)
    start_in_chunk = np.cumsum(within, 1) - within
    bitpos = (chunk_byte_off[:-1, None] * 8 + start_in_chunk).reshape(-1)[:n]
    # scatter codeword bits (slabbed to bound memory)
    cw = codes[data].astype(np.int64)
    SLAB = 1 << 22
    for lo in range(0, n, SLAB):
        hi = min(n, lo + SLAB)
        L = sym_lens[lo:hi]
        reps = np.repeat(np.arange(lo, hi), L)
        j = np.arange(int(L.sum())) - np.repeat(np.cumsum(L) - L, L)
        out_bits[bitpos[reps] + j] = (cw[reps] >> (sym_lens[reps] - 1 - j)) & 1
    payload = np.packbits(out_bits).tobytes()
    header = {
        "n": int(n),
        "lens": lens.tobytes().hex(),
        "chunk_bytes": np.asarray(chunk_bytes, np.uint32).tobytes().hex(),
    }
    return payload, header


def decode(payload: bytes, header: dict) -> np.ndarray:
    n = int(header["n"])
    if n == 0:
        return np.zeros(0, np.uint8)
    lens = np.frombuffer(bytes.fromhex(header["lens"]), np.uint8).copy()
    chunk_bytes = np.frombuffer(bytes.fromhex(header["chunk_bytes"]), np.uint32).astype(np.int64)
    codes, lens, first_code, sym_table, offsets, counts = canonical_codes(lens)
    maxlen = int(lens.max())
    nchunks = chunk_bytes.size
    byte_off = np.zeros(nchunks + 1, np.int64)
    np.cumsum(chunk_bytes, out=byte_off[1:])
    buf = np.frombuffer(payload, np.uint8)
    buf = np.concatenate([buf, np.zeros(8, np.uint8)])  # slack for peeking past end
    # canonical decode, vectorized across chunks
    W = 32
    # limit[l] = (first_code[l] + count[l]) << (W-l); monotone over l including
    # unused lengths (the canonical recurrence keeps gaps consistent), so
    # code length = first l with peek < limit[l].
    limits = np.zeros(MAXLEN + 1, np.uint64)
    for l in range(1, MAXLEN + 1):
        limits[l] = np.uint64(int(first_code[l]) + int(counts[l])) << np.uint64(W - l)
    limits_v = limits[1 : maxlen + 1]
    cursors = byte_off[:-1] * 8  # bit cursor per chunk
    counts_sym = np.full(nchunks, CHUNK, np.int64)
    counts_sym[-1] = n - CHUNK * (nchunks - 1)
    out = np.zeros(nchunks * CHUNK, np.uint8)
    first_code64 = first_code.astype(np.int64)
    offsets64 = offsets
    for t in range(int(counts_sym.max())):
        act = counts_sym > t
        cur = cursors[act]
        byte = cur >> 3
        shift = cur & 7
        # gather 5 bytes -> 32-bit MSB-aligned peek window
        window = np.zeros(cur.size, np.uint64)
        for b in range(5):
            window = (window << np.uint64(8)) | buf[byte + b].astype(np.uint64)
        peek = (window >> (np.uint64(8) - shift.astype(np.uint64))) & np.uint64(0xFFFFFFFF)
        ls = 1 + np.argmax(peek[:, None] < limits_v[None, :], axis=1)
        cw = (peek >> (np.uint64(W) - ls.astype(np.uint64))).astype(np.int64)
        sym = sym_table[offsets64[ls] + cw - first_code64[ls]]
        out[np.flatnonzero(act) * CHUNK + t] = sym
        cursors[act] = cur + ls
    return _gather_out(out, counts_sym)


def _gather_out(out: np.ndarray, counts_sym: np.ndarray) -> np.ndarray:
    nchunks = counts_sym.size
    if counts_sym[-1] == CHUNK:
        return out
    keep = out.reshape(nchunks, CHUNK)
    return np.concatenate([keep[:-1].reshape(-1), keep[-1, : counts_sym[-1]]])
