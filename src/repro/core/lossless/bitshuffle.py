"""BIT1: bit-plane shuffle (§5.2.3).

Within each block, output plane p holds bit p of every byte — after TCMS,
high planes are near-constant runs that RRE1 collapses. The transpose is a
pure data-movement op; repro.kernels.bitshuffle carries the Pallas/TPU
version, this is the host/numpy path used in the pipelines.
"""
from __future__ import annotations

import numpy as np

BLOCK = 8192


def bitshuffle_encode(data: np.ndarray, block: int = BLOCK):
    data = np.ascontiguousarray(data, np.uint8)
    n = data.size
    if n == 0:
        return b"", {"n": 0, "block": int(block)}
    pad = (-n) % block
    if pad:
        data = np.concatenate([data, np.zeros(pad, np.uint8)])
    arr = data.reshape(-1, block)
    bits = np.unpackbits(arr, axis=1).reshape(-1, block, 8)
    planes = np.packbits(bits.transpose(0, 2, 1).reshape(arr.shape[0], -1), axis=1)
    return planes.reshape(-1).tobytes(), {"n": int(n), "block": int(block)}


def bitshuffle_decode(payload: bytes, header: dict) -> np.ndarray:
    n, block = header["n"], header["block"]
    if n == 0:
        return np.zeros(0, np.uint8)
    arr = np.frombuffer(payload, np.uint8).reshape(-1, block)
    bits = np.unpackbits(arr, axis=1).reshape(-1, 8, block)
    out = np.packbits(bits.transpose(0, 2, 1).reshape(arr.shape[0], -1), axis=1)
    return out.reshape(-1)[:n].copy()
