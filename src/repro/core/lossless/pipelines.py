"""Composable lossless pipelines (paper §5.2, Figure 7).

A pipeline is a list of stage names; each stage maps a byte stream to
(payload, header) and back. The two cuSZ-Hi pipelines:

    CR mode:  hf  -> rre4 -> tcms8 -> rze1      (ratio-preferred)
    TP mode:  tcms1 -> bit1 -> rre1             (throughput-preferred)
"""
from __future__ import annotations

import json

import numpy as np

from . import bitshuffle as _bit
from . import huffman as _hf
from . import rre as _rre
from . import tcms as _tcms

PIPELINES = {
    "cr": ("hf", "rre4", "tcms8", "rze1"),
    "tp": ("tcms1", "bit1", "rre1"),
    "hf": ("hf",),
    "none": (),
    # baseline pipelines (see repro.core.baselines)
    "fz": ("bit1", "rre1"),
    # beyond-paper: CR pipeline with an open-source zstd tail (replaces the
    # role Bitcomp plays for cuSZ-IB, without the proprietary dependency)
    "crz": ("hf", "rre4", "tcms8", "rze1", "zstd"),
}


def _encode_stage(name: str, data: np.ndarray):
    if name == "hf":
        return _hf.encode(data)
    if name.startswith("rre"):
        return _rre.rre_encode(data, int(name[3:]))
    if name.startswith("rze"):
        return _rre.rze_encode(data, int(name[3:]))
    if name.startswith("tcms"):
        return _tcms.tcms_encode(data, int(name[4:]))
    if name == "bit1":
        return _bit.bitshuffle_encode(data)
    if name == "zstd":
        # zstandard is an optional dependency: fall back to stdlib zlib and
        # record the codec actually used so decode dispatches correctly
        try:
            import zstandard

            return zstandard.ZstdCompressor(level=6).compress(data.tobytes()), {"c": "zstd"}
        except ImportError:
            import zlib

            return zlib.compress(data.tobytes(), 6), {"c": "zlib"}
    raise ValueError(f"unknown stage {name!r}")


def _decode_stage(name: str, payload: bytes, header: dict) -> np.ndarray:
    if name == "hf":
        return _hf.decode(payload, header)
    if name.startswith("rre"):
        return _rre.rre_decode(payload, header)
    if name.startswith("rze"):
        return _rre.rze_decode(payload, header)
    if name.startswith("tcms"):
        return _tcms.tcms_decode(payload, header)
    if name == "bit1":
        return _bit.bitshuffle_decode(payload, header)
    if name == "zstd":
        if header.get("c", "zstd") == "zlib":
            import zlib

            return np.frombuffer(zlib.decompress(payload), np.uint8)
        try:
            import zstandard
        except ImportError as e:
            raise ImportError(
                "this stream was compressed with the optional 'zstandard' package; install it to decode"
            ) from e
        return np.frombuffer(zstandard.ZstdDecompressor().decompress(payload), np.uint8)
    raise ValueError(f"unknown stage {name!r}")


def encode(data: np.ndarray, pipeline: str | tuple) -> bytes:
    stages = PIPELINES[pipeline] if isinstance(pipeline, str) else tuple(pipeline)
    cur = np.ascontiguousarray(data, np.uint8)
    headers = []
    for name in stages:
        payload, hdr = _encode_stage(name, cur)
        nxt = np.frombuffer(payload, np.uint8) if isinstance(payload, bytes) else payload
        if nxt.size + len(json.dumps(hdr)) >= cur.size and cur.size > 0:
            headers.append({"_skip": True})  # stage expands: store-through
            continue
        headers.append(hdr)
        cur = nxt
    meta = json.dumps({"stages": list(stages), "headers": headers}).encode()
    return len(meta).to_bytes(4, "little") + meta + cur.tobytes()


def decode(buf: bytes) -> np.ndarray:
    mlen = int.from_bytes(buf[:4], "little")
    meta = json.loads(buf[4 : 4 + mlen])
    cur = buf[4 + mlen :]
    for name, hdr in zip(reversed(meta["stages"]), reversed(meta["headers"])):
        if hdr.get("_skip"):
            continue
        cur = _decode_stage(name, cur, hdr)
        cur = cur.tobytes() if isinstance(cur, np.ndarray) else cur
    return np.frombuffer(cur, np.uint8)
