"""Composable lossless pipelines over the stage registry (paper §5.2, Fig. 7).

A pipeline is a named sequence of registered stages
(:mod:`repro.core.lossless.stages`); :func:`register_pipeline` validates
every stage name against the registry at registration time, so a typo fails
with the list of known stages instead of deep inside an encode. The two
cuSZ-Hi pipelines:

    CR mode:  hf  -> rre4 -> tcms8 -> rze1      (ratio-preferred)
    TP mode:  tcms1 -> bit1 -> rre1             (throughput-preferred)

``pipeline="auto"`` (see :mod:`repro.core.lossless.orchestrate`) samples the
stream and picks the best-fit registered pipeline per field.

Device fast path: when ``encode`` receives a ``jax.Array``, each stage with
an ``encode_device`` twin (repro.core.lossless.engine) runs jit-compiled on
the device and the stream chains between stages as a device array — the
bytes only land on host once, in the final packed stream. The engine's
bit-identity contract makes the result byte-equal to the numpy path, so
the choice of path is invisible to decoders and golden fixtures. A stage
without a device twin (e.g. ``zstd``) drops the stream to host and the
remaining stages run the numpy path. ``decode(buf, device=True)`` is the
symmetric read path: stages with ``decode_device`` twins chain the stream
device-resident back to a device uint8 array, same bytes as the host
decode.

Stream format (v2, this module's framing): ``b"LLP2"`` magic, then one
record per stage — flags byte (bit0 = store-through skip for stages that
expanded the stream), name, and the stage's *binary-packed* header — then
the final payload. Streams written before this format (a JSON meta block
prefixed by its u32 length) are detected by the missing magic and decoded
through the same stage registry, so old containers keep working.
"""
from __future__ import annotations

import json
import struct

import numpy as np

from .stages import get_stage

_MAGIC = b"LLP2"

PIPELINES: dict[str, tuple] = {}  # name -> stage-name tuple (live registry)


def register_pipeline(name: str, stages, *, overwrite: bool = False) -> tuple:
    """Register a named pipeline; every stage must already be registered."""
    stages = tuple(stages)
    for s in stages:
        get_stage(s)  # raises with the registered-stage list on typos
    if name in PIPELINES and not overwrite and PIPELINES[name] != stages:
        raise ValueError(
            f"pipeline {name!r} is already registered as {PIPELINES[name]}; "
            "pass overwrite=True to replace it"
        )
    PIPELINES[name] = stages
    return stages


def get_pipeline(name: str) -> tuple:
    try:
        return PIPELINES[name]
    except KeyError:
        raise ValueError(
            f"unknown pipeline {name!r}; "
            f"registered pipelines: {', '.join(sorted(PIPELINES))} (or 'auto')"
        ) from None


def registered_pipelines() -> dict[str, tuple]:
    return dict(PIPELINES)


register_pipeline("cr", ("hf", "rre4", "tcms8", "rze1"))
register_pipeline("tp", ("tcms1", "bit1", "rre1"))
register_pipeline("hf", ("hf",))
register_pipeline("none", ())
# baseline pipelines (see repro.core.baselines)
register_pipeline("fz", ("bit1", "rre1"))
# beyond-paper: CR pipeline with an open-source zstd tail (replaces the
# role Bitcomp plays for cuSZ-IB, without the proprietary dependency)
register_pipeline("crz", ("hf", "rre4", "tcms8", "rze1", "zstd"))
# bit1-first variant: bit-plane shuffle up front so the run-reduction sees
# plane-major redundancy, Huffman mops up the survivors
register_pipeline("fzh", ("bit1", "rre1", "hf"))
# per-level variant: run-reduction before the entropy coder — tuned for the
# level-reordered code stream, whose fine-level tail is long same-code runs
register_pipeline("lvl", ("rre4", "hf", "rze1"))


def _resolve(pipeline) -> tuple:
    return get_pipeline(pipeline) if isinstance(pipeline, str) else tuple(pipeline)


def _is_jax(data) -> bool:
    """jax.Array detection without importing jax for host-only callers."""
    return not isinstance(data, np.ndarray) and "jax" in type(data).__module__


def encode(data, pipeline: str | tuple) -> bytes:
    stages = _resolve(pipeline)
    device = _is_jax(data)
    if device:
        from . import engine

        cur = engine.as_device_u8(data)
    else:
        cur = np.ascontiguousarray(data, np.uint8)
    recs = []
    for name in stages:
        st = get_stage(name)
        if device and st.encode_device is not None:
            payload, hdr = st.encode_device(cur)
            nxt = payload  # device uint8 array: the stream stays resident
        else:
            if device:  # host-only stage: the stream drops to host for good
                cur = np.asarray(cur)
                device = False
            payload, hdr = st.encode(cur)
            nxt = np.frombuffer(payload, np.uint8) if isinstance(payload, bytes) else payload
        hb = st.pack_header(hdr)
        if nxt.size + len(hb) >= cur.size and cur.size > 0:
            recs.append((name, 1, b""))  # stage expands: store-through
            continue
        recs.append((name, 0, hb))
        cur = nxt
    out = bytearray(_MAGIC)
    out += struct.pack("<B", len(recs))
    for name, flags, hb in recs:
        nb = name.encode()
        out += struct.pack("<BB", flags, len(nb)) + nb + struct.pack("<I", len(hb)) + hb
    out += np.asarray(cur).tobytes()
    return bytes(out)


def decode(buf, *, device: bool = False):
    """Decode a pipeline stream back to the uint8 code stream.

    ``buf`` is any bytes-like object (bytes, bytearray, memoryview, uint8
    ndarray) — the v3 frame reader hands memoryviews straight through and
    the payload is sliced, never copied. With ``device=True`` the stream
    decodes through the stages' ``decode_device`` twins, chaining between
    device-capable stages as a device array (a stage without a twin pulls
    the stream to host for that hop), and the return value is a device
    uint8 array; the bytes are identical to the host path either way.
    """
    mv = buf if isinstance(buf, memoryview) else memoryview(buf)
    if mv[:4] == _MAGIC:
        nstages = mv[4]
        off = 5
        recs = []
        for _ in range(nstages):
            flags, nlen = struct.unpack_from("<BB", mv, off)
            off += 2
            name = bytes(mv[off : off + nlen]).decode()
            off += nlen
            (hlen,) = struct.unpack_from("<I", mv, off)
            off += 4
            recs.append((name, flags, bytes(mv[off : off + hlen])))
            off += hlen
        cur = mv[off:]
        for name, flags, hb in reversed(recs):
            if flags & 1:
                continue
            st = get_stage(name)
            hdr = st.unpack_header(hb)
            if device and st.decode_device is not None:
                cur = st.decode_device(cur, hdr)  # device uint8 stream
                continue
            if _is_jax(cur):  # twin-less stage: pull the stream to host
                cur = np.asarray(cur)
            out = st.decode(cur, hdr)
            cur = out.tobytes() if isinstance(out, np.ndarray) else out
    else:
        # legacy stream: u32 length-prefixed JSON meta, dict headers (whose
        # hex-blob fields the twins would host-fallback on anyway)
        mlen = int.from_bytes(mv[:4], "little")
        meta = json.loads(bytes(mv[4 : 4 + mlen]))
        cur = mv[4 + mlen :]
        for name, hdr in zip(reversed(meta["stages"]), reversed(meta["headers"])):
            if hdr.get("_skip"):
                continue
            out = get_stage(name).decode(cur, hdr)
            cur = out.tobytes() if isinstance(out, np.ndarray) else out
    if device:
        from . import engine

        return engine.as_device_u8(cur)
    if _is_jax(cur):
        return np.asarray(cur).reshape(-1)
    return np.frombuffer(cur, np.uint8)


def encode_v1(data: np.ndarray, pipeline: str | tuple) -> bytes:
    """Legacy (pre-v2) stream writer: JSON meta block with dict headers.

    Kept so tests can fabricate old streams bit-compatibly and so tooling
    can still emit streams readable by pre-registry checkouts.
    """
    stages = _resolve(pipeline)
    cur = np.ascontiguousarray(data, np.uint8)
    headers = []
    for name in stages:
        payload, hdr = get_stage(name).encode(cur)
        # binary header extensions (e.g. hf's "offs" table) can't ride JSON;
        # v1 streams decode through the host reference path without them
        hdr = {k: v for k, v in hdr.items() if not isinstance(v, (bytes, bytearray))}
        nxt = np.frombuffer(payload, np.uint8) if isinstance(payload, bytes) else payload
        if nxt.size + len(json.dumps(hdr)) >= cur.size and cur.size > 0:
            headers.append({"_skip": True})  # stage expands: store-through
            continue
        headers.append(hdr)
        cur = nxt
    meta = json.dumps({"stages": list(stages), "headers": headers}).encode()
    return len(meta).to_bytes(4, "little") + meta + cur.tobytes()
