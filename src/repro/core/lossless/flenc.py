"""Fixed-length bit-packing encoder (the cuSZp2-like baseline stage).

Zigzags int32 codes, splits into blocks of 32 values, stores each block at
the per-block max bit width — cuSZp2's "fixed-length encoding" scheme.
"""
from __future__ import annotations

import numpy as np

BLK = 32


def fl_encode(codes: np.ndarray):
    c = np.ascontiguousarray(codes, np.int64).reshape(-1)
    n = c.size
    z = ((c << 1) ^ (c >> 63)).astype(np.uint64)  # zigzag
    pad = (-n) % BLK
    if pad:
        z = np.concatenate([z, np.zeros(pad, np.uint64)])
    zb = z.reshape(-1, BLK)
    mx = zb.max(axis=1)
    bw = np.zeros(zb.shape[0], np.uint8)
    nzb = mx > 0
    bw[nzb] = np.floor(np.log2(mx[nzb].astype(np.float64))).astype(np.uint8) + 1
    lens = np.repeat(bw.astype(np.int64), BLK)[: z.size]
    total = int(lens.sum())
    out_bits = np.zeros(((total + 7) // 8) * 8, np.uint8)
    offs = np.cumsum(lens) - lens
    SLAB = 1 << 22
    for lo in range(0, z.size, SLAB):
        hi = min(z.size, lo + SLAB)
        L = lens[lo:hi]
        tot = int(L.sum())
        if tot == 0:
            continue
        reps = np.repeat(np.arange(lo, hi), L)
        j = np.arange(tot) - np.repeat(np.cumsum(L) - L, L)
        out_bits[offs[reps] + j] = ((z[reps] >> (L[reps] - 1 - j).astype(np.uint64)) & np.uint64(1)).astype(np.uint8)
    payload = bw.tobytes() + np.packbits(out_bits).tobytes()
    return payload, {"n": int(n), "nblk": int(zb.shape[0]), "bits": total}


def fl_decode(payload: bytes, header: dict) -> np.ndarray:
    n, nblk = header["n"], header["nblk"]
    bw = np.frombuffer(payload[:nblk], np.uint8)
    bits = np.unpackbits(np.frombuffer(payload[nblk:], np.uint8), count=header["bits"]).astype(np.uint64)
    lens = np.repeat(bw.astype(np.int64), BLK)
    offs = np.cumsum(lens) - lens
    z = np.zeros(nblk * BLK, np.uint64)
    maxw = int(bw.max()) if nblk else 0
    for w in range(1, maxw + 1):
        sel = np.flatnonzero(lens == w)
        if sel.size == 0:
            continue
        acc = np.zeros(sel.size, np.uint64)
        for j in range(w):
            acc = (acc << np.uint64(1)) | bits[offs[sel] + j]
        z[sel] = acc
    zz = z[:n]
    return ((zz >> np.uint64(1)).astype(np.int64) ^ -(zz & np.uint64(1)).astype(np.int64)).astype(np.int32)
