"""Device-resident lossless encoding engine (jit/Pallas stage kernels).

Every numpy stage in this package is a *reference implementation*; this
module gives the hot ones a jit-compiled device twin with a **bit-identity
contract**: for the same input stream, ``<stage>_encode_device`` returns a
payload byte-for-byte equal to the numpy encoder's, so device-encoded
sections drop into existing containers (golden v1/v2/v3 fixtures included)
and a sharded writer and a single-host writer stay interchangeable.

The shape of each kernel follows the GPU literature the paper builds on
(cuSZ's two-phase Huffman, FZ-GPU's fused shuffle-and-encode):

* **hf** — frequencies come from :func:`histogram256_device` (the Pallas
  histogram256 kernel on TPU; on the host-backed CPU device a symbol-pair
  bincount over the same memory); the 256-leaf canonical codebook is
  O(256 log 256) scalar work and stays on host
  (:func:`repro.core.lossless.huffman.code_lengths`); emission is two
  fused jits: a pair-table gather + per-chunk exclusive prefix-sum bit
  offsets producing per-pair 32-bit word contributions, then a
  prefix-sum/boundary-gather reduction into the big-endian word stream —
  the same arithmetic as the numpy encoder, so the bitstream is
  identical.
* **rre/rze** — flag computation and MSB-first bitmap packing run on
  device; the kept-symbol compaction is a device row-gather addressed by
  the flag positions; only the packed bitmap (1/8k of the stream) crosses
  to host for the tiny recursive-bitmap recursion and header assembly.
* **bit1** — the plane shuffle runs through the Pallas bitshuffle kernel
  on TPU and a jnp twin elsewhere (identical bit layout either way).
* **tcms** — bytewise sign-magnitude bijection, one fused ``where``.

Inputs are taken as ``jax.Array`` uint8 streams and payloads are returned
as *device* uint8 arrays (plus the usual host header dict), so a pipeline
of device-capable stages chains without the stream ever visiting host —
:func:`repro.core.lossless.pipelines.encode` uses exactly that fast path.
Beyond encoded bytes, only flag bits (n/16 bytes for Huffman word
boundaries, n/8k for rre bitmaps) and O(1) scalars sync per stage —
XLA:CPU scatters run an order of magnitude behind its gathers, so the
staircase inversions those flags feed (``flatnonzero``) ride the host.

Compilation is keyed on padded shapes: streams are padded to the stage's
natural grid (Huffman chunks, 8192-symbol buckets for rre/rze/tcms,
shuffle blocks for bit1), so nearby lengths share a compiled kernel
instead of recompiling per byte count. Huffman additionally splits
>2^26-symbol streams into chunk-aligned slabs, keeping every bit cursor
inside u32 (the same slab trick — and the same byte-exact concatenation —
as the threaded numpy encoder).

Decode is symmetric: every encoder here has a ``<stage>_decode_device``
twin under the same bit-identity contract, so the read path
(:func:`repro.core.lossless.pipelines.decode` with ``device=True``, and
``Compressor.decompress`` above it) keeps the stream device-resident from
payload bytes to reconstructed field. Huffman decodes all chunks in
parallel by gathering against the per-chunk byte-offset table the encoder
emits into the section header (``"offs"``, a small versioned extension);
legacy headers without it — and any stream a twin can't handle — fall
back to the numpy reference decoder and re-upload, bit-identically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import huffman as _hf
from . import rre as _rre

_U31 = jnp.uint32(31)
_SYM_PAD = 8192        # rre/rze/tcms row-padding granularity (bounds recompiles)
_SLAB_CHUNKS = 1 << 16  # 2^26 symbols per hf slab: bit cursors stay in u32
_BIT1_BLOCK = 8192      # host bitshuffle.BLOCK — the layout the payload pins


def is_device(x) -> bool:
    """True for jax device arrays (the fast-path trigger); numpy is host."""
    return isinstance(x, jax.Array) and not isinstance(x, np.ndarray)


def as_device_u8(x) -> jax.Array:
    """Flat uint8 device view of ``x`` (cast, like ``ascontiguousarray``).

    Accepts device arrays, numpy arrays, and raw bytes-like payloads
    (bytes / bytearray / memoryview) — the decode twins take whatever the
    pipeline stream hands them.
    """
    if isinstance(x, (bytes, bytearray, memoryview)):
        x = np.frombuffer(x, np.uint8)
    arr = x if is_device(x) else jnp.asarray(np.ascontiguousarray(x))
    if arr.dtype != jnp.uint8:
        arr = arr.astype(jnp.uint8)
    return arr.reshape(-1)


def _host_u8(x) -> np.ndarray:
    """Flat uint8 *host* view of a payload (zero-copy where possible)."""
    if is_device(x):
        return np.asarray(x, np.uint8).reshape(-1)
    if isinstance(x, np.ndarray):
        return np.ascontiguousarray(x).view(np.uint8).reshape(-1)
    return np.frombuffer(x, np.uint8)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------- histogram
def histogram256_device(data) -> np.ndarray:
    """Exact 256-bin counts of a uint8 stream (host ``np.int64``).

    Compiled on TPU this is the Pallas histogram256 kernel (one-hot
    contraction per tile); on the CPU backend, device memory IS host
    memory (``np.asarray`` is zero-copy), so the counts come from a
    symbol-PAIR ``np.bincount`` over the u16 view folded back to 256 bins
    — ~6x faster than a byte-wise bincount because it halves the element
    count fed through numpy's index conversion. Counts equal
    ``np.bincount`` exactly (they are integers), which is what keeps the
    orchestrator's pipeline choice identical between host and device
    paths.
    """
    d = as_device_u8(data)
    if _on_tpu():
        from repro.kernels.histogram.histogram import TILE, histogram256_raw

        pad = (-d.size) % TILE
        if pad:
            d = jnp.concatenate([d, jnp.zeros(pad, jnp.uint8)])
        hist = histogram256_raw(d, False)
        if pad:
            hist = hist.at[0].add(-pad)
        return np.asarray(hist, np.int64)
    dn = np.asarray(d)
    n2 = dn.size & ~1
    if n2 >= (2 << 20):  # split across the shared pool like huffman.encode
        from .huffman import _executor

        k = (n2 // 2) & ~1
        parts = list(_executor().map(_hist_pairs_np, (dn[:k], dn[k:n2])))
        hist = parts[0] + parts[1]
    else:
        hist = _hist_pairs_np(dn[:n2]) if n2 else np.zeros(256, np.int64)
    if dn.size != n2:
        hist = hist.copy()
        hist[dn[-1]] += 1
    return hist.astype(np.int64)


# ----------------------------------------------------------------------- hf
#
# The emission is the two-phase GPU Huffman recast for XLA: phase A is a
# fused gather/scan kernel producing per-pair word contributions and
# per-chunk sizes; phase B reduces contributions into the 32-bit big-endian
# word stream with gathers against an *exclusive prefix sum* — the same
# cumsum-and-boundary-gather identity as the numpy `_segment_sum`, chosen
# because XLA:CPU scatters are an order of magnitude slower than its
# gathers. The word-boundary table (`bounds[j]` = first pair whose bits
# start in word j) rides a small host assist: pair starts are at most 32
# bits apart inside a chunk, so every word contains a pair start and the
# boundary flags — 1 bit per pair — are simply `flatnonzero`'d on host
# (plus a rare one-word-skip repair at byte-aligned chunk seams, detected
# from per-chunk scalars). Only those flags (n/16 bytes) and O(nck)
# scalars cross to host mid-encode.

def _pair_tables(lens: np.ndarray, codes: np.ndarray) -> np.ndarray:
    """(65536, 2) per-symbol-PAIR merge table: [v2, l2] rows.

    Indexed by the little-endian u16 view of two adjacent stream bytes, so
    the whole reduce-merge becomes ONE row gather (gather cost on XLA:CPU
    is index-bound, so fetching both fields per index beats two gathers).
    512 KiB, built once per codebook with vectorized numpy.
    """
    i = np.arange(65536, dtype=np.uint32)
    s0, s1 = i & 255, i >> 8
    l0, l1 = lens[s0].astype(np.uint32), lens[s1].astype(np.uint32)
    tblv = (codes[s0] << l1) | codes[s1]
    # i32 lanes throughout (XLA:CPU scalarizes u8/u16 arithmetic)
    return np.stack([tblv.view(np.int32), (l0 + l1).astype(np.int32)], axis=1)


@jax.jit
def _hf_emit_a(dp: jax.Array, tblc: jax.Array):
    """Phase A over full chunks (no pad lanes): per-pair contributions.

    Returns the pair values `v2`, their in-word contributions `hi`, the
    shift state `sh` (phase B rebuilds the rare spill words from v2/sh by
    gather instead of materializing a full `lo` array), `first`
    word-boundary flags, per-chunk payload bytes, chunk byte offsets, and
    each chunk's last pair-start word (for the seam-skip repair).
    """
    m = dp.shape[0]
    nck = m // _hf.CHUNK
    half = _hf.CHUNK // 2
    dpair = jax.lax.bitcast_convert_type(dp.reshape(-1, 2), jnp.uint16)
    idx = dpair.astype(jnp.int32)
    pair = tblc[idx]  # (npairs, 2) i32 rows: [v2, l2]
    v2 = jax.lax.bitcast_convert_type(pair[:, 0], jnp.uint32)
    l2 = pair[:, 1]
    # per-chunk bit offsets from the pair-length prefix sum (sums < 2^14);
    # 16-wide two-level scan keeps the sequential pass count low
    l2c = l2.reshape(nck, half)
    c16 = jnp.cumsum(l2c.reshape(nck, half // 16, 16), axis=2)
    blk = jnp.cumsum(c16[:, :, -1], axis=1)
    boff = jnp.concatenate([jnp.zeros((nck, 1), jnp.int32), blk[:, :-1]], axis=1)
    cum2 = (c16 + boff[:, :, None]).reshape(nck, half)
    chunk_bytes = (cum2[:, -1] + 7) >> 3
    byte_off = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(chunk_bytes)])
    within = cum2 - l2c  # exclusive bit offset of each pair in its chunk
    # bitpos = within + byte_off*8; only (bitpos & 31) and (bitpos >> 5)
    # are needed, and both split into chunk-scalar + lane arithmetic
    base8 = ((byte_off[:-1] & 3) << 3)[:, None]
    sh = (((within + base8) & 31) + l2c).reshape(-1)  # <= 63
    sh32 = sh.astype(jnp.uint32)
    lo = v2 << ((jnp.uint32(0) - sh32) & _U31)
    hi = jnp.where(sh > 32, v2 >> (sh32 & _U31), lo)
    # word-boundary flags: pair i starts a new word iff pair i-1 ran to or
    # past its word's end (sh >= 32; valid because full-chunk pairs always
    # have l2 >= 2). Chunk seams reset the recurrence and are repaired
    # with an nck-sized scatter against the previous chunk's last word.
    wstart = byte_off[:-1] >> 2
    last_w = wstart + ((within[:, -1] + base8[:, 0]) >> 5)
    seam = jnp.concatenate([jnp.ones(1, bool), wstart[1:] > last_w[:-1]])
    first = jnp.concatenate([jnp.ones(1, bool), sh[:-1] >= 32])
    first = first.at[jnp.arange(nck) * half].set(seam)
    return v2, hi, sh.astype(jnp.uint16), first, chunk_bytes, byte_off, last_w


@jax.jit
def _hf_emit_b(v2, hi, sh, bounds, bad, chunk_bytes):
    """Phase B: word stream from contributions + boundary table.

    ``bounds``: (alloc+1,) i32, first-pair index per word (alloc >= words
    used; tail entries = npairs). ``bad``: words that must NOT take the
    spill of pair ``bounds[j]-1`` (the word after a seam skip), padded
    with out-of-range indices. Word j = sum of hi over its pairs (disjoint
    bits, so sum == OR) | the spill of the last pair of word j-1 — the
    spill is a sparse gather from (v2, sh), never a dense array. Returns
    (bits bytes padded to the word allocation, chunk-size u16 bytes).
    """
    c16 = jnp.cumsum(hi.reshape(-1, 16), axis=1)
    blko = jnp.concatenate([jnp.zeros(1, jnp.uint32), jnp.cumsum(c16[:, -1])[:-1]])
    csum = (c16 + blko[:, None]).reshape(-1)  # inclusive prefix sum of hi

    b = bounds
    bm1 = jnp.maximum(b - 1, 0)
    g = jnp.where(b > 0, csum[bm1], jnp.uint32(0))  # exclusive sum at b
    words = g[1:] - g[:-1]
    p = bm1[:-1]
    shp = sh[p].astype(jnp.uint32)
    lop = jnp.where(shp > 32, v2[p] << ((jnp.uint32(0) - shp) & _U31), jnp.uint32(0))
    sp = jnp.where(b[:-1] > 0, lop, jnp.uint32(0))
    sp = sp.at[bad].set(jnp.uint32(0), mode="drop")
    words = words | sp
    # big-endian byte order fused into the same pass as the reduction
    wbe = (
        ((words & 0xFF) << 24)
        | ((words & 0xFF00) << 8)
        | ((words >> 8) & 0xFF00)
        | (words >> 24)
    )
    bits = jax.lax.bitcast_convert_type(wbe, jnp.uint8).reshape(-1)
    cb = jax.lax.bitcast_convert_type(
        chunk_bytes.astype(jnp.uint16), jnp.uint8
    ).reshape(-1)
    return bits, cb


def _slab_bridge(emit_a_out, m: int):
    """Host assist + phase-B dispatch for one slab's phase-A outputs.

    Builds the word-boundary table from the flag bits and the per-chunk
    scalars (see the section comment); the ``np.asarray`` pulls block on
    this slab's phase A only, so other slabs' device work keeps running.
    """
    nck = m // _hf.CHUNK
    v2, hi, sh, first, chunk_bytes, byte_off, last_w = emit_a_out
    firsts = np.flatnonzero(np.asarray(first)).astype(np.int32)
    bo = np.asarray(byte_off)
    lws = np.asarray(last_w)
    total = int(bo[-1])
    nwords = (total + 3) >> 2
    # seam skips: chunk payloads are byte- (not word-) aligned, so the gap
    # between the last pair start of chunk c-1 and the first of chunk c can
    # reach 39 bits and hop over one word entirely
    fw = (bo[:-1] >> 2).astype(np.int64)
    skip_mask = fw[1:] >= lws[:-1].astype(np.int64) + 2
    skip_words = fw[1:][skip_mask] - 1
    ins = (skip_words - np.arange(skip_words.size)).astype(np.int64)
    bounds_core = np.insert(firsts, ins, firsts[ins]) if ins.size else firsts
    # bucketed word allocation: jit shapes recompile per bucket, not per byte
    nw = m // 2
    wbucket = max(nw // 8, 4096)
    alloc = min(-(-max(nwords, 1) // wbucket) * wbucket, nw)
    bounds = np.empty(alloc + 1, np.int32)
    bounds[: bounds_core.size] = bounds_core
    bounds[bounds_core.size :] = nw
    bad = np.full(max(nck, 1), alloc + 1, np.int32)  # out of range: dropped
    bad[: skip_words.size] = (skip_words + 1).astype(np.int32)
    bits, cb = _hf_emit_b(v2, hi, sh, jnp.asarray(bounds), jnp.asarray(bad), chunk_bytes)
    return bits[:total], cb


def _hist_pairs_np(dn: np.ndarray) -> np.ndarray:
    c = np.bincount(dn.view(np.uint16), minlength=65536).reshape(256, 256)
    return c.sum(axis=0) + c.sum(axis=1)


_PAR_SLAB = 1 << 21  # symbols per thread-parallel slab on the CPU backend


def hf_encode_device(data):
    """Device Huffman encode; payload bytes == ``huffman.encode``'s.

    Streams larger than ``_PAR_SLAB`` split into chunk-aligned slabs whose
    phase-A kernels are all dispatched before any bridge blocks — XLA
    drains the queue asynchronously, so slab i's host assist hides behind
    slab i+1's device work. Slab payloads concatenate byte-exactly (the
    same chunk-aligned-split property the threaded numpy encoder relies
    on), and each slab's bit cursors stay inside u32.
    """
    d = as_device_u8(data)
    n = int(d.size)
    hist = histogram256_device(d)
    lens = _hf.code_lengths(hist)
    codes, lens, *_ = _hf.canonical_codes(lens)
    tbl_np = (lens.astype(np.uint32) << np.uint32(16)) | codes
    n_full = (n // _hf.CHUNK) * _hf.CHUNK
    cb_parts, bit_parts = [], []
    if n_full:
        tblc = jnp.asarray(_pair_tables(lens, codes))
        slab_syms = min(_PAR_SLAB, _SLAB_CHUNKS * _hf.CHUNK)  # u32 cursors
        slab_syms = max(slab_syms - slab_syms % _hf.CHUNK, _hf.CHUNK)  # chunk-aligned
        cuts = list(range(0, n_full, slab_syms)) + [n_full]
        # dispatch every slab's phase A up front — XLA executes the queue
        # concurrently, so slab i's host bridge hides behind slab i+1's
        # device work (the async twin of the numpy encoder's thread slabs)
        outs = [(_hf_emit_a(d[a:b], tblc), b - a) for a, b in zip(cuts, cuts[1:])]
        for out, m in outs:
            bits, cb = _slab_bridge(out, m)
            cb_parts.append(cb)
            bit_parts.append(bits)
    if n > n_full or n == 0:  # partial/empty tail chunk: reference encoder
        tail_bits, tail_cb = _hf._encode_slab(np.asarray(d[n_full:]), tbl_np)
        cb_parts.append(jnp.asarray(np.frombuffer(tail_cb.tobytes(), np.uint8)))
        bit_parts.append(jnp.asarray(np.frombuffer(tail_bits, np.uint8)))
    payload = jnp.concatenate([jnp.asarray(lens)] + cb_parts + bit_parts)
    chunk_bytes = np.concatenate([np.asarray(p) for p in cb_parts]).view("<u2")
    return payload, dict({"n": n}, **_hf.offset_table(chunk_bytes))


# hf decode limits: past these the twin hands the stream to the numpy
# reference decoder (which slabs/groups internally) and re-uploads.
_HF_DEC_MAX_BYTES = _hf._DECODE_GROUP_BYTES


@functools.partial(jax.jit, static_argnums=(3,))
def _hf_dec(be: jax.Array, cursors: jax.Array, lut: jax.Array, maxlen: int):
    """All chunks decode in lockstep: one lane per chunk, CHUNK/2 steps.

    ``be``: the bitstream as big-endian u32 words (padded). ``cursors``:
    per-lane absolute *bit* cursors (u32, from the header's byte-offset
    table ×8). Each step peeks 32 bits straddling a word boundary and
    resolves TWO symbols through the (len<<8|sym) prefix LUT — the same
    double-symbol peek as the numpy ``_span_pairs`` hot loop, so lane c
    step t yields exactly symbol ``c*CHUNK + 2t``. Everything stays u32
    (x64 is off; mixed-width promotion would upcast). Out-of-range word
    gathers clamp (jnp default), which only feeds garbage to lanes that
    are past their chunk's real symbol count — trimmed by the caller.
    """
    beS1 = jnp.concatenate([be[1:], jnp.zeros(1, jnp.uint32)]) >> 1
    shift = jnp.uint32(32 - maxlen)

    def step(cur, _):
        q = cur >> 5
        r = cur & _U31
        peek = (be[q] << r) | (beS1[q] >> (_U31 - r))
        e1 = lut[peek >> shift]
        ls1 = e1 >> 8
        e2 = lut[(peek << ls1) >> shift]
        return cur + ls1 + (e2 >> 8), jnp.stack([e1, e2]).astype(jnp.uint8)

    _, sym = jax.lax.scan(step, cursors, None, length=_hf.CHUNK // 2)
    # (CHUNK/2 steps, 2 syms, lanes) -> (CHUNK, lanes)
    return sym.reshape(_hf.CHUNK, -1)


def hf_decode_device(payload, header: dict):
    """Device Huffman decode; bytes == ``huffman.decode``'s.

    Needs the per-chunk byte-offset table (``header["offs"]``) to give
    every chunk lane an independent bit cursor; legacy headers (no table,
    or hex ``"lens"`` streams), oversized payloads, and >16-bit codebooks
    decode through the host reference path and re-upload.
    """
    n = int(header["n"])
    if n == 0:
        return jnp.zeros(0, jnp.uint8)
    offs = header.get("offs")
    nchunks = -(-n // _hf.CHUNK)
    usable = (
        offs is not None
        and "lens" not in header
        and len(offs) == 4 * nchunks
    )
    if usable:
        src = payload if is_device(payload) else None
        hp = None if src is not None else _host_u8(payload)
        lens = np.asarray(src[:256]) if src is not None else hp[:256]
        maxlen = int(lens.max(initial=0))
        total = (int(src.size) if src is not None else hp.size) - 256 - 2 * nchunks
        usable = 0 < maxlen <= _hf.MAXLEN and 0 <= total <= _HF_DEC_MAX_BYTES
    if not usable:
        return jnp.asarray(_hf.decode(_host_u8(payload), header))
    _, lens_c, first_code, sym_table, offsets, counts = _hf.canonical_codes(
        lens.astype(np.uint8)
    )
    lut = jnp.asarray(
        _hf._pair_lut(first_code, counts, sym_table, offsets, maxlen).astype(np.uint32)
    )
    bits0 = 256 + 2 * nchunks
    bits = src[bits0:] if src is not None else jnp.asarray(hp[bits0:])
    # pow2-bucketed word allocation: +8 bytes slack like the numpy _be32,
    # padded with zeros so garbage lanes read zeros, not uninitialized mem
    balloc = max(4096, 1 << (total + 8 - 1).bit_length())
    bits = jnp.concatenate([bits, jnp.zeros(balloc - total, jnp.uint8)])
    w = bits.reshape(-1, 4).astype(jnp.uint32)
    be = (w[:, 0] << 24) | (w[:, 1] << 16) | (w[:, 2] << 8) | w[:, 3]
    byte_off = np.frombuffer(offs, "<u4")
    calloc = max(64, 1 << (nchunks - 1).bit_length())
    cur = np.zeros(calloc, np.uint32)
    cur[:nchunks] = byte_off * np.uint32(8)
    out_t = _hf_dec(be, jnp.asarray(cur), lut, maxlen)
    return out_t[:, :nchunks].T.reshape(-1)[:n]


# ------------------------------------------------------------------ rre/rze
@functools.partial(jax.jit, static_argnums=(2,))
def _rr_flags(viewp: jax.Array, nsym: jax.Array, zero_mode: bool):
    """Flags + packed bitmap for RRE (``zero_mode=False``) / RZE.

    ``viewp``: (nsym_p, k) u8 rows, nsym_p % 8 == 0, rows past ``nsym``
    zero. Returns (flags, MSB-first packed bitmap over nsym_p flags).
    """
    nsym_p = viewp.shape[0]
    v32 = viewp.astype(jnp.int32)  # i32 lanes: XLA:CPU scalarizes u8 math
    if zero_mode:
        flags = (v32 != 0).any(axis=1)
    else:
        flags = jnp.concatenate(
            [jnp.ones(1, bool), (v32[1:] != v32[:-1]).any(axis=1)]
        )
    flags = flags & (jnp.arange(nsym_p) < nsym)
    # MSB-first bit packing (np.packbits layout)
    wts = jnp.left_shift(jnp.int32(1), 7 - jax.lax.iota(jnp.int32, 8))
    bitmap = (flags.reshape(-1, 8) * wts).sum(axis=1).astype(jnp.uint8)
    return flags, bitmap


@jax.jit
def _rr_gather(viewp: jax.Array, idx: jax.Array):
    return viewp[idx]


def _rr_encode_device(data, k: int, zero_mode: bool):
    d = as_device_u8(data)
    n = int(d.size)
    nsym = -(-n // k)
    if nsym == 0:
        z = np.zeros(0, np.uint8)
        payload, header = _rre._serialize(z, [], [], z, n, k, 0)
        return jnp.asarray(np.frombuffer(payload, np.uint8)), header
    nsym_p = -(-nsym // _SYM_PAD) * _SYM_PAD  # row bucket: bounds recompiles
    pad = nsym_p * k - n
    if pad:
        d = jnp.concatenate([d, jnp.zeros(pad, jnp.uint8)])
    viewp = d.reshape(nsym_p, k)
    flags, bitmap_p = _rr_flags(viewp, jnp.int32(nsym), zero_mode)
    # kept-row compaction: the scan's output indices are the flag
    # positions; flatnonzero rides the host (XLA:CPU scatters are slow,
    # its gathers are not), the row gather stays on device
    kept_idx = np.flatnonzero(np.asarray(flags))
    count = int(kept_idx.size)
    alloc = max(-(-count // _SYM_PAD) * _SYM_PAD, _SYM_PAD)
    idx = np.zeros(alloc, np.int32)
    idx[:count] = kept_idx
    kept_p = _rr_gather(viewp, jnp.asarray(idx))
    # the packed bitmap (1/8k of the stream) is all the host recursion needs
    bitmap = np.asarray(bitmap_p)[: (nsym + 7) // 8]
    top, levels, sizes = _rre._compress_bitmap(bitmap)
    header = {"n": n, "k": k, "nsym": nsym}
    meta = (
        np.asarray([top.size, len(levels)], "<u2").tobytes()
        + np.asarray(list(sizes) + [lv.size for lv in levels], "<u8").tobytes()
    )
    head = meta + top.tobytes() + b"".join(lv.tobytes() for lv in levels)
    payload = jnp.concatenate(
        [jnp.asarray(np.frombuffer(head, np.uint8)), kept_p[:count].reshape(-1)]
    )
    return payload, header


def rre_encode_device(data, k: int):
    """Device RRE-k; payload bytes == ``rre.rre_encode``'s."""
    return _rr_encode_device(data, k, zero_mode=False)


def rze_encode_device(data, k: int):
    """Device RZE-k; payload bytes == ``rre.rze_encode``'s."""
    return _rr_encode_device(data, k, zero_mode=True)


@functools.partial(jax.jit, static_argnums=(2,))
def _rr_expand(bitmap: jax.Array, kept: jax.Array, zero_mode: bool):
    """Inverse of flags+compaction: expand kept rows back over all symbols.

    ``bitmap``: packed MSB-first flags (padded, pad bits zero). ``kept``:
    (alloc, k) rows, rows past the real count zero. RRE replays row
    ``cumsum(flags)-1`` everywhere (run expansion); RZE gathers the same
    but zeroes unflagged rows. A gather, not a scatter — XLA:CPU scatters
    run an order of magnitude behind its gathers (same trade as encode).
    """
    shifts = 7 - jax.lax.iota(jnp.int32, 8)
    bits = ((bitmap.astype(jnp.int32)[:, None] >> shifts) & 1).reshape(-1)
    idx = jnp.cumsum(bits) - 1
    rows = kept[jnp.maximum(idx, 0)]
    if zero_mode:
        rows = jnp.where((bits == 1)[:, None], rows, jnp.uint8(0))
    return rows


def _rr_decode_device(payload, header: dict, zero_mode: bool):
    """Shared RRE/RZE device decode; bytes == the numpy decoders'."""
    n, k, nsym = int(header["n"]), int(header["k"]), int(header["nsym"])
    if nsym == 0:
        return jnp.zeros(0, jnp.uint8)
    if "top" in header:  # legacy hex-in-JSON header: host reference path
        dec = _rre.rze_decode if zero_mode else _rre.rre_decode
        return jnp.asarray(dec(_host_u8(payload).tobytes(), header))
    src = payload if is_device(payload) else None
    hp = None if src is not None else _host_u8(payload)

    def pull(a, b):
        return np.asarray(src[a:b]) if src is not None else hp[a:b]

    # the recursive-bitmap metadata is tiny (1/8k of the stream): pull it
    # to host for the level recursion, keep the kept rows device-side
    top_len, n_levels = (int(v) for v in np.frombuffer(pull(0, 4), "<u2"))
    off = 4 + 8 * 2 * n_levels
    szs = np.frombuffer(pull(4, off), "<u8")
    sizes = [int(s) for s in szs[:n_levels]]
    lvl_sizes = [int(s) for s in szs[n_levels:]]
    top = pull(off, off + top_len)
    off += top_len
    levels = []
    for ls in lvl_sizes:
        levels.append(pull(off, off + ls))
        off += ls
    bitmap = _rre._decompress_bitmap(top, levels, sizes)
    count = int(np.unpackbits(bitmap, count=nsym).sum())
    kept = src[off:] if src is not None else jnp.asarray(hp[off:])
    # bucketed allocations (pad rows/bits zero) bound recompiles
    nsym_p = -(-nsym // _SYM_PAD) * _SYM_PAD
    bm = np.zeros(nsym_p // 8, np.uint8)
    bm[: bitmap.size] = bitmap
    alloc = max(-(-count // _SYM_PAD) * _SYM_PAD, _SYM_PAD)
    kept_p = jnp.concatenate(
        [kept, jnp.zeros(alloc * k - count * k, jnp.uint8)]
    ).reshape(alloc, k)
    rows = _rr_expand(jnp.asarray(bm), kept_p, zero_mode)
    return rows.reshape(-1)[:n]


def rre_decode_device(payload, header: dict):
    """Device RRE-k decode; bytes == ``rre.rre_decode``'s."""
    return _rr_decode_device(payload, header, zero_mode=False)


def rze_decode_device(payload, header: dict):
    """Device RZE-k decode; bytes == ``rre.rze_decode``'s."""
    return _rr_decode_device(payload, header, zero_mode=True)


# --------------------------------------------------------------------- tcms
@jax.jit
def _tcms_core(viewp: jax.Array) -> jax.Array:
    """Bytewise two's-complement -> sign-magnitude over little-endian rows."""
    v = viewp.astype(jnp.int32)  # i32 lanes; ~x bytewise == 255 - x
    neg = (v[:, -1] & 0x80) != 0
    out = jnp.where(neg[:, None], 255 - v, v)
    out = out.at[:, -1].set(jnp.where(neg, out[:, -1] ^ 0x80, out[:, -1]))
    return out.astype(jnp.uint8)


def tcms_encode_device(data, k: int):
    """Device TCMS-k; payload bytes == ``tcms.tcms_encode``'s."""
    d = as_device_u8(data)
    n = int(d.size)
    nsym = -(-n // k) if n else 0
    nsym_p = max(-(-nsym // _SYM_PAD) * _SYM_PAD, _SYM_PAD)
    pad = nsym_p * k - n
    if pad:
        d = jnp.concatenate([d, jnp.zeros(pad, jnp.uint8)])
    out = _tcms_core(d.reshape(nsym_p, k))[:nsym]
    return out.reshape(-1), {"n": n, "k": k}


@jax.jit
def _tcms_inv_core(viewp: jax.Array) -> jax.Array:
    """Inverse bijection: numpy's ``~(x ^ msb)`` done bytewise on rows."""
    v = viewp.astype(jnp.int32)
    neg = (v[:, -1] & 0x80) != 0  # little-endian rows: last byte is the MSB
    w = v.at[:, -1].set(v[:, -1] ^ 0x80)  # x ^ msb
    out = jnp.where(neg[:, None], 255 - w, v)  # ~y bytewise == 255 - y
    return out.astype(jnp.uint8)


def tcms_decode_device(payload, header: dict):
    """Device TCMS-k decode; bytes == ``tcms.tcms_decode``'s."""
    n, k = int(header["n"]), int(header["k"])
    if n == 0:
        return jnp.zeros(0, jnp.uint8)
    d = as_device_u8(payload)
    nsym = -(-n // k)
    nsym_p = max(-(-nsym // _SYM_PAD) * _SYM_PAD, _SYM_PAD)
    pad = nsym_p * k - int(d.size)
    if pad:
        d = jnp.concatenate([d, jnp.zeros(pad, jnp.uint8)])
    out = _tcms_inv_core(d.reshape(nsym_p, k))[:nsym]
    return out.reshape(-1)[:n]


# --------------------------------------------------------------------- bit1
@jax.jit
def _bit1_core(arr: jax.Array) -> jax.Array:
    """jnp twin of the bitshuffle plane transpose (np.packbits bit layout)."""
    nb, block = arr.shape
    shifts = (7 - jnp.arange(8, dtype=jnp.uint8))[None, :, None]
    bits = (arr[:, None, :] >> shifts) & 1  # (nb, 8, block) u8
    g = bits.reshape(nb, 8, block // 8, 8)
    w = jnp.left_shift(jnp.int32(1), 7 - jax.lax.iota(jnp.int32, 8))
    packed = jnp.einsum("npgb,b->npg", g, w, preferred_element_type=jnp.int32)
    return packed.reshape(nb, block).astype(jnp.uint8)


def bit1_encode_device(data, block: int = _BIT1_BLOCK):
    """Device BIT1; payload bytes == ``bitshuffle.bitshuffle_encode``'s.

    Compiled on TPU this is the Pallas bitshuffle kernel; elsewhere the jnp
    twin (same arithmetic, no interpret-mode overhead). Both produce the
    host encoder's 8192-byte-block plane layout.
    """
    d = as_device_u8(data)
    n = int(d.size)
    if n == 0:
        return jnp.zeros(0, jnp.uint8), {"n": 0, "block": int(block)}
    pad = (-n) % block
    if pad:
        d = jnp.concatenate([d, jnp.zeros(pad, jnp.uint8)])
    arr = d.reshape(-1, block)
    if _on_tpu():
        from repro.kernels.bitshuffle.bitshuffle import bitshuffle_pallas_raw

        planes = bitshuffle_pallas_raw(arr, False, tile_blocks=1)
    else:
        planes = _bit1_core(arr)
    return planes.reshape(-1), {"n": n, "block": int(block)}


@jax.jit
def _bit1_inv_core(arr: jax.Array) -> jax.Array:
    """jnp twin of the bitshuffle inverse (plane rows -> original bytes)."""
    nb, block = arr.shape
    shifts = (7 - jnp.arange(8, dtype=jnp.uint8))[None, None, :]
    # payload byte (plane p, group q) holds bit p of bytes 8q..8q+7
    bits = ((arr.reshape(nb, 8, block // 8)[:, :, :, None] >> shifts) & 1).reshape(
        nb, 8, block
    )
    w = jnp.left_shift(jnp.int32(1), 7 - jax.lax.iota(jnp.int32, 8))
    out = jnp.einsum("npq,p->nq", bits, w, preferred_element_type=jnp.int32)
    return out.astype(jnp.uint8)


def bit1_decode_device(payload, header: dict):
    """Device BIT1 decode; bytes == ``bitshuffle.bitshuffle_decode``'s.

    Pallas inverse kernel on TPU, the jnp twin elsewhere — same bit layout
    either way.
    """
    n, block = int(header["n"]), int(header["block"])
    if n == 0:
        return jnp.zeros(0, jnp.uint8)
    arr = as_device_u8(payload).reshape(-1, block)
    if _on_tpu():
        from repro.kernels.bitshuffle.bitshuffle import bitunshuffle_pallas_raw

        out = bitunshuffle_pallas_raw(arr, False, tile_blocks=1)
    else:
        out = _bit1_inv_core(arr)
    return out.reshape(-1)[:n]
