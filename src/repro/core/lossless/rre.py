"""RRE-k / RZE-k reducing stages with recursive bitmap compression (§5.2.3).

RREk: view the stream as k-byte symbols; a bitmap marks (1) symbols that
differ from their predecessor; marked symbols are kept, repeats dropped.
RZEk: same, but the bitmap marks non-zero symbols and zeros are dropped.
The bitmap itself is compressed recursively: non-zero bitmap *bytes* are
kept and indexed by a parent bitmap, until the top level is tiny.
"""
from __future__ import annotations

import numpy as np

_BITMAP_FLOOR = 64  # stop recursing below this many bytes


def _compress_bitmap(bits: np.ndarray):
    """bits: packed uint8 bitmap. Returns (top_bytes, [level_kept...], sizes)."""
    levels = []
    sizes = []
    cur = bits
    while cur.size > _BITMAP_FLOOR:
        nz = cur != 0
        kept = cur[nz]
        levels.append(kept)
        sizes.append(int(cur.size))
        cur = np.packbits(nz)
    return cur, levels[::-1], sizes[::-1]


def _decompress_bitmap(top: np.ndarray, levels, sizes):
    cur = top
    for kept, size in zip(levels, sizes):
        nz = np.unpackbits(cur, count=size).astype(bool)
        out = np.zeros(size, np.uint8)
        out[nz] = kept
        cur = out
    return cur


def _pack_kbytes(data: np.ndarray, k: int):
    n = data.size
    pad = (-n) % k
    if pad:
        data = np.concatenate([data, np.zeros(pad, np.uint8)])
    return data.reshape(-1, k), n


def _serialize(top, levels, sizes, kept: np.ndarray, n_orig: int, k: int, nsym: int):
    """Compact binary layout: the recursive-bitmap metadata (top bytes +
    per-level sizes) rides inside the payload, keeping the JSON header to
    three integers.

    payload = [u16 top_len][u16 n_levels][u64 sizes...][u64 lvl_sizes...]
              [top][levels...][kept]
    """
    header = {"n": int(n_orig), "k": int(k), "nsym": int(nsym)}
    meta = (
        np.asarray([top.size, len(levels)], "<u2").tobytes()
        + np.asarray(list(sizes) + [l.size for l in levels], "<u8").tobytes()
    )
    payload = b"".join([meta, top.tobytes()] + [l.tobytes() for l in levels] + [kept.tobytes()])
    return payload, header


def _deserialize(payload: bytes, header: dict):
    buf = np.frombuffer(payload, np.uint8)
    if "top" in header:  # legacy hex-in-JSON header (seed containers)
        top = np.frombuffer(bytes.fromhex(header["top"]), np.uint8)
        sizes = header["sizes"]
        lvl_sizes = header["lvl_sizes"]
        off = 0
    else:
        top_len, n_levels = np.frombuffer(payload[:4], "<u2")
        off = 4 + 8 * (2 * int(n_levels))
        szs = np.frombuffer(payload[4:off], "<u8")
        sizes = [int(s) for s in szs[: int(n_levels)]]
        lvl_sizes = [int(s) for s in szs[int(n_levels) :]]
        top = buf[off : off + int(top_len)]
        off += int(top_len)
    levels = []
    for ls in lvl_sizes:
        levels.append(buf[off : off + ls])
        off += ls
    kept = buf[off:]
    return top, levels, sizes, kept


def rre_encode(data: np.ndarray, k: int):
    data = np.ascontiguousarray(data, np.uint8)
    view, n = _pack_kbytes(data, k)
    nsym = view.shape[0]
    if nsym == 0:
        return _serialize(np.zeros(0, np.uint8), [], [], np.zeros(0, np.uint8), n, k, 0)
    diff = np.ones(nsym, bool)
    diff[1:] = (view[1:] != view[:-1]).any(axis=1)
    kept = view[diff].reshape(-1)
    bitmap = np.packbits(diff)
    top, levels, sizes = _compress_bitmap(bitmap)
    return _serialize(top, levels, sizes, kept, n, k, nsym)


def rre_decode(payload: bytes, header: dict) -> np.ndarray:
    top, levels, sizes, kept = _deserialize(payload, header)
    n, k, nsym = header["n"], header["k"], header["nsym"]
    if nsym == 0:
        return np.zeros(0, np.uint8)
    bitmap = _decompress_bitmap(top, levels, sizes)
    diff = np.unpackbits(bitmap, count=nsym).astype(bool)
    kview = kept.reshape(-1, k)
    idx = np.cumsum(diff) - 1
    out = kview[idx].reshape(-1)[: n]
    return out


def rze_encode(data: np.ndarray, k: int):
    data = np.ascontiguousarray(data, np.uint8)
    view, n = _pack_kbytes(data, k)
    nsym = view.shape[0]
    if nsym == 0:
        return _serialize(np.zeros(0, np.uint8), [], [], np.zeros(0, np.uint8), n, k, 0)
    nz = (view != 0).any(axis=1)
    kept = view[nz].reshape(-1)
    bitmap = np.packbits(nz)
    top, levels, sizes = _compress_bitmap(bitmap)
    return _serialize(top, levels, sizes, kept, n, k, nsym)


def rze_decode(payload: bytes, header: dict) -> np.ndarray:
    top, levels, sizes, kept = _deserialize(payload, header)
    n, k, nsym = header["n"], header["k"], header["nsym"]
    if nsym == 0:
        return np.zeros(0, np.uint8)
    bitmap = _decompress_bitmap(top, levels, sizes)
    nz = np.unpackbits(bitmap, count=nsym).astype(bool)
    out = np.zeros((nsym, k), np.uint8)
    out[nz] = kept.reshape(-1, k)
    return out.reshape(-1)[: n]
