"""Workload-balanced interpolation auto-tuning (paper §5.1.3).

Uniformly samples ~0.2 % of the blocks and, level by level from the largest
stride, tests every (spline x scheme) configuration on the sampled blocks,
keeping the per-level argmin of the aggregated absolute prediction error.
The chosen config is then applied (with quantization feedback) before the
next level is tuned — mirroring the paper's per-level selection.

On the GPU the paper balances thread blocks per level; the TPU analogue is
the sample volume itself (the per-level tests here are a handful of small
batched matmuls), kept at the paper's 0.2 % budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .predictor import RADIUS, _anchor_mask, _predict
from .stencils import SCHEMES, SPLINES, build_steps

SAMPLE_FRACTION = 0.002
MIN_SAMPLE_BLOCKS = 8


@functools.partial(jax.jit, static_argnums=(3, 4))
def _level_pass(recon, orig, twoeb, steps, update: bool):
    """Run one level's steps; return (new_recon, sum |orig-pred| over targets)."""
    err = jnp.zeros((), jnp.float32)
    for step in steps:
        pred = _predict(recon, step)
        m = jnp.asarray(step.mask)
        err = err + jnp.sum(jnp.where(m, jnp.abs(orig - pred), 0.0))
        q = jnp.rint((orig - pred) / twoeb)
        outl = jnp.abs(q) > RADIUS
        rec = jnp.where(outl, orig, pred + q * twoeb)
        recon = jnp.where(m, rec, recon)
    return recon, err


def autotune(blocks: np.ndarray, twoeb: float, levels=(8, 4, 2, 1), anchor_every: int = 16, rng_seed: int = 0):
    """blocks: (nb, B..). Returns (splines, schemes) tuples, one entry per level."""
    nb = blocks.shape[0]
    ndim = blocks.ndim - 1
    B = blocks.shape[1]
    ns = max(MIN_SAMPLE_BLOCKS, int(round(SAMPLE_FRACTION * nb)))
    ns = min(ns, nb)
    idx = np.linspace(0, nb - 1, ns).astype(np.int64)  # uniform sampling (paper)
    sample = jnp.asarray(blocks[idx])
    am = jnp.asarray(_anchor_mask(sample.shape[1:], anchor_every))
    recon = jnp.where(am, sample, 0.0)
    twoeb = jnp.float32(twoeb)
    chosen_splines, chosen_schemes = [], []
    for li, s in enumerate(levels):
        best = None
        for spline in SPLINES:
            for scheme in SCHEMES:
                steps = build_steps(ndim, B, (s,), (spline,), (scheme,))
                _, err = _level_pass(recon, sample, twoeb, steps, False)
                err = float(err)
                if best is None or err < best[0]:
                    best = (err, spline, scheme)
        _, spline, scheme = best
        chosen_splines.append(spline)
        chosen_schemes.append(scheme)
        steps = build_steps(ndim, B, (s,), (spline,), (scheme,))
        recon, _ = _level_pass(recon, sample, twoeb, steps, True)
    return tuple(chosen_splines), tuple(chosen_schemes)
