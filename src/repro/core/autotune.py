"""Data-adaptive interpolation auto-tuning (paper §5.1.3) — the lossy half
of the synergistic orchestration.

Two tuners live here:

* :func:`autotune` — the legacy per-level (spline x scheme) argmin on
  aggregated absolute prediction error, kept for ``CompressorSpec(
  predictor="interp", autotune=True)`` and the ablation benchmarks.
* :func:`autotune_plan` — the full planner behind ``predictor="auto"``.
  It samples anchor blocks, trial-predicts every candidate spline
  (linear / cubic / natural-cubic) x interpolation scheme ("md" vs the
  per-dimension sequential orderings) per level with quantization
  feedback, and scores candidates by the *entropy of the quantized
  residual codes* — computed through
  :func:`repro.core.lossless.orchestrate.stream_stats`, so the lossy and
  lossless tuners share one cost model. It repeats the per-level greedy
  sweep for every candidate anchor stride and emits a
  :class:`PredictorPlan`: the stride, the per-level (spline, scheme)
  choices, and the scored alternatives for observability.

The plan serializes to a plain dict (``to_header`` / ``from_header``)
that rides the binary container v2 header via ``repro.core.serial``;
containers without a plan decode with the default cubic/md steps.

On the GPU the paper balances thread blocks per level; the TPU analogue is
the sample volume itself (each per-level trial is a handful of small
batched matmuls), kept at the paper's 0.2 % budget — except that small
fields (<= EXHAUSTIVE_BLOCKS blocks) are sampled exhaustively, which makes
the greedy per-level selection exact for the bench-suite fields.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import blocks as _blk
from .lossless import orchestrate as orc
from .lossless import pipelines as _pipelines
from .predictor import CENTER, RADIUS, _anchor_mask, _predict, quantize_pred
from .reorder import reorder_codes_batch
from .serial import pack_obj, unpack_obj
from .stencils import SCHEMES, SPLINES, build_steps

SAMPLE_FRACTION = 0.002
MIN_SAMPLE_BLOCKS = 8
EXHAUSTIVE_BLOCKS = 64       # sample everything below this block count
ANCHOR_BITS = 32             # anchors are stored as raw float32
OUTLIER_BITS = 96            # i64 index + f32 value per outlier
DEFAULT_STRIDES = (16, 8)    # candidate anchor strides for predictor="auto"


def levels_for_stride(stride: int) -> tuple[int, ...]:
    lv, s = [], stride // 2
    while s >= 1:
        lv.append(s)
        s //= 2
    return tuple(lv)


def candidate_splines() -> tuple[str, ...]:
    return SPLINES


def candidate_schemes(ndim: int) -> tuple[str, ...]:
    """"md" plus the two extreme sequential orderings (forward / reverse).

    For ndim == 1 every ordering collapses to the same single sweep.
    """
    if ndim <= 1:
        return ("md",)
    fwd = "1d-" + "".join(map(str, range(ndim)))
    rev = "1d-" + "".join(map(str, reversed(range(ndim))))
    return ("md", fwd, rev)


def fixed_step_baselines(nlev: int = 4) -> dict:
    """Uniform fixed-steps configurations (CompressorSpec kwargs) that
    ``predictor="auto"`` must match or beat — the bench's and the CR-floor
    tests' shared baseline grid."""
    return {
        "cubic-md": dict(splines=("cubic",) * nlev, schemes=("md",) * nlev),
        "linear-md": dict(splines=("linear",) * nlev, schemes=("md",) * nlev),
        "cubic-1d": dict(splines=("cubic",) * nlev, schemes=("1d",) * nlev),
        "natural-cubic-md": dict(splines=("natural-cubic",) * nlev, schemes=("md",) * nlev),
    }


# ------------------------------------------------------------------ plan
@dataclasses.dataclass(frozen=True)
class PredictorPlan:
    """Per-field interpolation plan emitted by :func:`autotune_plan`.

    ``splines`` / ``schemes`` hold one entry per level (largest stride
    first, levels derived from ``anchor_stride``). ``est_bits_per_code``
    is the cost-model score of the winning configuration; ``candidates``
    records the per-stride alternatives that lost, for observability.
    """

    ndim: int
    anchor_stride: int
    splines: tuple[str, ...]
    schemes: tuple[str, ...]
    est_bits_per_code: float = 0.0
    sampled_blocks: int = 0
    candidates: tuple = ()  # ((label, est_bits_per_code), ...) per stride

    def __post_init__(self):
        object.__setattr__(self, "splines", tuple(self.splines))
        object.__setattr__(self, "schemes", tuple(self.schemes))
        object.__setattr__(self, "candidates", tuple(tuple(c) for c in self.candidates))
        if len(self.splines) != len(self.levels) or len(self.schemes) != len(self.levels):
            raise ValueError(
                f"plan needs {len(self.levels)} per-level entries for anchor_stride="
                f"{self.anchor_stride}, got splines={self.splines} schemes={self.schemes}"
            )

    @property
    def levels(self) -> tuple[int, ...]:
        return levels_for_stride(self.anchor_stride)

    def __str__(self) -> str:
        """Compact display form, e.g. ``s16:linear/1d-012,cubic/md,...``."""
        return f"s{self.anchor_stride}:" + ",".join(
            f"{sp}/{sc}" for sp, sc in zip(self.splines, self.schemes)
        )

    def steps(self, B: int = 17):
        return build_steps(self.ndim, B, self.levels, self.splines, self.schemes)

    def to_header(self, include_candidates: bool = False) -> dict:
        """Plain-dict form for the binary container v2 header (core.serial).

        The scored-alternatives record is omitted by default: it is
        kilobytes of labels, which would dominate the container for small
        fields. Pass ``include_candidates=True`` for offline reports.
        """
        h = {
            "ndim": int(self.ndim),
            "anchor_stride": int(self.anchor_stride),
            "splines": list(self.splines),
            "schemes": list(self.schemes),
            "est_bits_per_code": float(self.est_bits_per_code),
            "sampled_blocks": int(self.sampled_blocks),
        }
        if include_candidates:
            h["candidates"] = [[str(lbl), float(bits)] for lbl, bits in self.candidates]
        return h

    @classmethod
    def from_header(cls, h: dict) -> "PredictorPlan":
        return cls(
            ndim=int(h["ndim"]),
            anchor_stride=int(h["anchor_stride"]),
            splines=tuple(h["splines"]),
            schemes=tuple(h["schemes"]),
            est_bits_per_code=float(h.get("est_bits_per_code", 0.0)),
            sampled_blocks=int(h.get("sampled_blocks", 0)),
            candidates=tuple((lbl, bits) for lbl, bits in h.get("candidates", ())),
        )

    def to_bytes(self) -> bytes:
        """Compact binary form (repro.core.serial) — the shape a plan-cache
        entry or a service response carries a plan in."""
        return pack_obj(self.to_header())

    @classmethod
    def from_bytes(cls, buf: bytes) -> "PredictorPlan":
        return cls.from_header(unpack_obj(buf))


# ---------------------------------------------------------- plan-cache keys
_SIG_VERSION = "ps1"        # bump when signature semantics change
_STATS_SAMPLE_CAP = 65536   # stats-bucket subsample size (uniform strided)
_STD_BUCKET_QUARTERS = 4    # std bucket resolution: quarter powers of two


def stats_bucket(x: np.ndarray) -> tuple[int, int]:
    """Coarse distribution bucket of a field, for plan-cache keying.

    Two integers: the power-of-two exponent of the value range, and the
    range-normalized standard deviation quantized to quarter powers of
    two. Fields whose tuning outcome would plausibly differ (a 1000x
    larger dynamic range, a flat vs. a noisy field) land in different
    buckets; run-to-run noise on the *same* recurring tensor does not —
    that is the whole point: the millions-of-users case is the same
    shapes with the same statistics arriving forever.

    Cost: one strided subsample (<= ``_STATS_SAMPLE_CAP`` elements) and
    two reductions — microseconds against the planner's trial encodes.
    """
    flat = np.asarray(x).reshape(-1)
    if flat.size == 0:
        return (0, 0)
    if flat.size > _STATS_SAMPLE_CAP:
        flat = flat[:: max(1, flat.size // _STATS_SAMPLE_CAP)]
    lo = float(np.min(flat))
    rng = float(np.max(flat)) - lo
    if not math.isfinite(rng) or rng <= 0.0:
        return (-(1 << 20), 0)  # constant (or non-finite) field: its own bucket
    b_rng = math.frexp(rng)[1]
    rel_std = float(np.std(flat)) / rng
    if rel_std <= 0.0:
        return (b_rng, -(1 << 20))
    return (b_rng, int(round(_STD_BUCKET_QUARTERS * math.log2(rel_std))))


def plan_signature(shape, dtype, eb: float, eb_mode: str, bucket=(), *, extra=()) -> tuple:
    """Hashable plan-cache key: field geometry + error-bound config +
    coarse stats bucket (+ caller extras, e.g. the spec knobs that steer
    the tuner). Two fields share a signature exactly when a cached tuning
    outcome for one is a valid (and near-optimal) plan for the other.
    """
    return (
        _SIG_VERSION,
        tuple(int(s) for s in shape),
        np.dtype(dtype).str,
        float(eb),
        str(eb_mode),
        tuple(bucket),
        tuple(extra),
    )


# ------------------------------------------------------------ trial passes
@functools.partial(jax.jit, static_argnums=(3, 4))
def _level_pass(recon, orig, twoeb, steps, update: bool):
    """Run one level's steps; return (new_recon, sum |orig-pred| over targets).

    Legacy scorer for :func:`autotune` (absolute-error argmin).
    """
    err = jnp.zeros((), jnp.float32)
    for step in steps:
        pred = _predict(recon, step)
        m = jnp.asarray(step.mask)
        err = err + jnp.sum(jnp.where(m, jnp.abs(orig - pred), 0.0))
        q = jnp.rint((orig - pred) / twoeb)
        outl = jnp.abs(q) > RADIUS
        rec = jnp.where(outl, orig, pred + q * twoeb)
        recon = jnp.where(m, rec, recon)
    return recon, err


@functools.partial(jax.jit, static_argnums=(3,))
def _level_codes_pass(recon, orig, twoeb, steps):
    """One level with quantization feedback, returning what the encoder
    would emit: (new_recon, codes) where ``codes`` carries the uint8
    quantization code at this level's target points and -1 elsewhere.

    Shares predictor.quantize_pred, so the stream the tuner scores is
    bit-identical to the stream the compressor then produces.
    """
    codes = jnp.full(orig.shape, -1, jnp.int32)
    inv2eb = 1.0 / twoeb
    for step in steps:
        pred = _predict(recon, step)
        code, _, rec = quantize_pred(orig, pred, twoeb, inv2eb)
        m = jnp.asarray(step.mask)
        recon = jnp.where(m, rec, recon)
        codes = jnp.where(m, code, codes)
    return recon, codes


def _level_emits(codes_np: np.ndarray) -> np.ndarray:
    """Flatten one level's emitted codes (drop non-target -1 fill) to uint8,
    block-major then row-major — the level-segment order the reorder keeps."""
    flat = codes_np.reshape(-1)
    return flat[flat >= 0].astype(np.uint8)


def _code_bits(hist: np.ndarray, n_outliers: int) -> float:
    """Estimated encoded bits for one level's code stream.

    Shares the lossless orchestrator's cost model: the byte-histogram
    entropy from :func:`orchestrate.stream_stats` (fed through its
    ``histogram`` hook) bounds what any registered entropy-coding pipeline
    achieves; outliers pay their raw storage on top.
    """
    hist = np.asarray(hist, np.int64)
    n = int(hist.sum())
    if n == 0:
        return 0.0
    stats = orc.stream_stats(np.zeros(0, np.uint8), n_total=n, histogram=lambda _: hist)
    return n * stats["entropy"] + int(n_outliers) * OUTLIER_BITS


def plan_sample_indices(nb: int) -> np.ndarray:
    """Block indices :func:`autotune_plan` samples out of ``nb`` blocks.

    Exported so device-parallel callers (repro.core.distributed) can gather
    exactly this sample per shard and hand it back ``presampled`` — the
    plan they obtain is then bit-identical to the in-process tuner's.
    """
    if nb <= EXHAUSTIVE_BLOCKS:
        return np.arange(nb, dtype=np.int64)
    ns = min(nb, max(MIN_SAMPLE_BLOCKS, int(round(SAMPLE_FRACTION * nb))))
    return np.linspace(0, nb - 1, ns).astype(np.int64)  # uniform sampling (paper)


def legacy_sample_indices(nb: int) -> np.ndarray:
    """Block indices the legacy :func:`autotune` samples (no exhaustive tier)."""
    ns = min(nb, max(MIN_SAMPLE_BLOCKS, int(round(SAMPLE_FRACTION * nb))))
    return np.linspace(0, nb - 1, ns).astype(np.int64)


def _sample_blocks(blocks: np.ndarray) -> np.ndarray:
    if blocks.shape[0] <= EXHAUSTIVE_BLOCKS:
        return np.ascontiguousarray(blocks)  # no-copy when already contiguous
    return np.ascontiguousarray(blocks[plan_sample_indices(blocks.shape[0])])


# ------------------------------------------------------------------ tuners
def autotune(blocks: np.ndarray, twoeb: float, levels=(8, 4, 2, 1), anchor_every: int = 16, rng_seed: int = 0,
             presampled: bool = False):
    """Legacy tuner: per-level (spline x scheme) argmin of absolute error.

    blocks: (nb, B..). Returns (splines, schemes) tuples, one entry per level.
    ``presampled=True``: blocks are already the :func:`legacy_sample_indices`
    sample (device-parallel callers gather it shard-side) — skip resampling.
    """
    ndim = blocks.ndim - 1
    B = blocks.shape[1]
    nb = blocks.shape[0]
    sample = jnp.asarray(blocks if presampled else blocks[legacy_sample_indices(nb)])
    am = jnp.asarray(_anchor_mask(sample.shape[1:], anchor_every))
    recon = jnp.where(am, sample, 0.0)
    twoeb = jnp.float32(twoeb)
    chosen_splines, chosen_schemes = [], []
    for s in levels:
        best = None
        for spline in SPLINES:
            for scheme in SCHEMES:
                steps = build_steps(ndim, B, (s,), (spline,), (scheme,))
                _, err = _level_pass(recon, sample, twoeb, steps, False)
                err = float(err)
                if best is None or err < best[0]:
                    best = (err, spline, scheme)
        _, spline, scheme = best
        chosen_splines.append(spline)
        chosen_schemes.append(scheme)
        steps = build_steps(ndim, B, (s,), (spline,), (scheme,))
        recon, _ = _level_pass(recon, sample, twoeb, steps, True)
    return tuple(chosen_splines), tuple(chosen_schemes)


def _anchor_count(field_shape: tuple[int, ...] | None, sample_shape: tuple[int, ...], n_blocks: int, stride: int) -> int:
    """Anchors the container will store, in full-field units.

    With the real (batch, *padded) field shape this is exact; the
    block-local fallback counts over ALL ``n_blocks`` blocks (not just the
    sample) so it shares units with the scale-extrapolated code bits — it
    overcounts shared faces, but ranks strides consistently.
    """
    if field_shape is not None:
        batch, spatial = field_shape[0], field_shape[1:]
        per = 1
        for d in spatial:
            per *= (d - 1) // stride + 1
        return int(batch) * per
    return n_blocks * int(np.count_nonzero(_anchor_mask(sample_shape, stride)))


def _greedy_levels(sample, twoeb_j, stride: int, ndim: int, B: int):
    """Per-level greedy sweep with quantization feedback.

    Returns (splines, schemes, per-level code grids big-stride-first).
    """
    am = jnp.asarray(_anchor_mask(sample.shape[1:], stride))
    recon = jnp.where(am, sample, 0.0)
    grids: list[np.ndarray] = []
    splines_sel: list[str] = []
    schemes_sel: list[str] = []
    for s in levels_for_stride(stride):
        level_best = None
        for spline in candidate_splines():
            for scheme in candidate_schemes(ndim):
                steps = build_steps(ndim, B, (s,), (spline,), (scheme,))
                r2, codes = _level_codes_pass(recon, sample, twoeb_j, steps)
                codes = np.asarray(codes)
                emits = _level_emits(codes)
                hist = np.bincount(emits, minlength=256)
                bits = _code_bits(hist, int(hist[0]))
                if level_best is None or bits < level_best[0]:
                    level_best = (bits, spline, scheme, r2, codes)
        _, spline, scheme, recon, codes = level_best
        grids.append(codes)
        splines_sel.append(spline)
        schemes_sel.append(scheme)
    return tuple(splines_sel), tuple(schemes_sel), grids


def _eval_config(sample, twoeb_j, stride: int, splines, schemes, ndim: int, B: int):
    """Full-hierarchy evaluation of a (splines, schemes) config with
    feedback; returns per-level code grids. Runs level by level so every
    jitted pass is shared with the greedy sweep's cache."""
    am = jnp.asarray(_anchor_mask(sample.shape[1:], stride))
    recon = jnp.where(am, sample, 0.0)
    grids: list[np.ndarray] = []
    for s, spline, scheme in zip(levels_for_stride(stride), splines, schemes):
        steps = build_steps(ndim, B, (s,), (spline,), (scheme,))
        recon, codes = _level_codes_pass(recon, sample, twoeb_j, steps)
        grids.append(np.asarray(codes))
    return grids


def autotune_plan(
    blocks: np.ndarray,
    twoeb: float,
    anchor_strides: tuple[int, ...] = DEFAULT_STRIDES,
    field_shape: tuple[int, ...] | None = None,
    trial_pipeline: str = "cr",
    max_trials: int = 6,
    reorder: bool = True,
    presampled_of: int | None = None,
) -> PredictorPlan:
    """Full planner behind ``predictor="auto"``.

    blocks: (nb, B..) anchor blocks (gathered at the block stride);
    ``field_shape``: optional (batch, *padded) shape for an exact anchor
    count in the stride comparison. ``presampled_of=N``: blocks are already
    the :func:`plan_sample_indices` sample of an N-block field (gathered
    shard-side by repro.core.distributed) — skip resampling and scale code
    bits by N/len(blocks), exactly as the in-process path would.

    Mirrors the lossless orchestrator's estimate-then-trial structure,
    per candidate anchor stride:

    1. the paper's greedy per-level sweep, each level scored by the
       entropy of its quantized-residual codes (the shared
       ``stream_stats`` cost model);
    2. every *uniform* (spline, scheme) configuration evaluated
       full-hierarchy with feedback — so the candidate set contains every
       fixed-steps configuration — pre-scored by mixture entropy over all
       levels plus outlier and anchor storage;
    3. the ``max_trials`` best candidates are *trial-encoded* through the
       actual ``trial_pipeline`` encoder and the plan minimizing trialed
       total bytes wins. When the sample is exhaustive (small fields) the
       trial stream is built through the real block-scatter + level
       reorder, so the trial byte count is the realized payload size; on
       sampled fields it falls back to block-local level segments,
       extrapolated to the full field.
    """
    ndim = blocks.ndim - 1
    B = blocks.shape[1]
    if presampled_of is not None:
        nb, sample_np = int(presampled_of), np.ascontiguousarray(blocks)
    else:
        nb, sample_np = blocks.shape[0], _sample_blocks(blocks)
    ns = sample_np.shape[0]
    sample = jnp.asarray(sample_np)
    twoeb_j = jnp.float32(twoeb)
    scale = nb / ns  # sampled code bits -> full-field code bits
    n_points = nb * B**ndim  # normalization only; comparisons use totals
    exact = ns == nb and field_shape is not None
    cands: list[dict] = []

    def consider(stride, splines, schemes, grids, anchor_bits, tag):
        seq = np.concatenate([_level_emits(g) for g in grids]) if grids else np.zeros(0, np.uint8)
        hist = np.bincount(seq, minlength=256)
        est = (anchor_bits + _code_bits(hist, int(hist[0])) * scale) / max(n_points, 1)
        combined = None
        if exact:  # u8 merge: a quarter of the level grids' footprint
            combined = np.full(sample_np.shape, CENTER, np.int32)  # anchors keep the fill
            for g in grids:
                combined = np.where(g >= 0, g, combined)
            combined = combined.astype(np.uint8)
        cands.append({
            "label": f"{tag}:stride{stride}:" + ",".join(f"{sp}/{sc}" for sp, sc in zip(splines, schemes)),
            "stride": stride, "splines": tuple(splines), "schemes": tuple(schemes),
            "seq": seq, "combined": combined, "n_out": int(hist[0]),
            "anchor_bits": anchor_bits, "est": est,
        })

    for stride in anchor_strides:
        anchor_bits = _anchor_count(field_shape, sample.shape[1:], nb, stride) * ANCHOR_BITS
        nlev = len(levels_for_stride(stride))
        g_splines, g_schemes, g_grids = _greedy_levels(sample, twoeb_j, stride, ndim, B)
        consider(stride, g_splines, g_schemes, g_grids, anchor_bits, "greedy")
        for spline in candidate_splines():
            for scheme in candidate_schemes(ndim):
                cfg = ((spline,) * nlev, (scheme,) * nlev)
                if cfg == (g_splines, g_schemes):
                    continue  # already scored as the greedy plan
                grids = _eval_config(sample, twoeb_j, stride, *cfg, ndim, B)
                consider(stride, *cfg, grids, anchor_bits, "uniform")

    order = sorted(cands, key=lambda c: (c["est"], c["label"]))[: max(1, max_trials)]
    batch = int(field_shape[0]) if field_shape is not None else 1
    for c in order:
        if exact:
            # the realized stream: scatter the blocks back and apply the
            # level reorder, exactly like the compressor's encode path
            cgrid = _blk.scatter_blocks_batch(c["combined"], batch, tuple(field_shape[1:]), B - 1)
            seq = reorder_codes_batch(cgrid, c["stride"], reorder)
            n_out = int(np.count_nonzero(seq == 0))
        else:
            seq, n_out = c["seq"], c["n_out"]
        code_bits = 8.0 * len(_pipelines.encode(seq, trial_pipeline)) + n_out * OUTLIER_BITS
        c["trial"] = (c["anchor_bits"] + code_bits * (1.0 if exact else scale)) / max(n_points, 1)
    winner = min(order, key=lambda c: (c["trial"], c["label"]))
    return PredictorPlan(
        ndim=ndim,
        anchor_stride=winner["stride"],
        splines=winner["splines"],
        schemes=winner["schemes"],
        est_bits_per_code=winner["trial"],
        sampled_blocks=ns,
        candidates=tuple((c["label"], c.get("trial", c["est"])) for c in cands),
    )
