"""Compact binary object codec for container headers.

A tiny tagged serializer over the JSON value model (None/bool/int/float/
str/bytes/list/dict). The binary container v2 header (repro.core.compressor)
and the host-side gradient payloads (repro.optim.grad_compress) both ride
this codec, so headers stay a few dozen bytes instead of a JSON blob and
never depend on float repr round-tripping.

Layout: one tag byte per value; ints are signed little-endian i64, floats
IEEE f64, str/bytes length-prefixed (u32), containers count-prefixed (u32).
Dict keys must be str. Numpy scalars are coerced to their Python types so
headers built from array metadata pack without ceremony.
"""
from __future__ import annotations

import struct

import numpy as np

_T_NONE, _T_FALSE, _T_TRUE, _T_INT, _T_FLOAT, _T_STR, _T_BYTES, _T_LIST, _T_DICT = range(9)


def pack_obj(obj) -> bytes:
    out = bytearray()
    _pack_into(out, obj)
    return bytes(out)


def _pack_into(out: bytearray, obj) -> None:
    if obj is None:
        out.append(_T_NONE)
    elif obj is True:
        out.append(_T_TRUE)
    elif obj is False:
        out.append(_T_FALSE)
    elif isinstance(obj, (int, np.integer)):
        out.append(_T_INT)
        out += struct.pack("<q", int(obj))
    elif isinstance(obj, (float, np.floating)):
        out.append(_T_FLOAT)
        out += struct.pack("<d", float(obj))
    elif isinstance(obj, str):
        b = obj.encode()
        out.append(_T_STR)
        out += struct.pack("<I", len(b))
        out += b
    elif isinstance(obj, (bytes, bytearray)):
        out.append(_T_BYTES)
        out += struct.pack("<I", len(obj))
        out += obj
    elif isinstance(obj, (list, tuple)):
        out.append(_T_LIST)
        out += struct.pack("<I", len(obj))
        for v in obj:
            _pack_into(out, v)
    elif isinstance(obj, dict):
        out.append(_T_DICT)
        out += struct.pack("<I", len(obj))
        for k, v in obj.items():
            if not isinstance(k, str):
                raise TypeError(f"dict keys must be str, got {type(k).__name__}")
            kb = k.encode()
            out += struct.pack("<I", len(kb))
            out += kb
            _pack_into(out, v)
    else:
        raise TypeError(f"cannot pack {type(obj).__name__}")


def unpack_obj(buf: bytes):
    obj, off = _unpack_from(buf, 0)
    return obj


def _unpack_from(buf: bytes, off: int):
    tag = buf[off]
    off += 1
    if tag == _T_NONE:
        return None, off
    if tag == _T_TRUE:
        return True, off
    if tag == _T_FALSE:
        return False, off
    if tag == _T_INT:
        return struct.unpack_from("<q", buf, off)[0], off + 8
    if tag == _T_FLOAT:
        return struct.unpack_from("<d", buf, off)[0], off + 8
    if tag in (_T_STR, _T_BYTES):
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        raw = bytes(buf[off : off + n])
        return (raw.decode() if tag == _T_STR else raw), off + n
    if tag == _T_LIST:
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        out = []
        for _ in range(n):
            v, off = _unpack_from(buf, off)
            out.append(v)
        return out, off
    if tag == _T_DICT:
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        out = {}
        for _ in range(n):
            (kl,) = struct.unpack_from("<I", buf, off)
            off += 4
            k = bytes(buf[off : off + kl]).decode()
            off += kl
            out[k], off = _unpack_from(buf, off)
        return out, off
    raise ValueError(f"bad tag byte {tag} at offset {off - 1}")
