"""Mapping-based quantization-code reordering (paper §5.1.4, Eq. 3).

Codes are emitted grouped by interpolation level — largest strides first —
row-major within each level. This is the same bijection as the paper's
closed-form index I(x,y,z); we materialize it once per field shape (cached)
and apply it as a gather. Anchor positions (every coord divisible by 16)
carry no quantization code and are excluded (they are stored losslessly).
"""
from __future__ import annotations

import functools

import numpy as np

ANCHOR_STRIDE = 16


@functools.lru_cache(maxsize=64)
def _level_of_shape(shape: tuple[int, ...], stride: int) -> np.ndarray:
    """Per-point hierarchy level: max l<=log2(stride) with 2^l | every coord."""
    lmax = int(np.log2(stride))
    lev = None
    for d in shape:
        c = np.arange(d)
        ld = np.full(d, 0, np.int8)
        for l in range(1, lmax + 1):
            ld[c % (1 << l) == 0] = l
        lev_d = ld
        lev = lev_d if lev is None else np.minimum(lev[..., None], lev_d)
    return lev  # shape `shape`, values 0..lmax


@functools.lru_cache(maxsize=64)
def level_permutation(shape: tuple[int, ...], stride: int = ANCHOR_STRIDE):
    """(perm, inv): perm[j] = flat index (row-major, in `shape`) of the j-th
    code in the reordered sequence; inv undoes it. Anchors excluded."""
    lev = _level_of_shape(shape, stride).reshape(-1)
    lmax = int(np.log2(stride))
    parts = [np.flatnonzero(lev == l) for l in range(lmax - 1, -1, -1)]  # big strides first
    perm = np.concatenate(parts).astype(np.int64)
    # inverse: pos[flat index] = position within the reordered sequence (-1 for anchors)
    pos = np.empty(int(np.prod(shape)), np.int64)
    pos.fill(-1)
    pos[perm] = np.arange(perm.size)
    return perm, pos


@functools.lru_cache(maxsize=64)
def flat_permutation(shape: tuple[int, ...], stride: int = ANCHOR_STRIDE):
    """Non-anchor indices in plain row-major order (the no-reorder ablation)."""
    perm, _ = level_permutation(shape, stride)
    return np.sort(perm)


def reorder_codes(codes_grid: np.ndarray, stride: int = ANCHOR_STRIDE, reorder: bool = True) -> np.ndarray:
    perm = level_permutation(codes_grid.shape, stride)[0] if reorder else flat_permutation(codes_grid.shape, stride)
    return codes_grid.reshape(-1)[perm]


def restore_codes(seq: np.ndarray, shape: tuple[int, ...], fill, dtype, stride: int = ANCHOR_STRIDE, reorder: bool = True) -> np.ndarray:
    perm = level_permutation(shape, stride)[0] if reorder else flat_permutation(shape, stride)
    out = np.full(int(np.prod(shape)), fill, dtype=dtype)
    out[perm] = seq
    return out.reshape(shape)


def reorder_codes_batch(grids: np.ndarray, stride: int = ANCHOR_STRIDE, reorder: bool = True) -> np.ndarray:
    """Batched reorder: (batch, *shape) -> concatenated per-item sequences.

    One cached-permutation gather across the whole batch; identical to
    concatenating per-item reorder_codes results.
    """
    shape = grids.shape[1:]
    perm = level_permutation(shape, stride)[0] if reorder else flat_permutation(shape, stride)
    return grids.reshape(grids.shape[0], -1)[:, perm].reshape(-1)


def reorder_codes_batch_device(grids, stride: int = ANCHOR_STRIDE, reorder: bool = True):
    """Device twin of reorder_codes_batch: the cached host permutation
    applied as one jnp gather; ``grids`` is a jax array (batch, *shape)."""
    import jax.numpy as jnp

    shape = tuple(int(s) for s in grids.shape[1:])
    perm = level_permutation(shape, stride)[0] if reorder else flat_permutation(shape, stride)
    return jnp.take(grids.reshape(grids.shape[0], -1), jnp.asarray(perm), axis=1).reshape(-1)


def restore_codes_batch(seq: np.ndarray, batch: int, shape: tuple[int, ...], fill, dtype, stride: int = ANCHOR_STRIDE, reorder: bool = True) -> np.ndarray:
    """Batched inverse of reorder_codes_batch -> (batch, *shape) grids."""
    perm = level_permutation(shape, stride)[0] if reorder else flat_permutation(shape, stride)
    out = np.full((batch, int(np.prod(shape))), fill, dtype=dtype)
    out[:, perm] = seq.reshape(batch, perm.size)
    return out.reshape((batch,) + shape)


@functools.lru_cache(maxsize=64)
def _restore_gather(shape: tuple[int, ...], stride: int, reorder: bool):
    """Cached device (idx, mask) realizing restore_codes_batch as a gather.

    ``idx[p]`` = sequence position of the code at flat grid index p (0 at
    anchors, masked off); the inverse-scatter becomes take+where, which is
    the fast direction on XLA:CPU (its scatters run ~10x behind gathers).
    """
    import jax.numpy as jnp

    if reorder:
        pos = level_permutation(shape, stride)[1]
    else:
        perm = flat_permutation(shape, stride)
        pos = np.full(int(np.prod(shape)), -1, np.int64)
        pos[perm] = np.arange(perm.size)
    idx = np.where(pos >= 0, pos, 0).astype(np.int32)
    return jnp.asarray(idx), jnp.asarray(pos >= 0)


def restore_codes_batch_device(seq, batch: int, shape: tuple[int, ...], fill, stride: int = ANCHOR_STRIDE, reorder: bool = True):
    """Device twin of restore_codes_batch over a uint8 device sequence.

    Returns the (batch, *shape) uint8 grids as a device array, bit-identical
    to the numpy restore (anchor positions carry ``fill``).
    """
    import jax.numpy as jnp

    idx, mask = _restore_gather(tuple(int(s) for s in shape), stride, bool(reorder))
    rows = jnp.take(seq.reshape(batch, -1), idx, axis=1)
    out = jnp.where(mask[None, :], rows, jnp.uint8(fill))
    return out.reshape((batch,) + tuple(shape))
