"""cuSZ-Hi top-level compressor (the paper's full pipeline, §4-§5).

compress():  pad -> [autotune] -> interpolation predict+quantize (blocks,
jit/Pallas) -> scatter codes -> level-reorder (Eq.3) -> lossless pipeline
(CR: hf-rre4-tcms8-rze1 / TP: tcms1-bit1-rre1) -> container with anchors +
outliers.  decompress() replays the identical arithmetic from the codes.

Error-bound contract: ||x - decompress(compress(x))||_inf <= eb_abs,
where eb_abs = eb * value_range(x) in the paper's default "rel" mode.
"""
from __future__ import annotations

import dataclasses
import json

import jax.numpy as jnp
import numpy as np

from . import blocks as blk
from . import lorenzo as lor
from .autotune import autotune
from .lossless import pipelines
from .lossless.flenc import fl_decode, fl_encode
from .predictor import compress_blocks, decompress_blocks
from .reorder import flat_permutation, level_permutation, reorder_codes, restore_codes
from .stencils import build_steps

MAGIC = b"CSZH1\n"


@dataclasses.dataclass(frozen=True)
class CompressorSpec:
    eb: float = 1e-3
    eb_mode: str = "rel"                  # "rel": eb * value range (paper); "abs"
    predictor: str = "interp"             # interp | lorenzo | offset1d
    pipeline: str = "cr"                  # cr | tp | hf | fz | none
    anchor_stride: int = 16               # 16 = cuSZ-Hi; 8 = cuSZ-I layout
    autotune: bool = True
    splines: tuple = ("cubic", "cubic", "cubic", "cubic")
    schemes: tuple = ("md", "md", "md", "md")
    reorder: bool = True

    @property
    def levels(self) -> tuple:
        lv, s = [], self.anchor_stride // 2
        while s >= 1:
            lv.append(s)
            s //= 2
        return tuple(lv)


def _sections_pack(header: dict, sections: list[bytes]) -> bytes:
    header = dict(header, _sizes=[len(s) for s in sections])
    hj = json.dumps(header).encode()
    return MAGIC + len(hj).to_bytes(8, "little") + hj + b"".join(sections)


def _sections_unpack(buf: bytes):
    assert buf[: len(MAGIC)] == MAGIC, "bad container magic"
    off = len(MAGIC)
    hlen = int.from_bytes(buf[off : off + 8], "little")
    off += 8
    header = json.loads(buf[off : off + hlen])
    off += hlen
    sections = []
    for sz in header["_sizes"]:
        sections.append(buf[off : off + sz])
        off += sz
    return header, sections


class Compressor:
    def __init__(self, spec: CompressorSpec | None = None, **kw):
        self.spec = spec or CompressorSpec(**kw)

    # ------------------------------------------------------------------ utils
    def _abs_eb(self, x: np.ndarray) -> float:
        if self.spec.eb_mode == "abs":
            return float(self.spec.eb)
        rng = float(np.max(x) - np.min(x)) if x.size else 0.0
        return float(self.spec.eb) * rng

    @staticmethod
    def _spatial_view(x: np.ndarray):
        """Fold >3-D arrays into (batch, spatial<=3)."""
        nd = min(x.ndim, 3)
        spatial = x.shape[x.ndim - nd :]
        batch = int(np.prod(x.shape[: x.ndim - nd], dtype=np.int64)) if x.ndim > nd else 1
        return x.reshape((batch,) + spatial), spatial

    # -------------------------------------------------------------- compress
    def compress(self, x: np.ndarray) -> bytes:
        sp = self.spec
        x = np.ascontiguousarray(x, np.float32)
        eb_abs = self._abs_eb(x)
        base_hdr = {
            "shape": list(x.shape),
            "predictor": sp.predictor,
            "eb_abs": eb_abs,
            "anchor_stride": sp.anchor_stride,
        }
        if eb_abs == 0.0:  # constant field (or degenerate): store verbatim min
            return _sections_pack(dict(base_hdr, mode="const"), [np.float32(x.reshape(-1)[0] if x.size else 0).tobytes()])
        if sp.predictor == "interp":
            return self._compress_interp(x, eb_abs, base_hdr)
        if sp.predictor == "lorenzo":
            return self._compress_lorenzo(x, eb_abs, base_hdr)
        if sp.predictor == "offset1d":
            return self._compress_offset1d(x, eb_abs, base_hdr)
        raise ValueError(sp.predictor)

    def _compress_interp(self, x: np.ndarray, eb_abs: float, base_hdr: dict) -> bytes:
        sp = self.spec
        xb, spatial = self._spatial_view(x)
        ndim = len(spatial)
        stride = sp.anchor_stride
        twoeb = jnp.float32(2.0 * eb_abs)
        padded = [blk.pad_field(xb[i], blk.ANCHOR_STRIDE) for i in range(xb.shape[0])]
        padded_shapes = padded[0].shape
        blocks = np.concatenate([blk.gather_blocks(p, blk.ANCHOR_STRIDE) for p in padded], axis=0)
        nb_per = blocks.shape[0] // xb.shape[0]
        if sp.autotune:
            splines, schemes = autotune(blocks, 2.0 * eb_abs, sp.levels, stride)
        else:
            splines, schemes = tuple(sp.splines[: len(sp.levels)]), tuple(sp.schemes[: len(sp.levels)])
        steps = build_steps(ndim, blk.BLOCK, sp.levels, splines, schemes)
        codes_b, outl_b, _ = compress_blocks(jnp.asarray(blocks), twoeb, steps, stride)
        codes_b, outl_b = np.asarray(codes_b), np.asarray(outl_b)
        seqs, anchors, o_idx, o_val = [], [], [], []
        psize = int(np.prod(padded_shapes))
        for i in range(xb.shape[0]):
            cgrid = blk.scatter_blocks(codes_b[i * nb_per : (i + 1) * nb_per], padded_shapes, blk.ANCHOR_STRIDE)
            ogrid = blk.scatter_blocks(outl_b[i * nb_per : (i + 1) * nb_per], padded_shapes, blk.ANCHOR_STRIDE)
            seqs.append(reorder_codes(cgrid, stride, sp.reorder))
            anchors.append(blk.anchor_grid(padded[i], stride))
            fi = np.flatnonzero(ogrid.reshape(-1))
            o_idx.append(fi + i * psize)
            o_val.append(padded[i].reshape(-1)[fi])
        seq = np.concatenate(seqs)
        payload = pipelines.encode(seq, sp.pipeline)
        anc = np.concatenate([a.reshape(-1) for a in anchors]).astype(np.float32)
        oi = np.concatenate(o_idx).astype(np.int64)
        ov = np.concatenate(o_val).astype(np.float32)
        header = dict(
            base_hdr,
            mode="interp",
            padded=list(padded_shapes),
            batch=int(xb.shape[0]),
            splines=list(splines),
            schemes=list(schemes),
            reorder=bool(sp.reorder),
            n_outliers=int(oi.size),
        )
        return _sections_pack(header, [payload, anc.tobytes(), oi.tobytes(), ov.tobytes()])

    def _compress_lorenzo(self, x: np.ndarray, eb_abs: float, base_hdr: dict) -> bytes:
        sp = self.spec
        xb, spatial = self._spatial_view(x)
        twoeb = jnp.float32(2.0 * eb_abs)
        codes, outl, cfull, _ = lor.lorenzo_encode(jnp.asarray(xb), twoeb, len(spatial))
        codes, outl, cfull = np.asarray(codes), np.asarray(outl), np.asarray(cfull)
        fi = np.flatnonzero(outl.reshape(-1))
        payload = pipelines.encode(codes.reshape(-1), sp.pipeline)
        header = dict(base_hdr, mode="lorenzo", batch=int(xb.shape[0]), spatial=list(spatial), n_outliers=int(fi.size))
        return _sections_pack(header, [payload, fi.astype(np.int64).tobytes(), cfull.reshape(-1)[fi].astype(np.int32).tobytes()])

    def _compress_offset1d(self, x: np.ndarray, eb_abs: float, base_hdr: dict) -> bytes:
        twoeb = jnp.float32(2.0 * eb_abs)
        codes = np.asarray(lor.offset1d_encode(jnp.asarray(x), twoeb))
        payload, hdr = fl_encode(codes)
        header = dict(base_hdr, mode="offset1d", fl=hdr)
        return _sections_pack(header, [payload])

    # ------------------------------------------------------------ decompress
    def decompress(self, buf: bytes) -> np.ndarray:
        header, sections = _sections_unpack(buf)
        shape = tuple(header["shape"])
        mode = header["mode"]
        if mode == "const":
            v = np.frombuffer(sections[0], np.float32)[0]
            return np.full(shape, v, np.float32)
        if mode == "interp":
            return self._decompress_interp(header, sections, shape)
        if mode == "lorenzo":
            return self._decompress_lorenzo(header, sections, shape)
        if mode == "offset1d":
            codes = fl_decode(sections[0], header["fl"])
            out = np.asarray(lor.offset1d_decode(jnp.asarray(codes), jnp.float32(2.0 * header["eb_abs"])))
            return out.reshape(shape)
        raise ValueError(mode)

    def _decompress_interp(self, header, sections, shape) -> np.ndarray:
        stride = header["anchor_stride"]
        padded_shapes = tuple(header["padded"])
        batch = header["batch"]
        ndim = len(padded_shapes)
        eb_abs = header["eb_abs"]
        seq = pipelines.decode(sections[0])
        anc = np.frombuffer(sections[1], np.float32)
        oi = np.frombuffer(sections[2], np.int64)
        ov = np.frombuffer(sections[3], np.float32)
        psize = int(np.prod(padded_shapes))
        perm, _ = level_permutation(padded_shapes, stride)
        npts = perm.size
        anc_shape = tuple((d - 1) // stride + 1 for d in padded_shapes)
        anc_per = int(np.prod(anc_shape))
        steps = build_steps(ndim, blk.BLOCK, tuple(CompressorSpec(anchor_stride=stride).levels), tuple(header["splines"]), tuple(header["schemes"]))
        outs = []
        for i in range(batch):
            cgrid = restore_codes(seq[i * npts : (i + 1) * npts], padded_shapes, fill=128, dtype=np.uint8,
                                  stride=stride, reorder=header.get("reorder", True))
            agrid = blk.place_anchors(padded_shapes, anc[i * anc_per : (i + 1) * anc_per].reshape(anc_shape), stride)
            ovgrid = np.zeros(psize, np.float32)
            sel = (oi >= i * psize) & (oi < (i + 1) * psize)
            ovgrid[oi[sel] - i * psize] = ov[sel]
            ovgrid = ovgrid.reshape(padded_shapes)
            cb = blk.gather_blocks(cgrid, blk.ANCHOR_STRIDE)
            ab = blk.gather_blocks(agrid, blk.ANCHOR_STRIDE)
            vb = blk.gather_blocks(ovgrid, blk.ANCHOR_STRIDE)
            recon_b = np.asarray(decompress_blocks(jnp.asarray(cb), jnp.asarray(ab), jnp.asarray(vb), jnp.float32(2.0 * eb_abs), steps, stride))
            recon = blk.scatter_blocks(recon_b, padded_shapes, blk.ANCHOR_STRIDE)
            outs.append(recon)
        out = np.stack(outs)
        nd = len(padded_shapes)
        spatial = shape[len(shape) - nd :] if len(shape) >= nd else shape
        sl = (slice(None),) + tuple(slice(0, s) for s in spatial)
        out = out[sl]
        return out.reshape(shape)

    def _decompress_lorenzo(self, header, sections, shape) -> np.ndarray:
        seq = pipelines.decode(sections[0])
        oi = np.frombuffer(sections[1], np.int64)
        ov = np.frombuffer(sections[2], np.int32)
        batch, spatial = header["batch"], tuple(header["spatial"])
        codes = seq.reshape((batch,) + spatial)
        ofull = np.zeros(codes.size, np.int32)
        ofull[oi] = ov
        out = lor.lorenzo_decode(jnp.asarray(codes), jnp.asarray(ofull.reshape(codes.shape)), jnp.float32(2.0 * header["eb_abs"]), len(spatial))
        return np.asarray(out).reshape(shape)


# ------------------------------------------------------------------ presets
def cusz_hi_cr(eb=1e-3, **kw) -> Compressor:
    return Compressor(CompressorSpec(eb=eb, pipeline="cr", **kw))


def cusz_hi_crz(eb=1e-3, **kw) -> Compressor:
    """Beyond-paper mode: CR pipeline + open-source zstd tail stage."""
    return Compressor(CompressorSpec(eb=eb, pipeline="crz", **kw))


def cusz_hi_tp(eb=1e-3, **kw) -> Compressor:
    return Compressor(CompressorSpec(eb=eb, pipeline="tp", **kw))


def cusz_l(eb=1e-3) -> Compressor:
    """cuSZ-L baseline: Lorenzo + Huffman."""
    return Compressor(CompressorSpec(eb=eb, predictor="lorenzo", pipeline="hf"))


def cusz_i(eb=1e-3) -> Compressor:
    """cuSZ-I baseline: stride-8 anchors, 3 levels, 1D scheme, Huffman only."""
    return Compressor(
        CompressorSpec(eb=eb, predictor="interp", pipeline="hf", anchor_stride=8, autotune=False,
                       splines=("cubic",) * 3, schemes=("1d",) * 3, reorder=False)
    )


def cuszp2_like(eb=1e-3) -> Compressor:
    """cuSZp2-like baseline: 1-D offset prediction + fixed-length encoding."""
    return Compressor(CompressorSpec(eb=eb, predictor="offset1d", pipeline="none"))


def fzgpu_like(eb=1e-3) -> Compressor:
    """FZ-GPU-like baseline: Lorenzo + bitshuffle + de-redundancy."""
    return Compressor(CompressorSpec(eb=eb, predictor="lorenzo", pipeline="fz"))
