"""cuSZ-Hi top-level compressor (the paper's full pipeline, §4-§5).

compress():  pad -> [autotune] -> interpolation predict+quantize (blocks,
jit/Pallas) -> scatter codes -> level-reorder (Eq.3) -> lossless pipeline
-> container with anchors + outliers.  decompress() replays the identical
arithmetic from the codes.

The lossy seam mirrors the lossless one: ``CompressorSpec.predictor``
accepts ``"auto"``, which runs the per-level planner
(repro.core.autotune.autotune_plan) over sampled anchor blocks — candidate
splines (linear / cubic / natural-cubic), interpolation schemes ("md" vs
per-dimension sequential orderings) and anchor strides, scored by
quantized-residual entropy through the same stream_stats cost model the
lossless orchestrator uses. The winning ``PredictorPlan`` drives the step
tables (jax and Pallas backends alike) and is serialized into the
container v2 header as the (anchor_stride, splines, schemes) fields —
zero overhead over a fixed spec; ``Compressor.inspect`` surfaces it as
``pplan``. v1/v2 containers without recorded splines/schemes decode with
the default cubic/md steps.

The lossless seam rides the stage registry (repro.core.lossless.stages /
pipelines): ``CompressorSpec.pipeline`` names any registered pipeline
(CR: hf-rre4-tcms8-rze1 / TP: tcms1-bit1-rre1 / ...), and ``"auto"``
invokes the orchestrator (repro.core.lossless.orchestrate), which samples
the quantization-code stream, scores every registered pipeline with the
stage cost hooks plus a trial encode, and picks the best fit per field.
The chosen pipeline name and the sampled statistics are recorded in the
container header, so decompression never re-infers anything.

Container format v2 (binary): ``CSZH2\\n`` magic, u32 header length, a
compact binary header (repro.core.serial), then a section table — u32
section count + u64 sizes — followed by the section bytes. Containers
written by earlier checkouts (``CSZH1\\n`` magic + JSON header, JSON-meta
lossless streams) still decompress bit-exactly through the v1 read path.
Container v3 (``CSZH3\\n``, repro.core.frames) frames a field as
independently decodable chunks — each frame is a complete v1/v2 container
of one chunk, CRC-guarded — written by ``repro.core.distributed`` for
sharded/streaming compression; ``decompress(buf, frames=[...])`` decodes
any subset in any order.
Spec validation happens at construction: unknown pipeline/backend/
predictor names raise immediately, listing the registered names.

Error-bound contract: ||x - decompress(compress(x))||_inf <= eb_abs,
where eb_abs = eb * value_range(x) in the paper's default "rel" mode.

Hot-path architecture: the whole compressor is *batched end-to-end*. Fields
with leading batch dimensions are folded to (batch, spatial<=3) once;
padding, block gather/scatter, the level reorder (cached permutation
gathers), anchor extraction and outlier collection are all single
vectorized numpy ops over the batch axis, the predictor runs as ONE jitted
device call over the concatenated block axis, and the quantization codes of
the whole batch are emitted as ONE code sequence into a single
``pipelines.encode`` call — no per-item Python loops, one host<->device
round-trip per field.

The predictor backend is selected by ``CompressorSpec.backend``:
``"jax"`` (default) uses the pure-jnp engine in repro.core.predictor;
``"pallas"`` routes compression through the fused Pallas TPU kernel in
repro.kernels.interp3d (interpret mode off-TPU, compiled on TPU; 3-D
fields only — other ranks fall back to jax). Decompression always replays
through the jax engine; both backends quantize with the same arithmetic,
so the error-bound contract holds either way.
"""
from __future__ import annotations

import dataclasses
import json
import struct
import threading
import time
import zlib

import jax.numpy as jnp
import numpy as np

from . import blocks as blk
from . import frames as frames_mod
from . import lorenzo as lor
from .errors import BoundViolationError, ContainerError, DamageReport, FrameCRCError, SpecError
from .retry import RetryPolicy
from .autotune import (
    DEFAULT_STRIDES,
    PredictorPlan,
    autotune,
    autotune_plan,
    levels_for_stride,
    plan_signature,
    stats_bucket,
)
from .lossless import orchestrate, pipelines
from .lossless.flenc import fl_decode, fl_encode
from .predictor import compress_blocks, decompress_blocks
from .reorder import reorder_codes_batch, restore_codes_batch, restore_codes_batch_device
from .serial import pack_obj, unpack_obj
from .stencils import SPLINES, build_steps

MAGIC_V1 = b"CSZH1\n"
MAGIC = b"CSZH2\n"
MAGIC_V3 = frames_mod.MAGIC_V3  # chunked frame streams (repro.core.frames)

_PREDICTORS = ("interp", "auto", "lorenzo", "offset1d")
_BACKENDS = ("jax", "pallas")
_ENGINES = ("auto", "numpy", "device")
_EB_MODES = ("rel", "abs", "pw_rel")
_VERIFY_MODES = ("off", "sample", "full")
_ANCHOR_STRIDES = (4, 8, 16)  # power-of-two strides the 17^ndim block supports

# Bound-verification knobs: "sample" checks at most this many points
# (deterministic stride sample over the flat field), the repair ladder
# re-encodes at a halved bound up to `attempts` times before raising
# BoundViolationError (core/retry.py policy shape: no sleeping — repair
# is CPU work, not a flaky transport).
_VERIFY_SAMPLE = 1 << 16
_REPAIR_POLICY = RetryPolicy(attempts=3, base_delay=0.0, max_delay=0.0, jitter=0.0,
                             retry_on=(BoundViolationError,))
_REPAIR_TIGHTEN = 0.5
# Enforcement slack: quantization guarantees err <= eb in exact arithmetic,
# but f32 reconstruction rounds — a clean encode can land a point at
# eb * (1 + few-ulp). The systemwide contract (tests, benches) already
# allows 1e-4 relative; enforcing tighter here would "repair" correct
# containers at a real CR cost. Genuine violations (a wrong code is >= 2eb
# off) clear this slack by orders of magnitude.
_VERIFY_SLACK = 1e-4

# Test-only fault hook (repro.testing.faults.perturb_quant_codes): called
# with the quantization-code block batch right after the predictor, before
# reorder/encode — lets the chaos suite inject a real bound violation that
# verify= must catch. None in production.
_CODE_FAULT = None

# ---------------------------------------------------------------- spec grammar
# Canonical compression-spec string grammar (the single spec entry point
# shared by repro.io, the compressd protocol, `serve --kv-spec`, the
# checkpoint codec's REPRO_CKPT_SPEC, and the benches):
#
#     "lossy" "," <eb_mode> "," <number> { "," key "=" value }
#     "lossy" "," "psnr"    "," <target_dB> { "," key "=" value }
#
# e.g. "lossy,abs,1e-3,predictor=auto" or "lossy,psnr,60,pipeline=cr".
# Tuple-valued keys join their items with ":" ("splines=cubic:linear"),
# booleans are "true"/"false". `CompressorSpec.to_string()` emits the
# canonical form (head + sorted non-default key=value pairs), and
# `from_string(to_string(spec)) == spec` for every valid spec. The
# dataset-level "lossless[,...]" form is handled by repro.io (raw-chunk
# storage); it is not a CompressorSpec.
_SPEC_TUPLE_FIELDS = {"splines", "schemes", "pipeline_candidates", "plan_anchor_strides"}
_SPEC_BOOL_FIELDS = {"autotune", "reorder"}


def _spec_parse_value(key: str, raw: str):
    """Parse one ``key=value`` token of the spec grammar into the typed
    CompressorSpec field value; raises :class:`SpecError` on bad syntax."""
    if key in _SPEC_BOOL_FIELDS:
        low = raw.strip().lower()
        if low in ("true", "1", "yes", "on"):
            return True
        if low in ("false", "0", "no", "off"):
            return False
        raise SpecError(f"spec key {key!r} expects a boolean, got {raw!r}")
    if key in _SPEC_TUPLE_FIELDS:
        items = tuple(t.strip() for t in raw.split(":") if t.strip())
        if not items:
            raise SpecError(f"spec key {key!r} expects ':'-joined items, got {raw!r}")
        if key == "plan_anchor_strides":
            try:
                return tuple(int(t) for t in items)
            except ValueError as e:
                raise SpecError(f"spec key {key!r} expects integers, got {raw!r}") from e
        return items
    if key == "anchor_stride":
        try:
            return int(raw)
        except ValueError as e:
            raise SpecError(f"spec key {key!r} expects an integer, got {raw!r}") from e
    if key in ("eb", "psnr_target"):
        try:
            return float(raw)
        except ValueError as e:
            raise SpecError(f"spec key {key!r} expects a number, got {raw!r}") from e
    return raw.strip()


def _spec_format_value(key: str, value) -> str:
    if key in _SPEC_BOOL_FIELDS:
        return "true" if value else "false"
    if key in _SPEC_TUPLE_FIELDS:
        return ":".join(str(v) for v in value)
    if isinstance(value, float):
        return repr(value)  # shortest round-tripping float repr
    return str(value)


@dataclasses.dataclass(frozen=True)
class CompressorSpec:
    eb: float = 1e-3
    eb_mode: str = "rel"                  # "rel": eb * value range (paper); "abs"
    predictor: str = "interp"             # interp | auto (plan-driven) | lorenzo | offset1d
    pipeline: str = "cr"                  # any registered pipeline, or "auto"
    anchor_stride: int = 16               # 16 = cuSZ-Hi; 8 = cuSZ-I layout
    autotune: bool = True
    splines: tuple = ("cubic", "cubic", "cubic", "cubic")
    schemes: tuple = ("md", "md", "md", "md")
    reorder: bool = True
    backend: str = "jax"                  # jax | pallas (fused interp3d kernel)
    # lossless encoding engine (repro.core.lossless.engine): "numpy" runs the
    # reference host stages, "device" keeps the code stream on device through
    # scatter/reorder/entropy-encode (jit/Pallas stage kernels), "auto" uses
    # the device engine exactly when the stream is already device-resident
    # (the sharded path) and the host path otherwise. All three produce
    # byte-identical containers — the engine carries a bit-identity contract.
    engine: str = "auto"
    # pipeline="auto" only: restrict the orchestrator's search space, e.g. to
    # orchestrate.portable_pipelines() for artifacts that must restore on any
    # machine. None = every registered pipeline.
    pipeline_candidates: tuple | None = None
    # predictor="auto" only: anchor strides the planner explores.
    plan_anchor_strides: tuple = DEFAULT_STRIDES
    # PSNR-target mode: instead of a fixed bound, binary-search the abs eb
    # over a sampled trial compress until the reconstruction PSNR lands on
    # this target (dB). The searched eb_abs is recorded in the container
    # header like any other, so decode is oblivious. Mutually exclusive
    # with eb_mode="pw_rel" (the search runs in the abs-bound domain).
    psnr_target: float | None = None
    # Post-compression bound verification: "sample" (default) decodes the
    # fresh container and checks the error bound on a deterministic point
    # sample, "full" checks every point, "off" trusts the encoder (the
    # pre-PR-10 behavior). A violation auto-repairs: re-encode at a
    # tightened bound under a bounded retry ladder, recorded in
    # last_telemetry["verify"]; BoundViolationError only when exhausted.
    verify: str = "sample"

    def __post_init__(self):
        if self.verify not in _VERIFY_MODES:
            raise ValueError(f"unknown verify mode {self.verify!r}; one of {_VERIFY_MODES}")
        if self.pipeline != "auto" and self.pipeline not in pipelines.PIPELINES:
            raise ValueError(
                f"unknown pipeline {self.pipeline!r}; registered pipelines: "
                f"{', '.join(sorted(pipelines.PIPELINES))} (or 'auto')"
            )
        if self.pipeline_candidates is not None and not self.pipeline_candidates:
            raise ValueError("pipeline_candidates must be None or a non-empty sequence of pipeline names")
        for nm in self.pipeline_candidates or ():
            pipelines.get_pipeline(nm)  # raises with the registered list
        if self.predictor not in _PREDICTORS:
            raise ValueError(f"unknown predictor {self.predictor!r}; one of {_PREDICTORS}")
        if self.backend not in _BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; one of {_BACKENDS}")
        if self.engine not in _ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}; one of {_ENGINES}")
        if self.eb_mode not in _EB_MODES:
            raise ValueError(f"unknown eb_mode {self.eb_mode!r}; one of {_EB_MODES}")
        for st in (self.anchor_stride,) + tuple(self.plan_anchor_strides):
            if st not in _ANCHOR_STRIDES:
                raise ValueError(f"unsupported anchor stride {st}; one of {_ANCHOR_STRIDES}")
        for s in self.splines:
            if s not in SPLINES:
                raise ValueError(f"unknown spline {s!r}; one of {SPLINES}")
        for s in self.schemes:
            if s != "md" and s != "1d" and not s.startswith("1d-"):
                raise ValueError(f"unknown scheme {s!r}; 'md', '1d', or '1d-<perm>'")
        if self.eb_mode == "pw_rel" and not (self.eb > 0):
            raise ValueError(f"eb_mode='pw_rel' needs eb > 0, got {self.eb}")
        if self.psnr_target is not None:
            if not (float(self.psnr_target) > 0) or not np.isfinite(self.psnr_target):
                raise ValueError(f"psnr_target must be a positive finite dB value, got {self.psnr_target}")
            if self.eb_mode == "pw_rel":
                raise ValueError("psnr_target is incompatible with eb_mode='pw_rel' "
                                 "(the eb search runs in the abs-bound domain)")

    @property
    def levels(self) -> tuple:
        return levels_for_stride(self.anchor_stride)

    # ------------------------------------------------------- spec strings
    @classmethod
    def from_string(cls, spec: str) -> "CompressorSpec":
        """Parse the canonical compression-spec grammar (module comment
        above): ``"lossy,<eb_mode>,<eb>[,key=value...]"`` or
        ``"lossy,psnr,<target>[,key=value...]"``. Raises
        :class:`repro.core.errors.SpecError` (a ``ValueError``) for bad
        grammar, unknown keys, or values the spec rejects."""
        parts = [p.strip() for p in str(spec).split(",")]
        if not parts or not parts[0]:
            raise SpecError("empty compression spec")
        if parts[0] == "lossless":
            raise SpecError(
                "'lossless' is a dataset-level spec (raw chunk storage, see repro.io); "
                "CompressorSpec is error-bounded — use 'lossy,<mode>,<eb>'")
        if parts[0] != "lossy":
            raise SpecError(f"compression spec must start with 'lossy', got {parts[0]!r} "
                            f"(full spec: {spec!r})")
        if len(parts) < 3:
            raise SpecError(f"lossy spec needs 'lossy,<mode>,<value>', got {spec!r}")
        mode = parts[1]
        kw: dict = {}
        if mode == "psnr":
            kw["psnr_target"] = _spec_parse_value("psnr_target", parts[2])
        elif mode in _EB_MODES:
            kw["eb_mode"] = mode
            kw["eb"] = _spec_parse_value("eb", parts[2])
        else:
            raise SpecError(f"unknown error-bound mode {mode!r}; one of "
                            f"{', '.join(_EB_MODES)} or 'psnr'")
        allowed = {f.name for f in dataclasses.fields(cls)}
        for tok in parts[3:]:
            if "=" not in tok:
                raise SpecError(f"expected key=value, got {tok!r} (full spec: {spec!r})")
            key, _, raw = tok.partition("=")
            key = key.strip()
            if key not in allowed:
                raise SpecError(f"unknown spec key {key!r}; allowed: {', '.join(sorted(allowed))}")
            if key in kw:
                raise SpecError(f"duplicate spec key {key!r} in {spec!r}")
            kw[key] = _spec_parse_value(key, raw)
        try:
            return cls(**kw)
        except SpecError:
            raise
        except (ValueError, TypeError) as e:
            raise SpecError(f"invalid compression spec {spec!r}: {e}") from e

    def to_string(self) -> str:
        """Canonical spec string: ``from_string(spec.to_string()) == spec``
        for every valid spec. Non-default fields append as sorted
        ``key=value`` pairs after the ``lossy,<mode>,<value>`` head."""
        defaults = {f.name: f.default for f in dataclasses.fields(self)}
        if (self.psnr_target is not None and self.eb == defaults["eb"]
                and self.eb_mode == defaults["eb_mode"]):
            head = f"lossy,psnr,{_spec_format_value('psnr_target', self.psnr_target)}"
            skip = {"eb", "eb_mode", "psnr_target"}
        else:
            head = f"lossy,{self.eb_mode},{_spec_format_value('eb', self.eb)}"
            skip = {"eb", "eb_mode"}
        pairs = []
        for name in sorted(defaults):
            if name in skip:
                continue
            value = getattr(self, name)
            if value == defaults[name] or value is None:
                continue
            pairs.append(f"{name}={_spec_format_value(name, value)}")
        return ",".join([head] + pairs)


def _sections_pack(header: dict, sections: list[bytes]) -> bytes:
    """Container v2: binary header + u32/u64 section table."""
    hb = pack_obj(header)
    out = bytearray(MAGIC)
    out += struct.pack("<I", len(hb))
    out += hb
    out += struct.pack("<I", len(sections))
    for s in sections:
        out += struct.pack("<Q", len(s))
    for s in sections:
        out += s
    return bytes(out)


def _sections_pack_v1(header: dict, sections: list[bytes]) -> bytes:
    """Legacy container writer (JSON header), kept for compat tests/tools."""
    header = dict(header, _sizes=[len(s) for s in sections])
    hj = json.dumps(header).encode()
    return MAGIC_V1 + len(hj).to_bytes(8, "little") + hj + b"".join(sections)


def _sections_unpack(buf: bytes):
    if buf[: len(MAGIC)] == MAGIC:  # v2: binary header + section table
        off = len(MAGIC)
        (hlen,) = struct.unpack_from("<I", buf, off)
        off += 4
        header = unpack_obj(buf[off : off + hlen])
        off += hlen
        (nsec,) = struct.unpack_from("<I", buf, off)
        off += 4
        sizes = struct.unpack_from(f"<{nsec}Q", buf, off)
        off += 8 * nsec
        sections = []
        for sz in sizes:
            sections.append(buf[off : off + sz])
            off += sz
        return header, sections
    if buf[: len(MAGIC_V1)] == MAGIC_V1:  # v1: JSON header, sizes inline
        off = len(MAGIC_V1)
        hlen = int.from_bytes(buf[off : off + 8], "little")
        off += 8
        header = json.loads(bytes(buf[off : off + hlen]))
        off += hlen
        sections = []
        for sz in header["_sizes"]:
            sections.append(buf[off : off + sz])
            off += sz
        return header, sections
    raise ValueError(f"bad container magic {bytes(buf[:6])!r}; expected {MAGIC!r} or {MAGIC_V1!r}")


class _PerCallState(threading.local):
    """Per-thread observability slots of a (possibly shared) Compressor.

    One Compressor may serve many threads at once (the compressd worker
    pool, shard_decompress's frame decoders): every per-call record —
    telemetry, damage report, winning plan, the multi-chunk hold flag —
    lives here so concurrent calls never see each other's state. The
    public ``last_*`` attributes are compatibility views over this
    storage: same-thread call-then-read behaves exactly as before.
    """

    telemetry = None
    damage = None
    plan = None
    hold = False


class Compressor:
    def __init__(self, spec: CompressorSpec | None = None, *, plan_cache=None, **kw):
        self.spec = spec or CompressorSpec(**kw)
        # Optional repro.core.plancache.PlanCache (shareable across
        # compressors and threads): memoizes the tuning outcome per field
        # signature so recurring shapes skip re-autotuning. None (the
        # default) = tune every call, the historical behavior.
        self.plan_cache = plan_cache
        # Per-call observability, stored per-*thread* (see _PerCallState):
        #   last_plan — the winning PredictorPlan of the last predictor=
        #     "auto" compress() on this thread (observability only; the
        #     container header records everything decode needs).
        #   last_telemetry — reset by compress() and decompress(); records
        #     the requested backend/engine plus every fallback the ladder
        #     took (pallas predictor -> jax, device encode/reorder/pack/
        #     decode -> numpy), the plan-cache outcome ("plan_cache":
        #     "hit"/"miss") and the chosen pipeline. decompress()
        #     additionally records a "decode" dict (engine, out, seconds,
        #     bytes, mbps). The bit-identity contract makes fallbacks
        #     invisible in the output bytes, so this dict is how
        #     degradation stays observable.
        #   last_damage — reset by decompress(); under on_error="skip"/
        #     "fill" records the DamageReport and the per-chunk intact
        #     mask of a salvaged v3 container (None = fully intact).
        self._call = _PerCallState()

    # ---- compatibility views over the per-thread call state: a thread
    # reads exactly what its own calls recorded, never a concurrent one's
    @property
    def last_plan(self):
        return self._call.plan

    @last_plan.setter
    def last_plan(self, value):
        self._call.plan = value

    @property
    def last_telemetry(self):
        return self._call.telemetry

    @last_telemetry.setter
    def last_telemetry(self, value):
        self._call.telemetry = value

    @property
    def last_damage(self):
        return self._call.damage

    @last_damage.setter
    def last_damage(self, value):
        self._call.damage = value

    @property
    def _telemetry_hold(self):
        return self._call.hold

    @_telemetry_hold.setter
    def _telemetry_hold(self, value):
        self._call.hold = bool(value)

    def _telemetry(self) -> dict:
        if self.last_telemetry is None:
            self.last_telemetry = {"backend": self.spec.backend, "engine": self.spec.engine,
                                   "fallbacks": []}
        return self.last_telemetry

    def _record_fallback(self, point: str, src: str, dst: str, err: Exception) -> None:
        self._telemetry()["fallbacks"].append(
            {"point": point, "from": src, "to": dst, "error": repr(err)}
        )

    # ------------------------------------------------------------------ utils
    def _abs_eb(self, x: np.ndarray) -> float:
        if self.spec.eb_mode == "abs":
            return float(self.spec.eb)
        # range in f64: a float32 max-min of an extreme-range field
        # (|x| near 3e38) overflows to inf and poisons the bound
        rng = (float(np.max(x)) - float(np.min(x))) if x.size else 0.0
        return float(self.spec.eb) * rng

    @staticmethod
    def _spatial_view(x: np.ndarray):
        """Fold >3-D arrays into (batch, spatial<=3)."""
        nd = min(x.ndim, 3)
        spatial = x.shape[x.ndim - nd :]
        batch = int(np.prod(x.shape[: x.ndim - nd], dtype=np.int64)) if x.ndim > nd else 1
        return x.reshape((batch,) + spatial), spatial

    # -------------------------------------------------------------- compress
    def compress(self, x: np.ndarray) -> bytes:
        """Compress ``x`` to a v1/v2 container under the spec's bound.

        Two guarantees ride on top of the raw pipeline:

        * **Non-finite-safe ingest** — NaN/±Inf points (masked ocean
          cells, sensor dropouts, blowups) are detected up front, pulled
          out into a packed bitmap + exact bit patterns, and replaced
          with an inert finite fill before prediction; decode restores
          the original bit patterns exactly. Finite fields pay nothing
          (one ``isfinite`` scan, unchanged bytes). Fields that are
          entirely non-finite short-circuit to a trivial container.
        * **Bound verification** — under ``spec.verify`` ("sample" by
          default) the fresh container is decoded and checked against
          the declared bound; a violation re-encodes at a tightened
          bound (bounded ladder) and raises
          :class:`~repro.core.errors.BoundViolationError` only when
          repair is exhausted. See ``last_telemetry["verify"]``.
        """
        if not self._telemetry_hold:
            self.last_telemetry = None
        self._telemetry()
        x = np.ascontiguousarray(x, np.float32)
        fin = np.isfinite(x)
        if not fin.all():
            return self._compress_nonfinite(x, fin)
        return self._compress_finite(x)

    def _compress_finite(self, x: np.ndarray) -> bytes:
        """The historical compress body: ``x`` is canonical f32, all-finite."""
        sp = self.spec
        if sp.eb_mode == "pw_rel":
            buf = self._compress_pw_rel(x)
            return self._verify_repair(x, buf, bound=float(sp.eb), rel=True)
        psnr_hdr = {}
        if sp.psnr_target is not None:
            eb_abs = self._psnr_target_eb(x)
            psnr_hdr["psnr_target"] = float(sp.psnr_target)
        else:
            eb_abs = self._abs_eb(x)
        base_hdr = {
            "shape": list(x.shape),
            "predictor": sp.predictor,
            "eb_abs": eb_abs,
            "anchor_stride": sp.anchor_stride,
            **psnr_hdr,
        }
        if eb_abs == 0.0:  # constant field (or degenerate): store verbatim min
            buf = _sections_pack(dict(base_hdr, mode="const"), [np.float32(x.reshape(-1)[0] if x.size else 0).tobytes()])
            return self._verify_repair(x, buf, bound=0.0, rel=False)
        if sp.predictor in ("interp", "auto"):
            buf = self._compress_interp(x, eb_abs, base_hdr)
        elif sp.predictor == "lorenzo":
            buf = self._compress_lorenzo(x, eb_abs, base_hdr)
        elif sp.predictor == "offset1d":
            buf = self._compress_offset1d(x, eb_abs, base_hdr)
        else:
            raise ValueError(sp.predictor)
        return self._verify_repair(x, buf, bound=eb_abs, rel=False)

    # ---------------------------------------------------- non-finite ingest
    def _compress_nonfinite(self, x: np.ndarray, fin: np.ndarray) -> bytes:
        """Canonicalization pass for fields carrying NaN/±Inf.

        The non-finite points are recorded as ``[packbits(mask),
        zlib(u32 bit patterns)]`` sections of an ``"nfsafe"`` wrapper
        container (mode is the versioned header extension — old readers
        of *finite* containers are untouched, and a finite field never
        pays a byte); the field itself, with non-finite points replaced
        by the median of the finite points, rides the normal path as a
        complete inner container, so plan caching / engines / verify all
        apply. Decode restores the exact original bit patterns (NaN
        payloads included). An entirely non-finite field short-circuits
        to a trivial ``"nonfinite"`` container of just the patterns.
        """
        mask = ~fin
        n_bad = int(np.count_nonzero(mask))
        flat = x.reshape(-1)
        pats = flat.view(np.uint32)[mask.reshape(-1)]
        tel = self._telemetry()
        tel["nonfinite"] = {"n": n_bad, "total": int(x.size)}
        if n_bad == x.size:  # nothing finite to predict from: patterns only
            header = {"shape": list(x.shape), "mode": "nonfinite", "n_nonfinite": n_bad}
            return _sections_pack(header, [zlib.compress(pats.tobytes(), 6)])
        fill = float(np.median(flat[fin.reshape(-1)]))
        xf = x.copy()
        xf[mask] = np.float32(fill)
        ibuf = self._compress_finite(xf)
        header = {"shape": list(x.shape), "mode": "nfsafe", "n_nonfinite": n_bad,
                  "fill": fill}
        return _sections_pack(header, [ibuf, np.packbits(mask.reshape(-1)).tobytes(),
                                       zlib.compress(pats.tobytes(), 6)])

    def _decompress_nonfinite(self, header, sections, shape) -> np.ndarray:
        pats = np.frombuffer(zlib.decompress(sections[0]), np.uint32)
        return pats.copy().view(np.float32).reshape(shape)

    def _decompress_nfsafe(self, header, sections, shape, device: bool = False) -> np.ndarray:
        ihdr, isec = _sections_unpack(sections[0])
        y = np.asarray(self._decompress_sections(ihdr, isec, device=device))
        flat = y.reshape(-1).astype(np.float32).copy()
        mask = np.unpackbits(np.frombuffer(sections[1], np.uint8), count=flat.size).astype(bool)
        pats = np.frombuffer(zlib.decompress(sections[2]), np.uint32)
        flat.view(np.uint32)[mask] = pats  # exact bit patterns, NaN payloads included
        return flat.reshape(shape)

    # ------------------------------------------------ bound verification
    def _verify_check(self, x: np.ndarray, buf: bytes, *, bound: float, rel: bool):
        """Decode ``buf`` and measure the worst error vs the all-finite
        ``x``: absolute error, or point-wise relative error (``rel=True``,
        zeros must reconstruct as zeros). Sample mode checks a
        deterministic ≤``_VERIFY_SAMPLE``-point stride sample. Returns
        ``(max_err, n_checked)``."""
        hold, self._telemetry_hold = self._telemetry_hold, True
        try:
            y = self.decompress(buf)
        finally:
            self._telemetry_hold = hold
        xf = x.reshape(-1).astype(np.float64)
        yf = np.asarray(y, np.float64).reshape(-1)
        if self.spec.verify == "sample" and xf.size > _VERIFY_SAMPLE:
            idx = np.linspace(0, xf.size - 1, _VERIFY_SAMPLE).astype(np.int64)
            xf, yf = xf[idx], yf[idx]
        if not xf.size:
            return 0.0, 0
        if rel:
            nz = xf != 0.0
            err = float(np.max(np.abs(yf[nz] - xf[nz]) / np.abs(xf[nz]))) if nz.any() else 0.0
            if np.any(yf[~nz] != 0.0):  # exact-zero contract of pw_rel
                err = float("inf")
            return err, int(xf.size)
        return float(np.max(np.abs(yf - xf))), int(xf.size)

    def _repair_encode(self, x: np.ndarray, eb_new: float, rel: bool) -> bytes:
        """One rung of the repair ladder: re-encode at a tightened bound.

        Abs-domain repairs pin ``eb_mode="abs"`` (the tightened value IS
        the new absolute bound, whatever mode derived the original);
        pw_rel repairs tighten the relative bound. The inner compressor
        runs ``verify="off"`` — the ladder re-verifies against the
        *original* bound itself."""
        sp = self.spec
        if rel:
            rspec = dataclasses.replace(sp, eb=float(eb_new), verify="off")
        else:
            rspec = dataclasses.replace(sp, eb_mode="abs", eb=float(eb_new),
                                        psnr_target=None, verify="off")
        inner = Compressor(rspec, plan_cache=self.plan_cache)
        buf = inner.compress(x)
        itel = inner.last_telemetry or {}
        self._telemetry()["fallbacks"].extend(itel.get("fallbacks") or ())
        return buf

    def _verify_repair(self, x: np.ndarray, buf: bytes, *, bound: float, rel: bool) -> bytes:
        """Post-encode bound enforcement (``spec.verify`` != "off").

        Decode-and-check the fresh container; on violation re-encode at a
        halved bound, re-verify against the ORIGINAL bound, up to
        ``_REPAIR_POLICY.attempts`` rungs, then raise
        :class:`BoundViolationError`. The outcome — mode, points checked,
        worst error, bound, repair count — lands in
        ``last_telemetry["verify"]`` either way."""
        sp = self.spec
        if sp.verify == "off":
            return buf
        tel = self._telemetry()
        max_err, checked = self._verify_check(x, buf, bound=bound, rel=rel)
        repairs = 0
        cur = float(bound)
        limit = bound * (1.0 + _VERIFY_SLACK) + 1e-12  # f32 rounding headroom
        while max_err > limit:
            if repairs >= _REPAIR_POLICY.attempts or cur <= 0.0:
                tel["verify"] = {"mode": sp.verify, "checked": checked,
                                 "max_err": max_err, "bound": bound, "repairs": repairs}
                raise BoundViolationError(
                    f"bound violation survived {repairs} repair(s): max err "
                    f"{max_err:.6g} > declared bound {bound:.6g} "
                    f"(verify={sp.verify!r}, {checked} points checked)",
                    max_err=max_err, bound=bound, repairs=repairs)
            repairs += 1
            cur *= _REPAIR_TIGHTEN
            try:
                buf = self._repair_encode(x, cur, rel)
            except ValueError as e:  # tightened bound fell off the codec's range
                tel["verify"] = {"mode": sp.verify, "checked": checked,
                                 "max_err": max_err, "bound": bound, "repairs": repairs}
                raise BoundViolationError(
                    f"bound violation (max err {max_err:.6g} > {bound:.6g}) and repair "
                    f"rung {repairs} cannot encode at eb={cur:.6g}: {e}",
                    max_err=max_err, bound=bound, repairs=repairs) from e
            max_err, checked = self._verify_check(x, buf, bound=bound, rel=rel)
        tel["verify"] = {"mode": sp.verify, "checked": checked, "max_err": max_err,
                         "bound": bound, "repairs": repairs}
        return buf

    def _encode_codes(self, seq, pipeline_override: str | None = None) -> tuple[bytes, dict]:
        """Lossless-encode the code stream; returns (payload, header fields).

        ``pipeline="auto"`` routes through the orchestrator: the chosen
        pipeline plus the sampled statistics land in the container header
        (per field), so the selection is recorded, reproducible, and never
        re-inferred at decode time. ``pipeline_override`` (a plan-cache
        hit replaying the pipeline the orchestrator chose for this field
        signature) short-circuits the sampling/scoring pass and encodes
        with the recorded pipeline directly; the header carries
        ``pcached=True`` instead of the orchestrator's ``pchoice`` record.

        Engine dispatch: ``spec.engine`` decides whether ``seq`` is encoded
        by the numpy reference stages or the device engine
        (repro.core.lossless.engine); ``"auto"`` keeps whatever residency
        the stream already has. Either way the payload bytes are identical
        (the engine's bit-identity contract), so the header carries no
        engine field and decode never knows.

        Fallback ladder: a device-engine failure (lowering, OOM, a dead
        accelerator) pulls the stream to host and retries the numpy
        reference path — bit-identical output, recorded in
        ``last_telemetry`` so the degradation is observable, never silent.
        """
        sp = self.spec
        is_dev = pipelines._is_jax(seq)
        if sp.engine == "device" and not is_dev:
            try:
                seq = jnp.asarray(np.ascontiguousarray(seq, np.uint8))
            except Exception as e:  # device placement itself failed
                self._record_fallback("encode", "device", "numpy", e)
        elif sp.engine == "numpy" and is_dev:
            seq = np.asarray(seq)
        fixed = sp.pipeline if sp.pipeline != "auto" else pipeline_override
        if fixed is not None:
            hdr = {"pipeline": fixed}
            if sp.pipeline == "auto":
                hdr["pcached"] = True  # plan-cache replay, not a spec-fixed pipeline
            self._telemetry()["pipeline"] = fixed
            try:
                return pipelines.encode(seq, fixed), hdr
            except Exception as e:
                if not pipelines._is_jax(seq):
                    raise  # host reference path: a real error, not a device fault
                self._record_fallback("encode", "device", "numpy", e)
                seq = np.asarray(seq)
            return pipelines.encode(seq, fixed), hdr
        histogram = None
        if sp.backend == "pallas" and not pipelines._is_jax(seq):
            import jax

            from repro.kernels.histogram import histogram256_pallas

            interpret = jax.devices()[0].platform != "tpu"
            histogram = lambda d: histogram256_pallas(d, interpret=interpret)  # noqa: E731
        try:
            payload, record = orchestrate.encode_auto(
                seq, candidates=sp.pipeline_candidates, histogram=histogram
            )
        except Exception as e:
            if pipelines._is_jax(seq):
                self._record_fallback("encode", "device", "numpy", e)
                seq, histogram = np.asarray(seq), None
            elif histogram is not None:  # pallas histogram hook failed
                self._record_fallback("histogram", "pallas", "numpy", e)
                histogram = None
            else:
                raise
            payload, record = orchestrate.encode_auto(seq, candidates=sp.pipeline_candidates,
                                                      histogram=histogram)
        self._telemetry()["pipeline"] = record["pipeline"]
        return payload, {"pipeline": record["pipeline"], "pchoice": record}

    @staticmethod
    def inspect(buf: bytes) -> dict:
        """Container header + section sizes, without decompressing.

        Plan-driven containers (``predictor="auto"``) additionally expose
        the winning :class:`~repro.core.autotune.PredictorPlan` under
        ``pplan`` — assembled from the serialized header fields, which is
        why a plan costs the container nothing over a fixed spec.

        v3 (chunked) containers return the global header plus a ``frames``
        list with each frame's inspect dict and byte size, a per-frame
        ``frame_crc_ok`` mask, and — for damaged streams — a ``damage``
        :class:`~repro.core.errors.DamageReport` (inspect never raises for
        frame-level damage; it is the damage-assessment tool).
        """
        if frames_mod.is_v3(buf):
            try:
                header, table = frames_mod.frame_table(buf)
            except ContainerError:
                # structurally damaged stream: report what a salvage pass
                # would recover instead of refusing to look at it
                header = frames_mod.read_header(buf)
                good, report = frames_mod.scan_frames(buf)
                out = dict(header, n_frames=len(good), frame_bytes=[len(p) for _, p in good],
                           frame_indices=[i for i, _ in good], damage=report)
                if header.get("kind") == "chunks":
                    out["frames"] = [Compressor.inspect(p) for _, p in good]
                return out
            crc_ok, payloads = [], []
            for t in table:
                try:
                    payloads.append(frames_mod.read_frame(buf, t))
                    crc_ok.append(True)
                except FrameCRCError:
                    payloads.append(None)
                    crc_ok.append(False)
            out = dict(header, n_frames=len(table), frame_bytes=[size for _, size, _ in table],
                       frame_crc_ok=crc_ok)
            if not all(crc_ok):
                report = DamageReport(declared_frames=len(table), frames_ok=sum(crc_ok),
                                      frames_damaged=len(table) - sum(crc_ok))
                for i, ok in enumerate(crc_ok):
                    if not ok:
                        report.add("crc", table[i][0], index=i, detail="payload CRC32 mismatch")
                out["damage"] = report
            if header.get("kind") == "chunks":  # frames are themselves containers
                out["frames"] = [None if p is None else Compressor.inspect(p) for p in payloads]
            return out
        header, sections = _sections_unpack(buf)
        out = dict(header, section_bytes=[len(s) for s in sections])
        # wrapper modes: section 0 is a full inner container
        if header.get("mode") in ("pw_rel", "nfsafe"):
            out["inner"] = Compressor.inspect(bytes(sections[0]))
        if header.get("mode") == "interp" and header.get("predictor") == "auto" and "splines" in header:
            out["pplan"] = {
                "ndim": len(header["padded"]),
                "anchor_stride": int(header["anchor_stride"]),
                "splines": list(header["splines"]),
                "schemes": list(header["schemes"]),
            }
        return out

    def _run_predictor(self, blocks: np.ndarray, eb_abs: float, steps, stride: int, ndim: int):
        """Dispatch the fused predict+quantize over the whole block batch.

        Returns backend-native arrays (device for the jax backend) — the
        host path converts, the device-engine path keeps them resident.

        A Pallas lowering/runtime failure falls back to the jax engine —
        both backends quantize with the same arithmetic, so the output is
        identical; the fallback lands in ``last_telemetry``.
        """
        if self.spec.backend == "pallas" and ndim == 3:
            try:
                from repro.kernels.interp3d import compress_blocks_pallas

                codes_b, outl_b, _ = compress_blocks_pallas(blocks, 2.0 * eb_abs, steps, stride)
                return self._maybe_fault_codes(codes_b), outl_b
            except Exception as e:
                self._record_fallback("predictor", "pallas", "jax", e)
        codes_b, outl_b, _ = compress_blocks(jnp.asarray(blocks), jnp.float32(2.0 * eb_abs), steps, stride)
        return self._maybe_fault_codes(codes_b), outl_b

    @staticmethod
    def _maybe_fault_codes(codes_b):
        """Apply the chaos-suite code-perturbation hook (module-level
        ``_CODE_FAULT``, armed by repro.testing.faults.perturb_quant_codes)
        to the fresh quantization codes. The hook must preserve the
        code==0 <=> outlier invariant; it never fires in production."""
        if _CODE_FAULT is None:
            return codes_b
        return _CODE_FAULT(np.asarray(codes_b))

    def _tune_interp(self, blocks: np.ndarray, eb_abs: float, batch: int, padded_shapes,
                     presampled_of: int | None = None):
        """Resolve the (stride, splines, schemes) the predictor will run.

        ``blocks`` is the full block batch, or — for device-parallel callers
        (repro.core.distributed) that only pulled the tuning sample to host —
        the pre-gathered sample with ``presampled_of`` the true block count.
        Records ``self.last_plan`` under ``predictor="auto"``.
        """
        sp = self.spec
        if sp.predictor == "auto":
            plan = autotune_plan(blocks, 2.0 * eb_abs, tuple(sp.plan_anchor_strides),
                                 field_shape=(batch,) + tuple(padded_shapes),
                                 trial_pipeline=sp.pipeline if sp.pipeline != "auto" else "cr",
                                 reorder=sp.reorder, presampled_of=presampled_of)
            self.last_plan = plan
            return plan.anchor_stride, plan.splines, plan.schemes
        stride, levels = sp.anchor_stride, sp.levels
        if sp.autotune:
            splines, schemes = autotune(blocks, 2.0 * eb_abs, levels, stride,
                                        presampled=presampled_of is not None)
        else:
            splines, schemes = tuple(sp.splines[: len(levels)]), tuple(sp.schemes[: len(levels)])
        return stride, splines, schemes

    def _pack_interp(self, base_hdr: dict, *, cgrid: np.ndarray, anc: np.ndarray,
                     oi: np.ndarray, ov: np.ndarray, stride: int, splines, schemes,
                     pipeline_override: str | None = None) -> bytes:
        """Assemble the interp container from the post-predictor artifacts.

        Shared tail of the host path and the shard_map path
        (repro.core.distributed): identical inputs produce identical bytes,
        which is what makes a v3 frame bit-equal to an independent
        ``compress()`` of the same shard. ``cgrid`` may be a device array —
        the level reorder then runs as a device gather and the code stream
        flows into the encoding engine without ever visiting host.
        """
        sp = self.spec
        if pipelines._is_jax(cgrid):
            try:
                from .reorder import reorder_codes_batch_device

                seq = reorder_codes_batch_device(cgrid, stride, sp.reorder)
            except Exception as e:  # device reorder failed: host twin, same bytes
                self._record_fallback("reorder", "device", "numpy", e)
                cgrid = np.asarray(cgrid)
                seq = reorder_codes_batch(cgrid, stride, sp.reorder)
        else:
            seq = reorder_codes_batch(cgrid, stride, sp.reorder)
        payload, penc = self._encode_codes(seq, pipeline_override=pipeline_override)
        header = dict(
            base_hdr,
            mode="interp",
            anchor_stride=int(stride),  # may differ from the spec under a plan
            padded=list(cgrid.shape[1:]),
            batch=int(cgrid.shape[0]),
            splines=list(splines),
            schemes=list(schemes),
            reorder=bool(sp.reorder),
            n_outliers=int(oi.size),
            **penc,
        )
        # No separate plan blob: the plan IS (anchor_stride, splines, schemes),
        # already serialized above — zero container overhead vs a fixed spec.
        # Compressor.inspect reassembles the "pplan" view from those fields;
        # the full diagnostics (scores, candidates) stay on self.last_plan.
        anc = anc.astype(np.float32, copy=False)
        return _sections_pack(header, [payload, anc.tobytes(),
                                       oi.astype(np.int64, copy=False).tobytes(),
                                       ov.astype(np.float32, copy=False).tobytes()])

    def _plan_cache_key(self, x: np.ndarray):
        """Plan-cache signature of this field under this spec, or ``None``
        when the call has nothing cacheable (no cache attached, or a fixed
        spec that neither tunes the predictor nor picks a pipeline).

        The key folds in every spec knob that steers the tuners, so one
        cache can safely serve compressors with different specs.
        """
        sp = self.spec
        if self.plan_cache is None or sp.predictor not in ("interp", "auto"):
            return None
        if not (sp.predictor == "auto" or sp.autotune or sp.pipeline == "auto"):
            return None
        extra = (sp.predictor, int(sp.anchor_stride), tuple(sp.plan_anchor_strides),
                 bool(sp.autotune), bool(sp.reorder), sp.pipeline,
                 tuple(sp.pipeline_candidates or ()), sp.psnr_target)
        return plan_signature(x.shape, x.dtype, sp.eb, sp.eb_mode, stats_bucket(x), extra=extra)

    def _compress_interp(self, x: np.ndarray, eb_abs: float, base_hdr: dict) -> bytes:
        sp = self.spec
        xb, spatial = self._spatial_view(x)
        ndim = len(spatial)
        batch = xb.shape[0]
        padded = blk.pad_field_batch(xb, blk.ANCHOR_STRIDE)
        padded_shapes = padded.shape[1:]
        blocks = blk.gather_blocks_batch(padded, blk.ANCHOR_STRIDE)
        # plan cache: a recurring field signature replays the recorded
        # tuning outcome — predictor plan AND (pipeline="auto") the
        # orchestrator's pipeline choice — skipping both tuners entirely
        ckey = self._plan_cache_key(x)
        cached = self.plan_cache.get(ckey) if ckey is not None else None
        pipe_override = None
        if cached is not None:
            self._telemetry()["plan_cache"] = "hit"
            stride = int(cached["stride"])
            splines, schemes = tuple(cached["splines"]), tuple(cached["schemes"])
            if sp.predictor == "auto" and cached.get("plan") is not None:
                self.last_plan = PredictorPlan.from_header(cached["plan"])
            pipe_override = cached.get("pipeline")
        else:
            if ckey is not None:
                self._telemetry()["plan_cache"] = "miss"
            stride, splines, schemes = self._tune_interp(blocks, eb_abs, batch, padded_shapes)
        steps = build_steps(ndim, blk.BLOCK, levels_for_stride(stride), splines, schemes)
        codes_b, outl_b = self._run_predictor(blocks, eb_abs, steps, stride, ndim)
        buf = None
        if sp.engine == "device":
            # fused tail: codes stay device-resident through block scatter,
            # level reorder, and the encoding engine (inside _pack_interp);
            # outliers come from the code==0 <=> outlier invariant the
            # sharded path already relies on — no outlier grid crosses over
            try:
                cgrid = blk.scatter_blocks_batch_jnp(jnp.asarray(codes_b), batch,
                                                     padded_shapes, blk.ANCHOR_STRIDE)
                anc = blk.anchor_grid_batch(padded, stride)
                oi = np.asarray(jnp.flatnonzero(cgrid.reshape(-1) == 0)).astype(np.int64)
                ov = padded.reshape(-1)[oi]
                buf = self._pack_interp(base_hdr, cgrid=cgrid, anc=anc, oi=oi, ov=ov,
                                        stride=stride, splines=splines, schemes=schemes,
                                        pipeline_override=pipe_override)
            except Exception as e:
                # device tail failed (lowering/OOM/dead device): replay the
                # numpy reference tail below — bit-identical container
                self._record_fallback("pack", "device", "numpy", e)
        if buf is None:
            codes_b, outl_b = np.asarray(codes_b), np.asarray(outl_b)
            cgrid = blk.scatter_blocks_batch(codes_b, batch, padded_shapes, blk.ANCHOR_STRIDE)
            ogrid = blk.scatter_blocks_batch(outl_b, batch, padded_shapes, blk.ANCHOR_STRIDE)
            anc = blk.anchor_grid_batch(padded, stride)
            oi = np.flatnonzero(ogrid.reshape(-1)).astype(np.int64)  # already batch-global
            ov = padded.reshape(-1)[oi]
            buf = self._pack_interp(base_hdr, cgrid=cgrid, anc=anc, oi=oi, ov=ov,
                                    stride=stride, splines=splines, schemes=schemes,
                                    pipeline_override=pipe_override)
        if ckey is not None and cached is None:
            plan = self.last_plan if sp.predictor == "auto" else None
            self.plan_cache.put(ckey, {
                "stride": int(stride), "splines": tuple(splines), "schemes": tuple(schemes),
                "plan": None if plan is None else plan.to_header(),
                # pipeline recorded only when the orchestrator chose it —
                # a fixed pipeline needs no replay
                "pipeline": self._telemetry().get("pipeline") if sp.pipeline == "auto" else None,
            })
        return buf

    def _compress_lorenzo(self, x: np.ndarray, eb_abs: float, base_hdr: dict) -> bytes:
        xb, spatial = self._spatial_view(x)
        twoeb = jnp.float32(2.0 * eb_abs)
        codes, outl, cfull, _ = lor.lorenzo_encode(jnp.asarray(xb), twoeb, len(spatial))
        codes, outl, cfull = np.asarray(codes), np.asarray(outl), np.asarray(cfull)
        fi = np.flatnonzero(outl.reshape(-1))
        payload, penc = self._encode_codes(codes.reshape(-1))
        header = dict(base_hdr, mode="lorenzo", batch=int(xb.shape[0]), spatial=list(spatial), n_outliers=int(fi.size), **penc)
        return _sections_pack(header, [payload, fi.astype(np.int64).tobytes(), cfull.reshape(-1)[fi].astype(np.int32).tobytes()])

    def _compress_offset1d(self, x: np.ndarray, eb_abs: float, base_hdr: dict) -> bytes:
        twoeb = jnp.float32(2.0 * eb_abs)
        codes = np.asarray(lor.offset1d_encode(jnp.asarray(x), twoeb))
        payload, hdr = fl_encode(codes)
        header = dict(base_hdr, mode="offset1d", fl=hdr)
        return _sections_pack(header, [payload])

    # ------------------------------------------------------------- pw_rel
    def _compress_pw_rel(self, x: np.ndarray) -> bytes:
        """Point-wise-relative bound (SZ3's ``pw_rel``) via the log-domain
        transform: compress ``y = ln|x|`` under an absolute bound
        ``eb_log < log1p(eb)``, so every nonzero point satisfies
        ``|x'/x - 1| = |exp(y' - y) - 1| <= eb``; signs and exact zeros
        ride packed bitmaps and reconstruct exactly. ``y`` takes the
        existing quantize -> orchestrate -> engine path unchanged (the
        inner payload is a complete v2 container), so plan caching,
        engine selection, and the fallback ladder all apply. The margin
        subtracted from ``log1p(eb)`` covers the float32 storage of the
        log field and the f64->f32 rounding of the reconstruction, making
        the bound hold in delivered float32 arithmetic, not just in exact
        math."""
        sp = self.spec
        eb = float(sp.eb)
        flat = x.reshape(-1)
        zero = flat == 0.0
        nz = ~zero
        # sign over ALL points (not just nonzero): -0.0 compares equal to
        # 0.0 and rides the zero bitmap, so its signbit must be recorded
        # here for the decode side to restore -0.0 bit-exactly
        sign = np.signbit(flat)
        y64 = np.log(np.abs(flat[nz].astype(np.float64)))
        y32 = y64.astype(np.float32)
        cast_err = float(np.max(np.abs(y64 - y32))) if y32.size else 0.0
        slack = 1.2e-7  # f64->f32 rounding of exp(y') on the way back out
        eb_log = (float(np.log1p(eb)) - cast_err - slack) * (1.0 - 2e-4)
        if eb_log <= 0:
            worst = float(np.abs(flat[nz].astype(np.float64))[np.argmax(np.abs(y64 - y32))])
            raise ValueError(
                f"eb={eb:g} is below the float32 pw_rel transform's resolution at "
                f"|x|={worst:.6g} (log-domain cast error {cast_err:.3g} eats the "
                f"whole log1p(eb) budget); use a larger bound or eb_mode='abs'")
        fill = float(y32.min()) if y32.size else 0.0  # zero slots: inert filler
        y = np.full(flat.shape, np.float32(fill), np.float32)
        y[nz] = y32
        inner = Compressor(dataclasses.replace(sp, eb_mode="abs", eb=eb_log, verify="off"),
                           plan_cache=self.plan_cache)
        ibuf = inner.compress(y.reshape(x.shape))
        itel = inner.last_telemetry or {}
        tel = self._telemetry()
        tel["fallbacks"].extend(itel.get("fallbacks") or ())
        for k in ("pipeline", "plan_cache"):
            if k in itel:
                tel[k] = itel[k]
        self.last_plan = inner.last_plan
        header = {"shape": list(x.shape), "mode": "pw_rel", "predictor": sp.predictor,
                  "eb_rel": eb, "eb_abs": float(eb_log), "n_zero": int(zero.sum())}
        return _sections_pack(header, [ibuf, np.packbits(sign).tobytes(),
                                       np.packbits(zero).tobytes()])

    def _decompress_pw_rel(self, header, sections, shape, device: bool = False) -> np.ndarray:
        ihdr, isec = _sections_unpack(sections[0])
        y = np.asarray(self._decompress_sections(ihdr, isec, device=device))
        sign = np.unpackbits(np.frombuffer(sections[1], np.uint8), count=y.size).astype(bool)
        zero = np.unpackbits(np.frombuffer(sections[2], np.uint8), count=y.size).astype(bool)
        out = np.exp(y.reshape(-1).astype(np.float64))
        # zero first, negate second: a signed zero slot (new containers
        # record signbit over all points) becomes -0.0 bit-exactly; old
        # containers never mark a zero slot in `sign`, so the order swap
        # decodes them identically to before
        out[zero] = 0.0
        out[sign] = -out[sign]
        return out.astype(np.float32).reshape(shape)

    # -------------------------------------------------------- psnr target
    def _psnr_trial_field(self, x: np.ndarray) -> np.ndarray:
        """The trial sample the eb search compresses: the field itself when
        small, else a centered <=64-wide crop per axis (a crop keeps the
        field's smoothness structure; a strided subsample would not)."""
        if x.size <= (1 << 20):
            return x
        sl = []
        for d in x.shape:
            if d <= 64:
                sl.append(slice(None))
            else:
                c = d // 2
                sl.append(slice(c - 32, c + 32))
        return np.ascontiguousarray(x[tuple(sl)])

    def _psnr_target_eb(self, x: np.ndarray) -> float:
        """Binary-search the absolute eb whose reconstruction lands on
        ``spec.psnr_target`` dB (range-normalized, full-field range).

        The search runs on MSE, not PSNR — ``mse_target = rng^2 *
        10^(-target/10)`` — so the trial crop's narrower value range
        cannot skew the dB arithmetic, and aims 0.5 dB above target so
        trial-vs-full sampling error stays inside a ±1 dB window. Each
        trial compresses with the cheap fixed configuration: distortion
        is independent of the lossless pipeline (it is lossless) and
        nearly independent of predictor tuning (quantization error is
        ~uniform within ±eb), so the trials skip both tuners."""
        sp = self.spec
        target = float(sp.psnr_target)
        rng = (float(np.max(x)) - float(np.min(x))) if x.size else 0.0
        if rng == 0.0:
            return 0.0  # constant field: verbatim const container, PSNR = inf
        trial = self._psnr_trial_field(x)
        tspec = dataclasses.replace(
            sp, psnr_target=None, eb_mode="abs", eb=1.0,
            predictor="interp" if sp.predictor == "auto" else sp.predictor,
            pipeline="none", pipeline_candidates=None, autotune=False, verify="off")
        mse_aim = rng * rng * 10.0 ** (-(target + 0.5) / 10.0)
        trials = 0

        def mse_at(eb_abs: float) -> float:
            nonlocal trials
            trials += 1
            comp = Compressor(dataclasses.replace(tspec, eb=float(eb_abs)))
            y = comp.decompress(comp.compress(trial))
            d = trial.astype(np.float64) - y.astype(np.float64)
            return float(np.mean(d * d))

        # uniform-quantization model (mse ~ eb^2/3) seeds the bracket
        eb0 = min(float(np.sqrt(3.0 * mse_aim)), 0.25 * rng)
        lo = hi = eb0
        if mse_at(eb0) <= mse_aim:  # feasible: push eb up until it breaks
            grown = False
            for _ in range(8):
                hi = lo * 4.0
                if mse_at(hi) > mse_aim:
                    grown = True
                    break
                lo = hi
            if not grown:
                hi = lo  # even the loosest probe met the target
        else:  # infeasible at the model guess: tighten until it holds
            for _ in range(12):
                lo = lo / 4.0
                if mse_at(lo) <= mse_aim:
                    break
            else:
                raise ValueError(
                    f"psnr_target={target:g} dB unreachable: trial mse "
                    f"{mse_at(lo):.3g} > target {mse_aim:.3g} even at eb={lo:.3g}")
        while hi / lo > 1.02:  # log-bisect, keeping lo on the feasible side
            mid = float(np.sqrt(lo * hi))
            if mse_at(mid) <= mse_aim:
                lo = mid
            else:
                hi = mid
        self._telemetry()["psnr_search"] = {
            "target_db": target, "eb_abs": float(lo), "trials": trials,
            "trial_elems": int(trial.size),
        }
        return float(lo)

    # ------------------------------------------------------------ decompress
    def decompress(self, buf: bytes, frames=None, *, on_error: str = "raise",
                   fill_value: float = 0.0, out: str = "numpy") -> np.ndarray:
        """Decompress a v1/v2/v3 container.

        ``frames``: v3 containers only — an iterable of frame indices to
        decode (any order). The result is the selected chunks concatenated
        along the container's chunk axis in the order given; ``None``
        decodes every frame and reassembles the full field.

        ``out``: ``"numpy"`` (default) returns a host ndarray; ``"device"``
        returns a device-resident ``jax.Array`` — with ``engine="device"``
        (or ``"auto"``, which follows ``out``) the code stream decodes
        through the stages' device twins and stays on device through
        restore/anchor-placement/reconstruction, so the field never
        bounces through host memory. Bytes-for-bytes the result matches
        the numpy path (the engine bit-identity contract); a device decode
        failure falls back to the numpy path and is recorded on
        ``last_telemetry["fallbacks"]``. Each call also records
        ``last_telemetry["decode"]`` (engine, out, seconds, bytes, MB/s).

        ``on_error`` — degraded-mode decode of damaged containers:

        * ``"raise"`` (default): any integrity failure raises the typed
          error (:mod:`repro.core.errors`) — the strict historical
          behavior.
        * ``"skip"``: v3 only — damaged chunks are omitted from the
          reassembled field (the result is shorter along the chunk axis).
        * ``"fill"``: damaged chunks are reconstructed as
          ``fill_value`` blocks of the right shape, so the result keeps
          the container's full geometry.

        Either degraded mode records what happened on ``self.last_damage``
        (``None`` when the container was fully intact): a dict with the
        :class:`~repro.core.errors.DamageReport` under ``"report"`` and
        the per-requested-chunk intact mask under ``"chunks_ok"``.
        """
        if on_error not in ("raise", "skip", "fill"):
            raise ValueError(f"on_error must be 'raise', 'skip' or 'fill', got {on_error!r}")
        if out not in ("numpy", "device"):
            raise ValueError(f"out must be 'numpy' or 'device', got {out!r}")
        hold = self._telemetry_hold
        if not hold:
            self.last_telemetry = None
        tel = self._telemetry()
        want_dev = self.spec.engine == "device" or (self.spec.engine == "auto" and out == "device")
        t0 = time.perf_counter()
        self.last_damage = None
        if frames_mod.is_v3(buf):
            result = self._decompress_v3(buf, frames, on_error=on_error,
                                         fill_value=fill_value, out=out)
        else:
            if frames is not None:
                raise ValueError("frames= is only meaningful for v3 (chunked) containers")
            try:
                header, sections = _sections_unpack(buf)
                result = self._decompress_sections(header, sections, device=want_dev)
            except Exception as e:
                if on_error != "fill":
                    raise
                # salvage a single container only when its header still tells
                # us the field geometry; otherwise there is nothing to fill
                try:
                    header, _ = _sections_unpack(buf)
                    shape = tuple(header["shape"])
                except Exception:
                    raise e from None
                report = DamageReport()
                report.add("decode", 0, index=0, detail=repr(e))
                report.frames_damaged = 1
                self.last_damage = {"report": report, "chunks_ok": [False], "on_error": on_error}
                result = np.full(shape, np.float32(fill_value), np.float32)
        if out == "device" and isinstance(result, np.ndarray):
            result = jnp.asarray(result)
        elif out == "numpy" and not isinstance(result, np.ndarray):
            result = np.asarray(result)
        if not hold:
            if not isinstance(result, np.ndarray):
                result.block_until_ready()  # honest timing for device results
            dt = time.perf_counter() - t0
            tel["decode"] = {
                "engine": "device" if want_dev else "numpy", "out": out,
                "seconds": dt, "bytes": int(result.nbytes),
                "mbps": (result.nbytes / dt / 1e6) if dt > 0 else 0.0,
            }
        return result

    def _decompress_sections(self, header, sections, device: bool = False) -> np.ndarray:
        shape = tuple(header["shape"])
        mode = header["mode"]
        if mode == "const":
            v = np.frombuffer(sections[0], np.float32)[0]
            return np.full(shape, v, np.float32)
        if mode == "interp":
            return self._decompress_interp(header, sections, shape, device=device)
        if mode == "lorenzo":
            return self._decompress_lorenzo(header, sections, shape, device=device)
        if mode == "offset1d":
            codes = fl_decode(sections[0], header["fl"])
            out = lor.offset1d_decode(jnp.asarray(codes), jnp.float32(2.0 * header["eb_abs"]))
            return out.reshape(shape) if device else np.asarray(out).reshape(shape)
        if mode == "pw_rel":
            return self._decompress_pw_rel(header, sections, shape, device=device)
        if mode == "nfsafe":
            return self._decompress_nfsafe(header, sections, shape, device=device)
        if mode == "nonfinite":
            return self._decompress_nonfinite(header, sections, shape)
        raise ValueError(mode)

    def _decompress_interp(self, header, sections, shape, device: bool = False) -> np.ndarray:
        stride = header["anchor_stride"]
        padded_shapes = tuple(header["padded"])
        batch = header["batch"]
        ndim = len(padded_shapes)
        eb_abs = header["eb_abs"]
        psize = int(np.prod(padded_shapes))
        anc_shape = tuple((d - 1) // stride + 1 for d in padded_shapes)
        levels = levels_for_stride(stride)
        # Containers that predate recorded step tables (or hand-rolled v1
        # headers without them) decode with the default cubic/md hierarchy.
        splines = tuple(header.get("splines", ("cubic",) * len(levels)))
        schemes = tuple(header.get("schemes", ("md",) * len(levels)))
        steps = build_steps(ndim, blk.BLOCK, levels, splines, schemes)
        spatial = shape[len(shape) - ndim :] if len(shape) >= ndim else shape
        sl = (slice(None),) + tuple(slice(0, s) for s in spatial)
        anc = np.frombuffer(sections[1], np.float32)
        oi = np.frombuffer(sections[2], np.int64)
        ov = np.frombuffer(sections[3], np.float32)
        if device:
            # device-resident tail: codes decode through the stage twins and
            # every hop to the reconstructed field is a jnp gather — same
            # bytes as the numpy path below (bit-identity contract)
            try:
                seq = pipelines.decode(sections[0], device=True)
                cgrid = restore_codes_batch_device(seq, batch, padded_shapes, fill=128,
                                                   stride=stride, reorder=header.get("reorder", True))
                agrid = blk.place_anchors_batch_jnp(
                    padded_shapes, jnp.asarray(anc).reshape((batch,) + anc_shape), stride)
                ovflat = jnp.zeros(batch * psize, jnp.float32)
                if oi.size:  # outlier indices are batch-global and unique
                    ovflat = ovflat.at[jnp.asarray(oi)].set(jnp.asarray(ov))
                ovgrid = ovflat.reshape((batch,) + padded_shapes)
                cb = blk.gather_blocks_batch_jnp(cgrid, blk.ANCHOR_STRIDE)
                ab = blk.gather_blocks_batch_jnp(agrid, blk.ANCHOR_STRIDE)
                vb = blk.gather_blocks_batch_jnp(ovgrid, blk.ANCHOR_STRIDE)
                recon_b = decompress_blocks(cb, ab, vb, jnp.float32(2.0 * eb_abs), steps, stride)
                out = blk.scatter_blocks_batch_jnp(recon_b, batch, padded_shapes, blk.ANCHOR_STRIDE)
                return out[sl].reshape(shape)
            except Exception as e:
                self._record_fallback("decode", "device", "numpy", e)
        seq = pipelines.decode(sections[0])
        cgrid = restore_codes_batch(seq, batch, padded_shapes, fill=128, dtype=np.uint8,
                                    stride=stride, reorder=header.get("reorder", True))
        agrid = blk.place_anchors_batch(padded_shapes, anc.reshape((batch,) + anc_shape), stride)
        ovflat = np.zeros(batch * psize, np.float32)
        ovflat[oi] = ov  # outlier indices are batch-global
        ovgrid = ovflat.reshape((batch,) + padded_shapes)
        cb = blk.gather_blocks_batch(cgrid, blk.ANCHOR_STRIDE)
        ab = blk.gather_blocks_batch(agrid, blk.ANCHOR_STRIDE)
        vb = blk.gather_blocks_batch(ovgrid, blk.ANCHOR_STRIDE)
        recon_b = np.asarray(decompress_blocks(jnp.asarray(cb), jnp.asarray(ab), jnp.asarray(vb), jnp.float32(2.0 * eb_abs), steps, stride))
        out = blk.scatter_blocks_batch(recon_b, batch, padded_shapes, blk.ANCHOR_STRIDE)
        return out[sl].reshape(shape)

    @staticmethod
    def _chunk_shape(header: dict, i: int) -> tuple:
        """Chunk ``i``'s field shape from a v3 chunk-stream header."""
        shape = list(header["shape"])
        axis = int(header.get("axis", 0))
        shape[axis] = int(header["chunk_sizes"][i])
        return tuple(shape)

    def _salvage_payloads(self, buf, on_error: str):
        """Per-frame payloads of a v3 stream, degraded-mode aware.

        Returns ``(header, payloads: dict[int, bytes], report)``. Strict
        mode raises on the first integrity failure; degraded modes fall
        back to :func:`repro.core.frames.scan_frames` when the frame walk
        itself is damaged (corrupt lengths, truncation), and mark
        CRC-damaged frames absent otherwise.
        """
        try:
            header, table = frames_mod.frame_table(buf)
        except ContainerError:
            if on_error == "raise":
                raise
            header = frames_mod.read_header(buf)
            good, report = frames_mod.scan_frames(buf)
            return header, dict(good), report
        report = DamageReport(declared_frames=len(table))
        payloads = {}
        for i, t in enumerate(table):
            try:
                payloads[i] = frames_mod.read_frame(buf, t)
                report.frames_ok += 1
            except FrameCRCError:
                if on_error == "raise":
                    raise
                report.add("crc", t[0], index=i, detail="payload CRC32 mismatch")
                report.frames_damaged += 1
        return header, payloads, report

    def _decompress_v3(self, buf: bytes, frames=None, *, on_error: str = "raise",
                       fill_value: float = 0.0, out: str = "numpy") -> np.ndarray:
        """Chunked container v3: decode frames (each a v1/v2 container of one
        chunk) independently and reassemble along the chunk axis. Under
        ``on_error="skip"``/``"fill"`` damaged chunks cost only themselves:
        the other chunks reassemble normally (see :meth:`decompress`).
        ``out="device"`` decodes each frame onto device and concatenates
        there — chunks land in per-shard device buffers without a host
        bounce."""
        header, payloads, report = self._salvage_payloads(buf, on_error)
        if header.get("kind") != "chunks":
            raise ValueError(
                f"v3 container kind {header.get('kind')!r} is not a compressor chunk "
                "stream; use its producer's reader"
            )
        n_chunks = len(header["chunk_sizes"])
        idx = list(range(n_chunks)) if frames is None else [int(i) for i in frames]
        if not idx:
            raise ValueError("frames= selected no frames; pass at least one index (or None for all)")
        parts, mask = [], []
        # per-frame decompress() calls share this call's telemetry dict
        # (fallbacks accumulate) instead of resetting it frame by frame
        hold, self._telemetry_hold = self._telemetry_hold, True
        try:
            for i in idx:
                part = None
                if i in payloads:
                    if on_error == "raise":
                        part = self.decompress(payloads[i], out=out)
                    else:
                        try:
                            part = self.decompress(payloads[i], out=out)
                        except Exception as e:  # resync false positive / garbage past CRC
                            report.add("decode", -1, index=i, detail=repr(e))
                            report.frames_damaged += 1
                elif on_error == "raise":
                    raise ContainerError(f"frame {i} missing from v3 container")
                mask.append(part is not None)
                if part is not None:
                    parts.append(part)
                elif on_error == "fill":
                    parts.append(np.full(self._chunk_shape(header, i), np.float32(fill_value), np.float32))
        finally:
            self._telemetry_hold = hold
        if not report.ok:
            self.last_damage = {"report": report, "chunks_ok": mask, "on_error": on_error}
        if not parts:
            raise ContainerError(
                f"no decodable frames in damaged v3 container ({report.summary()})"
            )
        axis = int(header.get("axis", 0))
        if len(parts) == 1:
            return parts[0]
        if out == "device":
            return jnp.concatenate([jnp.asarray(p) for p in parts], axis=axis)
        return np.concatenate(parts, axis=axis)

    def _decompress_lorenzo(self, header, sections, shape, device: bool = False) -> np.ndarray:
        batch, spatial = header["batch"], tuple(header["spatial"])
        oi = np.frombuffer(sections[1], np.int64)
        ov = np.frombuffer(sections[2], np.int32)
        if device:
            try:
                seq = pipelines.decode(sections[0], device=True)
                codes = seq.reshape((batch,) + spatial)
                ofull = jnp.zeros(codes.size, jnp.int32)
                if oi.size:
                    ofull = ofull.at[jnp.asarray(oi)].set(jnp.asarray(ov))
                out = lor.lorenzo_decode(codes, ofull.reshape(codes.shape),
                                         jnp.float32(2.0 * header["eb_abs"]), len(spatial))
                return out.reshape(shape)
            except Exception as e:
                self._record_fallback("decode", "device", "numpy", e)
        seq = pipelines.decode(sections[0])
        codes = seq.reshape((batch,) + spatial)
        ofull = np.zeros(codes.size, np.int32)
        ofull[oi] = ov
        out = lor.lorenzo_decode(jnp.asarray(codes), jnp.asarray(ofull.reshape(codes.shape)), jnp.float32(2.0 * header["eb_abs"]), len(spatial))
        return np.asarray(out).reshape(shape)


# ------------------------------------------------------------------ presets
def cusz_hi_auto(eb=1e-3, **kw) -> Compressor:
    """Orchestrated mode: per-field best-fit lossless pipeline (§5.2)."""
    return Compressor(CompressorSpec(eb=eb, pipeline="auto", **kw))


def cusz_hi_autoplan(eb=1e-3, **kw) -> Compressor:
    """Fully synergistic mode: plan-driven predictor (per-level spline/scheme/
    stride autotuning, §5.1.3) + per-field best-fit lossless pipeline (§5.2)."""
    return Compressor(CompressorSpec(eb=eb, predictor="auto", pipeline="auto", **kw))


def cusz_hi_cr(eb=1e-3, **kw) -> Compressor:
    return Compressor(CompressorSpec(eb=eb, pipeline="cr", **kw))


def cusz_hi_crz(eb=1e-3, **kw) -> Compressor:
    """Beyond-paper mode: CR pipeline + open-source zstd tail stage."""
    return Compressor(CompressorSpec(eb=eb, pipeline="crz", **kw))


def cusz_hi_tp(eb=1e-3, **kw) -> Compressor:
    return Compressor(CompressorSpec(eb=eb, pipeline="tp", **kw))


def cusz_l(eb=1e-3) -> Compressor:
    """cuSZ-L baseline: Lorenzo + Huffman."""
    return Compressor(CompressorSpec(eb=eb, predictor="lorenzo", pipeline="hf"))


def cusz_i(eb=1e-3) -> Compressor:
    """cuSZ-I baseline: stride-8 anchors, 3 levels, 1D scheme, Huffman only."""
    return Compressor(
        CompressorSpec(eb=eb, predictor="interp", pipeline="hf", anchor_stride=8, autotune=False,
                       splines=("cubic",) * 3, schemes=("1d",) * 3, reorder=False)
    )


def cuszp2_like(eb=1e-3) -> Compressor:
    """cuSZp2-like baseline: 1-D offset prediction + fixed-length encoding."""
    return Compressor(CompressorSpec(eb=eb, predictor="offset1d", pipeline="none"))


def fzgpu_like(eb=1e-3) -> Compressor:
    """FZ-GPU-like baseline: Lorenzo + bitshuffle + de-redundancy."""
    return Compressor(CompressorSpec(eb=eb, predictor="lorenzo", pipeline="fz"))
