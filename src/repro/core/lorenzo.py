"""Lorenzo-extrapolation decomposition (the cuSZ-L baseline, §2.2/§6.1.2).

Uses cuSZ's dual-quant trick: pre-quantize values to integers
(pq = rint(x / 2eb), error <= eb), then take the exact integer Lorenzo
difference along every axis. Decompression is an exact integer prefix-sum,
so no reconstruction feedback loop is needed — fully parallel both ways.
Large codes (|q| > 127) are outliers: the int32 code is stored on the side
and the uint8 slot is the reserved value 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

RADIUS = 127
CENTER = 128


@functools.partial(jax.jit, static_argnums=(2,))
def lorenzo_encode(x: jnp.ndarray, twoeb: jnp.ndarray, ndim_spatial: int | None = None):
    """x: float array. Returns (codes u8, outlier_mask, outlier_int32, recon)."""
    nd = x.ndim if ndim_spatial is None else ndim_spatial
    pq = jnp.rint(x / twoeb).astype(jnp.int32)
    c = pq
    for ax in range(x.ndim - nd, x.ndim):
        c = jnp.diff(c, axis=ax, prepend=0)
    outl = jnp.abs(c) > RADIUS
    codes = jnp.where(outl, 0, jnp.clip(c, -RADIUS, RADIUS) + CENTER).astype(jnp.uint8)
    recon = pq.astype(jnp.float32) * twoeb
    return codes, outl, c, recon


@functools.partial(jax.jit, static_argnums=(3,))
def lorenzo_decode(codes: jnp.ndarray, outlier_full: jnp.ndarray, twoeb: jnp.ndarray, ndim_spatial: int | None = None):
    """codes u8 + dense int32 outlier array (0 elsewhere) -> recon floats."""
    nd = codes.ndim if ndim_spatial is None else ndim_spatial
    q = jnp.where(codes == 0, outlier_full, codes.astype(jnp.int32) - CENTER)
    for ax in range(codes.ndim - nd, codes.ndim):
        q = jnp.cumsum(q, axis=ax)
    return q.astype(jnp.float32) * twoeb


@jax.jit
def offset1d_encode(x: jnp.ndarray, twoeb: jnp.ndarray):
    """cuSZp2-style 1-D offset prediction on the flattened stream."""
    pq = jnp.rint(x.reshape(-1) / twoeb).astype(jnp.int32)
    return jnp.diff(pq, prepend=0)


@jax.jit
def offset1d_decode(codes: jnp.ndarray, twoeb: jnp.ndarray):
    return jnp.cumsum(codes).astype(jnp.float32) * twoeb
