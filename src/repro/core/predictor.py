"""Interpolation-based lossy decomposition (paper §5.1) — pure-JAX engine.

Runs the 4-level hierarchical spline prediction over a batch of closed
17^ndim blocks (block axis vectorized), quantizes prediction errors to
uint8 codes (radius 127, code 0 reserved for outliers, paper §5.2.1) and
maintains the reconstruction in lock-step so compression and decompression
replay bit-identical arithmetic.

The per-step math is the matmul formulation from stencils.py; the Pallas
kernel in repro.kernels.interp3d implements the same steps with the block
axis as the TPU lane axis. This module is the reference/runtime engine used
by the host compressor (and the oracle the kernel is tested against).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .stencils import Step, build_steps

RADIUS = 127
CENTER = 128  # uint8 code = q + 128; 0 marks an outlier


def _apply_mat(recon: jnp.ndarray, M: np.ndarray, axis: int) -> jnp.ndarray:
    """Apply (B,B) operator along spatial `axis` of (nb, B, ..., B)."""
    x = jnp.moveaxis(recon, axis + 1, 0)  # (B, nb, ...)
    y = jnp.tensordot(jnp.asarray(M), x, axes=((1,), (0,)))
    return jnp.moveaxis(y, 0, axis + 1)


def _predict(recon: jnp.ndarray, step: Step) -> jnp.ndarray:
    pred = jnp.zeros_like(recon)
    for d, M, w in zip(step.dims, step.matrices, step.weights):
        pred = pred + jnp.asarray(w) * _apply_mat(recon, M, d)
    return pred


def quantize_pred(orig, pred, twoeb, inv2eb):
    """The quantizer: (code u8-valued i32 with 0 = outlier, outlier mask,
    feedback reconstruction). Single source of truth for the arithmetic —
    the engine below, the autotuner's trial passes, and the Pallas kernel
    all call this, so their code streams stay bit-identical.
    """
    q = jnp.rint((orig - pred) * inv2eb)
    outl = jnp.abs(q) > RADIUS
    rec = jnp.where(outl, orig, pred + q * twoeb)
    qi = jnp.clip(q, -RADIUS - 1, RADIUS + 1).astype(jnp.int32)  # safe cast; outliers coded 0
    code = jnp.where(outl, 0, qi + CENTER)
    return code, outl, rec


def _anchor_mask(spatial: tuple[int, ...], anchor_every: int) -> np.ndarray:
    m = np.zeros(spatial, bool)
    sl = tuple(slice(None, None, anchor_every) for _ in spatial)
    m[sl] = True
    return m


@functools.partial(jax.jit, static_argnums=(2, 3))
def compress_blocks(blocks: jnp.ndarray, twoeb: jnp.ndarray, steps: tuple[Step, ...], anchor_every: int = 16):
    """blocks: (nb, B..) f32 with anchors in place.

    Returns (codes u8 (nb,B..), outlier_mask bool, recon f32).
    recon == what the decompressor reproduces (outliers patched exactly).
    """
    orig = blocks
    # start from anchors only; non-anchor entries are dead until predicted
    anchor_mask = _anchor_mask(blocks.shape[1:], anchor_every)
    recon = jnp.where(jnp.asarray(anchor_mask), orig, 0.0)
    codes = jnp.full(blocks.shape, CENTER, jnp.int32)
    outl_all = jnp.zeros(blocks.shape, bool)
    inv2eb = 1.0 / twoeb
    for step in steps:
        pred = _predict(recon, step)
        code, outl, rec = quantize_pred(orig, pred, twoeb, inv2eb)
        m = jnp.asarray(step.mask)
        recon = jnp.where(m, rec, recon)
        codes = jnp.where(m, code, codes)
        outl_all = outl_all | (m & outl)
    return codes.astype(jnp.uint8), outl_all, recon


@functools.partial(jax.jit, static_argnums=(4, 5))
def decompress_blocks(
    codes: jnp.ndarray,      # (nb, B..) u8, anchors position value irrelevant
    anchors: jnp.ndarray,    # (nb, B..) f32, valid only at anchor positions
    outlier_vals: jnp.ndarray,  # (nb, B..) f32, valid only where code == 0
    twoeb: jnp.ndarray,
    steps: tuple[Step, ...],
    anchor_every: int = 16,
) -> jnp.ndarray:
    anchor_mask = _anchor_mask(codes.shape[1:], anchor_every)
    recon = jnp.where(jnp.asarray(anchor_mask), anchors, 0.0)
    q = codes.astype(jnp.int32) - CENTER
    is_outl = codes == 0
    for step in steps:
        pred = _predict(recon, step)
        rec = jnp.where(is_outl, outlier_vals, pred + q.astype(jnp.float32) * twoeb)
        recon = jnp.where(jnp.asarray(step.mask), rec, recon)
    return recon


def default_steps(ndim: int, splines=("cubic",) * 4, schemes=("md",) * 4, levels=(8, 4, 2, 1), B: int = 17):
    return build_steps(ndim, B, tuple(levels), tuple(splines), tuple(schemes))
