"""Thread-safe LRU cache for autotuned compression plans.

The heavy-traffic case (``repro.launch.compressd``, the checkpoint saver,
KV-cache paging) is the *same tensor shapes arriving forever*: every
checkpoint step writes the same parameter geometry, every KV page has the
layer's fixed (heads, seq, dim) shape. Re-running the predictor planner
(:func:`repro.core.autotune.autotune_plan`) and the lossless orchestrator
per call burns most of the request latency on work whose answer never
changes. A :class:`PlanCache` memoizes the tuning outcome — the
``(anchor_stride, splines, schemes)`` step tables plus the orchestrator's
pipeline choice — keyed by :func:`repro.core.autotune.plan_signature`
(shape, dtype, error-bound config, coarse stats bucket), so a recurring
field signature skips straight to the predictor.

The cache is an *opt-in* handed to :class:`repro.core.Compressor`
(``Compressor(spec, plan_cache=cache)``); the default remains uncached,
so single-shot callers and the bit-identity acceptance tests are
untouched. One cache may be shared by many compressors across many
threads: every operation takes the internal lock, and entries are plain
immutable-ish dicts produced and consumed by the compressor.

Telemetry: ``hits`` / ``misses`` / ``evictions`` counters and
:meth:`stats` (which adds ``hit_rate``) are how the service's ``stats``
request and the bench assert — not just time — that recurring shapes
skip re-autotuning.
"""
from __future__ import annotations

import threading
from collections import OrderedDict


class PlanCache:
    """Bounded LRU mapping plan signatures to tuning outcomes.

    ``max_entries`` bounds memory: one entry is a few hundred bytes of
    step-table labels, so even thousands of entries are cheap — the bound
    exists to keep pathological signature churn (e.g. hashing continuous
    stats without bucketing) from growing without limit.
    """

    def __init__(self, max_entries: int = 256):
        if int(max_entries) < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = int(max_entries)
        self._lock = threading.Lock()
        self._entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key):
        """Entry for ``key`` (refreshing its LRU position) or ``None``.

        Counts a hit or a miss; use :meth:`peek` for a count-free probe.
        """
        with self._lock:
            try:
                value = self._entries[key]
            except KeyError:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return value

    def put(self, key, value) -> None:
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
            self._entries[key] = value
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.evictions += 1

    def peek(self, key):
        """Like :meth:`get` but without touching LRU order or counters."""
        with self._lock:
            return self._entries.get(key)

    def keys(self) -> list:
        """Current keys, least-recently-used first (snapshot)."""
        with self._lock:
            return list(self._entries.keys())

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._entries

    def stats(self) -> dict:
        with self._lock:
            looked = self.hits + self.misses
            return {
                "entries": len(self._entries),
                "max_entries": self.max_entries,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": (self.hits / looked) if looked else 0.0,
            }
