"""Compression quality metrics (paper §6.1.4).

The paper's headline claim is compression ratio at *matched PSNR* on real
scientific fields, so beyond the classic rate/distortion pair (PSNR,
bit rate) this module carries the structural metrics the enstools/cuSZ-i
evaluation family reports: a windowed SSIM-style index and a spectral
error over the field's isotropic power spectrum. All metrics are
numpy-only, accept any-rank float fields, and are defined (finite or an
explicit ``inf``) on the degenerate inputs a benchmark sweep will hit —
empty arrays, constant (zero-range) fields, all-zero fields.

Non-finite hygiene: real masked fields (ocean grids, sensor dropouts)
carry NaN/Inf fill, and a naive mean/max silently poisons every metric to
NaN. Every metric here instead *masks* points where either field is
non-finite: the flat metrics (psnr / max_abs_err / max_rel_err /
value_range) compute over the jointly-finite points only, and the
structural metrics (ssim / spectral_error) neutralize masked points with
the finite mean of ``orig`` before windowing/FFT, so they contribute no
structural difference. ``quality_report`` reports the masked count as
``n_nonfinite`` (0 for clean pairs) — the masking is observable, never
silent.
"""
from __future__ import annotations

import numpy as np


def _finite_mask(orig: np.ndarray, recon: np.ndarray) -> np.ndarray:
    """Jointly-finite mask of a metric pair."""
    return np.isfinite(orig) & np.isfinite(recon)


def nonfinite_count(orig: np.ndarray, recon: np.ndarray | None = None) -> int:
    """Points excluded by the metrics' non-finite mask: non-finite in
    ``orig`` or (when given) in ``recon``."""
    bad = ~np.isfinite(orig)
    if recon is not None:
        bad |= ~np.isfinite(recon)
    return int(np.count_nonzero(bad))


def _neutralized_pair(orig: np.ndarray, recon: np.ndarray):
    """f64 copies of the pair with union-non-finite points replaced by the
    finite mean of ``orig`` (0.0 when nothing is finite) — keeps the grid
    structure the windowed/spectral metrics need while the masked points
    contribute zero structural difference."""
    a = orig.astype(np.float64)
    b = recon.astype(np.float64)
    m = _finite_mask(a, b)
    if m.all():
        return a, b
    fill = float(a[np.isfinite(a)].mean()) if np.isfinite(a).any() else 0.0
    a = np.where(m, a, fill)
    b = np.where(m, b, fill)
    return a, b


def value_range(x: np.ndarray) -> float:
    """Dynamic range over the finite points (f64 arithmetic, so extreme
    float32 fields don't overflow the subtraction to inf); 0.0 when empty
    or nothing is finite."""
    if not x.size:
        return 0.0
    xf = np.asarray(x, np.float64).reshape(-1)
    xf = xf[np.isfinite(xf)]
    return float(xf.max() - xf.min()) if xf.size else 0.0


def max_abs_err(a: np.ndarray, b: np.ndarray) -> float:
    if not a.size:
        return 0.0
    x = a.astype(np.float64).reshape(-1)
    y = b.astype(np.float64).reshape(-1)
    m = _finite_mask(x, y)
    return float(np.max(np.abs(x[m] - y[m]))) if m.any() else 0.0


def max_rel_err(orig: np.ndarray, recon: np.ndarray) -> float:
    """Max point-wise *relative* error ``|x - x'| / |x|`` over the nonzero
    points of ``orig`` — the quantity an ``eb_mode="pw_rel"`` bound
    guarantees. Zero points are excluded from the ratio (a relative bound
    is undefined there); the pw_rel codec stores them exactly, and any
    zero point reconstructed nonzero counts as ``inf``. Points where
    either field is non-finite are masked out."""
    if not orig.size:
        return 0.0
    a = orig.astype(np.float64).reshape(-1)
    b = recon.astype(np.float64).reshape(-1)
    m = _finite_mask(a, b)
    a, b = a[m], b[m]
    if not a.size:
        return 0.0
    nz = a != 0.0
    worst = 0.0
    if np.any(~nz) and np.any(b[~nz] != 0.0):
        return float("inf")
    if np.any(nz):
        worst = float(np.max(np.abs(a[nz] - b[nz]) / np.abs(a[nz])))
    return worst


def _psnr_scale(orig: np.ndarray) -> float:
    """The dynamic-range normalizer PSNR divides by. Value range of the
    field, falling back to the peak magnitude for constant fields and to
    1.0 for the all-zero field — so PSNR is always defined."""
    rng = value_range(orig)
    if rng > 0:
        return rng
    fin = orig[np.isfinite(orig)] if orig.size else orig
    peak = float(np.max(np.abs(fin.astype(np.float64)))) if fin.size else 0.0
    return peak if peak > 0 else 1.0


def psnr(orig: np.ndarray, recon: np.ndarray) -> float:
    """Range-normalized PSNR in dB; ``inf`` for a perfect reconstruction.

    Constant (zero-range) fields normalize by their peak magnitude
    (1.0 when identically zero) instead of the degenerate range, so the
    result is a defined, finite number whenever ``mse > 0``. The MSE runs
    over the jointly-finite points (see the module's non-finite hygiene
    note); an entirely non-finite pair scores ``inf`` (nothing to
    compare).
    """
    if not orig.size:
        return float("inf")
    a = orig.astype(np.float64).reshape(-1)
    b = recon.astype(np.float64).reshape(-1)
    m = _finite_mask(a, b)
    if not m.any():
        return float("inf")
    d = a[m] - b[m]
    mse = float(np.mean(d * d))
    if mse == 0.0:
        return float("inf")
    return 20.0 * np.log10(_psnr_scale(orig)) - 10.0 * np.log10(mse)


def compression_ratio(orig: np.ndarray, compressed: bytes) -> float:
    return orig.nbytes / max(1, len(compressed))


def bit_rate(orig: np.ndarray, compressed: bytes) -> float:
    """bits per element (32/CR for fp32); 0.0 for an empty array."""
    if orig.size == 0:
        return 0.0
    return 8.0 * len(compressed) / orig.size


# --------------------------------------------------------------- SSIM-style
def _win_mean(x: np.ndarray, win: int) -> np.ndarray:
    """Moving average over a ``win``-wide window along every axis, via the
    cumulative-sum trick (valid region only) — numpy-only separable
    uniform filter, O(n) per axis."""
    for ax in range(x.ndim):
        c = np.cumsum(x, axis=ax, dtype=np.float64)
        pad_shape = list(c.shape)
        pad_shape[ax] = 1
        c = np.concatenate([np.zeros(pad_shape), c], axis=ax)
        hi = [slice(None)] * x.ndim
        lo = [slice(None)] * x.ndim
        hi[ax] = slice(win, None)
        lo[ax] = slice(None, -win)
        x = (c[tuple(hi)] - c[tuple(lo)]) / win
    return x


def ssim(orig: np.ndarray, recon: np.ndarray, *, window: int = 7) -> float:
    """Mean SSIM-style structural similarity over an N-d uniform window.

    The standard luminance/contrast/structure product with the usual
    stabilizers ``C1=(0.01*L)^2``, ``C2=(0.03*L)^2`` where ``L`` is the
    dynamic range of ``orig`` (peak magnitude for constant fields), the
    window a ``window``-wide uniform box along every axis. Fields smaller
    than the window along some axis shrink the window to fit; empty or
    single-point fields compare globally. Identical fields score 1.0.
    """
    if orig.shape != recon.shape:
        raise ValueError(f"shape mismatch: {orig.shape} vs {recon.shape}")
    if orig.size == 0:
        return 1.0
    a, b = _neutralized_pair(orig, recon)
    win = max(1, min(int(window), *a.shape))
    L = _psnr_scale(orig)
    c1 = (0.01 * L) ** 2
    c2 = (0.03 * L) ** 2
    mu_a = _win_mean(a, win)
    mu_b = _win_mean(b, win)
    var_a = np.maximum(_win_mean(a * a, win) - mu_a**2, 0.0)
    var_b = np.maximum(_win_mean(b * b, win) - mu_b**2, 0.0)
    cov = _win_mean(a * b, win) - mu_a * mu_b
    num = (2.0 * mu_a * mu_b + c1) * (2.0 * cov + c2)
    den = (mu_a**2 + mu_b**2 + c1) * (var_a + var_b + c2)
    return float(np.mean(num / den))


# ------------------------------------------------------------ spectral error
def _radial_spectrum(x: np.ndarray, nbins: int) -> np.ndarray:
    """Isotropically binned power spectrum of ``x`` (mean power per
    |k|-shell, DC excluded)."""
    F = np.fft.rfftn(x.astype(np.float64))
    power = np.abs(F) ** 2
    ks = np.meshgrid(
        *[np.fft.fftfreq(n) for n in x.shape[:-1]] + [np.fft.rfftfreq(x.shape[-1])],
        indexing="ij",
    )
    k = np.sqrt(sum(kk**2 for kk in ks))
    kmax = float(k.max())
    if kmax == 0.0:
        return np.asarray([power.reshape(-1)[0]])
    bins = np.minimum((k / kmax * nbins).astype(np.int64), nbins - 1).reshape(-1)
    p = power.reshape(-1)
    keep = k.reshape(-1) > 0  # DC carries the mean, not structure
    sums = np.bincount(bins[keep], weights=p[keep], minlength=nbins)
    counts = np.bincount(bins[keep], minlength=nbins)
    nz = counts > 0
    return sums[nz] / counts[nz]


def spectral_error(orig: np.ndarray, recon: np.ndarray, *, nbins: int = 32) -> float:
    """Mean absolute log10 ratio of the isotropic power spectra.

    0.0 means the reconstruction preserved the field's power spectrum
    exactly; 1.0 means the spectral shells are off by 10x on average —
    the "did compression smear the physics" metric the enstools
    evaluation family reports alongside PSNR. Shells whose true power is
    below ``1e-20 * peak`` are skipped (they are numerical dust);
    constant and empty fields score 0.0 against themselves.
    """
    if orig.shape != recon.shape:
        raise ValueError(f"shape mismatch: {orig.shape} vs {recon.shape}")
    if orig.size <= 1:
        return 0.0
    a, b = _neutralized_pair(orig, recon)
    sa = _radial_spectrum(a, nbins)
    sb = _radial_spectrum(b, nbins)
    floor = float(sa.max()) * 1e-20 if sa.size and sa.max() > 0 else 0.0
    keep = sa > floor
    if not np.any(keep):
        return 0.0 if not np.any(sb > floor) else float("inf")
    ratio = (sb[keep] + floor) / (sa[keep] + floor) if floor > 0 else sb[keep] / sa[keep]
    ratio = np.maximum(ratio, 1e-300)
    return float(np.mean(np.abs(np.log10(ratio))))


def quality_report(orig: np.ndarray, recon: np.ndarray, compressed: bytes | None = None) -> dict:
    """All quality metrics of one (field, reconstruction) pair in one dict —
    the row schema ``bench_lossless --metrics`` records and the CI io lane
    gates on. ``compressed`` adds the rate columns (cr, bit_rate).
    ``n_nonfinite`` counts the points the non-finite mask excluded from
    the flat metrics (union over both fields; 0 for clean pairs)."""
    out = {
        "psnr": psnr(orig, recon),
        "ssim": ssim(orig, recon),
        "spectral_error": spectral_error(orig, recon),
        "max_abs_err": max_abs_err(orig, recon),
        "max_rel_err": max_rel_err(orig, recon),
        "n_nonfinite": nonfinite_count(orig, recon),
    }
    if compressed is not None:
        out["cr"] = compression_ratio(orig, compressed)
        out["bit_rate"] = bit_rate(orig, compressed)
    return out
