"""Compression quality metrics (paper §6.1.4)."""
from __future__ import annotations

import numpy as np


def value_range(x: np.ndarray) -> float:
    return float(np.max(x) - np.min(x))


def max_abs_err(a: np.ndarray, b: np.ndarray) -> float:
    return float(np.max(np.abs(a.astype(np.float64) - b.astype(np.float64)))) if a.size else 0.0


def psnr(orig: np.ndarray, recon: np.ndarray) -> float:
    rng = value_range(orig)
    mse = float(np.mean((orig.astype(np.float64) - recon.astype(np.float64)) ** 2))
    if mse == 0.0:
        return float("inf")
    return 20.0 * np.log10(rng) - 10.0 * np.log10(mse) if rng > 0 else float("-inf")


def compression_ratio(orig: np.ndarray, compressed: bytes) -> float:
    return orig.nbytes / max(1, len(compressed))


def bit_rate(orig: np.ndarray, compressed: bytes) -> float:
    """bits per element (32/CR for fp32)."""
    return 8.0 * len(compressed) / orig.size
