"""Error taxonomy + damage reporting for the fault-tolerant runtime.

Every integrity failure in the container stack raises a typed error from
this module, so consumers can distinguish *what kind* of damage they hit
(CRC mismatch vs truncation vs structural garbage) and degrade instead of
aborting. All container errors subclass :class:`ValueError` — the type the
pre-taxonomy code raised — so existing ``except ValueError`` handlers and
tests keep working unchanged.

The salvage paths (:func:`repro.core.frames.scan_frames`,
``Compressor.decompress(on_error=...)``, ``checkpoint.restore(strict=
False)``) never *raise* for recoverable damage; they return a
:class:`DamageReport` describing exactly what was lost, where, and what
was done about it — silent data loss is as bad as a crash.
"""
from __future__ import annotations

import dataclasses


class ContainerError(ValueError):
    """Base for all container integrity failures (subclasses ValueError
    for compatibility with pre-taxonomy callers)."""


class TruncatedContainerError(ContainerError):
    """The stream ended early: inside a frame, inside a prefix, or with a
    missing/inconsistent end marker."""


class FrameCRCError(ContainerError):
    """A frame payload failed its CRC32 check."""

    def __init__(self, msg: str, *, index: int | None = None, offset: int | None = None):
        super().__init__(msg)
        self.index = index
        self.offset = offset


class FrameSyncError(ContainerError):
    """A sync-marked stream had a bad/missing per-frame sync marker."""


class CheckpointDamageError(RuntimeError):
    """A checkpoint leaf failed its integrity check under ``strict=True``."""


class SpecError(ValueError):
    """A compression-spec string failed to parse or validate.

    Raised by :meth:`repro.core.CompressorSpec.from_string` (and every
    consumer that accepts the spec-string grammar: ``repro.io``, the
    compressd protocol, ``serve --kv-spec``, the checkpoint codec's
    ``REPRO_CKPT_SPEC``) for bad grammar, unknown keys, or values the
    underlying :class:`~repro.core.CompressorSpec` rejects. Subclasses
    ``ValueError`` so pre-grammar ``except ValueError`` handlers keep
    working."""


class BoundViolationError(RuntimeError):
    """Post-compression bound verification found ``max|x - x_hat|`` above
    the declared error bound and the auto-repair ladder could not fix it.

    Raised by ``Compressor.compress`` under ``CompressorSpec(verify=
    "sample"|"full")`` only after the bounded re-encode ladder (tighten
    eb, re-encode, re-verify) is exhausted — a single violation repairs
    silently and lands in ``last_telemetry["verify"]["repairs"]``.
    Carries ``max_err`` / ``bound`` / ``repairs`` for attribution."""

    def __init__(self, msg: str, *, max_err: float = 0.0, bound: float = 0.0,
                 repairs: int = 0):
        super().__init__(msg)
        self.max_err = float(max_err)
        self.bound = float(bound)
        self.repairs = int(repairs)


class ServiceError(RuntimeError):
    """Base for compression-service (repro.launch.compressd) failures.

    The daemon maps these onto typed error responses; the client maps the
    responses back, so a caller catches the same class on either side of
    the socket."""


class ServiceOverloadedError(ServiceError):
    """Load shed: the daemon's admission queue is at its depth cap (or the
    request cannot be admitted within the configured wait). Back off and
    retry; the request was never processed."""


class RequestTooLargeError(ServiceError):
    """The request payload exceeds the daemon's per-request byte cap. The
    payload was drained, never buffered — split the field or raise the
    server's ``max_request_bytes``."""


class ServiceProtocolError(ServiceError):
    """Malformed request/response framing (bad magic, header, or lengths)."""


class DeadlineExceededError(ServiceError):
    """The request's per-request deadline (``REPRO_COMPRESSD_DEADLINE_MS``
    / ``CompressdServer(deadline_ms=...)``) elapsed before the daemon
    finished it — while queued for admission or while executing. The
    client gets this typed response instead of a hung stream; whether the
    work completed server-side is indeterminate (the result is
    discarded)."""


@dataclasses.dataclass
class DamageRecord:
    """One damaged region: what kind, where, and which frame (when known)."""

    kind: str                 # "crc" | "length" | "sync" | "truncated" | "trailer" | "decode"
    offset: int               # byte offset where the damage was detected
    index: int | None = None  # frame index/sequence number, when known
    detail: str = ""

    def __str__(self):
        at = f" frame {self.index}" if self.index is not None else ""
        return f"[{self.kind}]{at} @ byte {self.offset}" + (f": {self.detail}" if self.detail else "")


@dataclasses.dataclass
class DamageReport:
    """What a salvage pass found: intact counts, damage records, skipped
    bytes. ``ok`` is True iff the stream was fully intact."""

    records: list = dataclasses.field(default_factory=list)
    frames_ok: int = 0
    frames_damaged: int = 0
    bytes_skipped: int = 0
    declared_frames: int | None = None  # trailer count, when the trailer survived
    truncated: bool = False

    @property
    def ok(self) -> bool:
        return not self.records and not self.truncated

    def add(self, kind: str, offset: int, *, index: int | None = None, detail: str = "") -> DamageRecord:
        rec = DamageRecord(kind, int(offset), index, detail)
        self.records.append(rec)
        return rec

    def summary(self) -> str:
        if self.ok:
            return f"intact: {self.frames_ok} frames"
        parts = [f"{self.frames_ok} frames ok, {self.frames_damaged} damaged"]
        if self.bytes_skipped:
            parts.append(f"{self.bytes_skipped} bytes skipped")
        if self.truncated:
            parts.append("stream truncated")
        return "; ".join(parts) + " | " + "; ".join(str(r) for r in self.records)
