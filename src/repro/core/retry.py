"""Bounded retry with exponential backoff + jitter for transient I/O.

The checkpoint saver and the streaming frame producers write through
network filesystems and page caches where a single ``write()`` can fail
transiently (EAGAIN, ENOSPC races, NFS blips) without the whole save
being doomed. :func:`retry_call` retries a callable a bounded number of
times with exponential backoff and multiplicative jitter (decorrelated
start times when many writers retry together); :class:`RetryingWriter`
applies it per ``write()``/``flush()`` on a file-like sink.

Retrying a write assumes the failed call wrote nothing — true for the
fault injectors in :mod:`repro.testing.faults` (they raise before
touching the sink) and for the common transient errnos, and the CRC
framing downstream catches the pathological partial-write case anyway.

Defaults are overridable via ``REPRO_IO_RETRIES`` (attempt count; ``1``
disables retrying) so a chaos lane or an ops environment can tune the
policy without code changes. The ``sleep`` hook exists so tests assert
backoff schedules without actually sleeping.
"""
from __future__ import annotations

import dataclasses
import os
import random
import time


def _env_attempts(default: int) -> int:
    try:
        return max(1, int(os.environ.get("REPRO_IO_RETRIES", default)))
    except ValueError:
        return default


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """attempts = total tries (1 = no retry); delay_s grows as
    ``base_delay * 2**(try-1)``, capped at ``max_delay``, then scaled by
    ``1 + U[0, jitter)``."""

    attempts: int = 3
    base_delay: float = 0.05
    max_delay: float = 2.0
    jitter: float = 0.5
    retry_on: tuple = (OSError,)

    def delay(self, attempt: int, rng: random.Random) -> float:
        d = min(self.max_delay, self.base_delay * (2.0 ** (attempt - 1)))
        return d * (1.0 + rng.random() * self.jitter)


def default_policy() -> RetryPolicy:
    return RetryPolicy(attempts=_env_attempts(3))


def retry_call(fn, *, policy: RetryPolicy | None = None, on_retry=None,
               sleep=time.sleep, seed: int | None = None):
    """Call ``fn()``; on an exception in ``policy.retry_on``, back off and
    retry up to ``policy.attempts`` total tries, then re-raise the last
    error. ``on_retry(attempt, exc, delay_s)`` observes each retry (the
    telemetry hook); ``seed`` pins the jitter for reproducible tests."""
    policy = policy if policy is not None else default_policy()
    rng = random.Random(seed)
    attempt = 0
    while True:
        attempt += 1
        try:
            return fn()
        except policy.retry_on as e:
            if attempt >= policy.attempts:
                raise
            delay = policy.delay(attempt, rng)
            if on_retry is not None:
                on_retry(attempt, e, delay)
            sleep(delay)


class RetryingWriter:
    """File-like proxy that retries transient ``write()``/``flush()``
    failures per :class:`RetryPolicy`. ``retries`` counts the retries that
    happened (0 on a healthy sink) — surfaced into save telemetry so
    silent degradation stays observable."""

    def __init__(self, f, *, policy: RetryPolicy | None = None, sleep=time.sleep, seed: int | None = None):
        self._f = f
        self._policy = policy if policy is not None else default_policy()
        self._sleep = sleep
        self._rng = random.Random(seed)
        self.retries = 0

    def _retrying(self, fn):
        attempt = 0
        while True:
            attempt += 1
            try:
                return fn()
            except self._policy.retry_on:
                if attempt >= self._policy.attempts:
                    raise
                self.retries += 1
                self._sleep(self._policy.delay(attempt, self._rng))

    def write(self, b):
        return self._retrying(lambda: self._f.write(b))

    def flush(self):
        if hasattr(self._f, "flush"):
            return self._retrying(self._f.flush)

    def __getattr__(self, name):  # fileno, seek, ... pass through untouched
        return getattr(self._f, name)
