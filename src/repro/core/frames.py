"""Container v3: chunked frame streams (``CSZH3`` magic).

A v3 container is a sequence of *independently decodable* frames behind one
global header. Each frame is an opaque byte blob — for the compressor it is
a complete v1/v2 container of one shard/chunk, so every frame carries its
own header and section table and replays without any other frame — guarded
by a CRC32 and a length prefix. The layout is streaming-first:

    CSZH3\\n | u32 hlen | header (repro.core.serial) |
    n x [ u64 size | u32 crc32 | frame bytes ] | u32 n_frames | CSZ3END\\n

Frames are length-prefixed (a writer never needs to know sizes up front,
so encode can overlap I/O), and the trailing count + end marker let a
reader detect truncation. The global header is a plain serial dict; the
compressor stores ``kind="chunks"`` plus the split geometry there, other
producers (gradient shards, KV-cache offload) store their own kinds.

Random access walks the length prefixes — n hops of 12 bytes each, no
payload parsing — so partial decode (``frames=[...]``) and out-of-order
decode cost nothing beyond the frames actually read.

Fault tolerance
---------------
Frames are the unit of salvage: one flipped bit destroys at most its own
frame, never the stream. Integrity failures raise the typed errors in
:mod:`repro.core.errors` (all ``ValueError`` subclasses), and
:func:`scan_frames` recovers every intact frame from a damaged stream
together with a :class:`~repro.core.errors.DamageReport`.

``FrameWriter(..., sync=True)`` additionally prefixes every frame record
with an 8-byte sync marker and a u32 sequence number (recorded as
``_sync`` in the global header, so readers know the record layout). Plain
streams resync after damage by a heuristic forward scan that must re-find
a (length, CRC)-consistent record; sync-marked streams resync by scanning
for the next marker — O(damage region), and the sequence number pins the
true index of every survivor even when whole frames vanished. Old v3
files (no ``_sync``) read unchanged, byte for byte.
"""
from __future__ import annotations

import io
import struct
import zlib

from .errors import (  # noqa: F401 - re-exported: frames' own error surface
    ContainerError,
    DamageReport,
    FrameCRCError,
    FrameSyncError,
    TruncatedContainerError,
)
from .serial import pack_obj, unpack_obj

MAGIC_V3 = b"CSZH3\n"
_END = b"CSZ3END\n"
_FRAME_PREFIX = struct.Struct("<QI")  # u64 size, u32 crc32
# sync-marked record: marker | u32 seq | u64 size | u32 crc32 | payload.
# The marker's first byte is non-ASCII so plain-text payloads can't
# shadow it; the CRC check is the real gate against false positives.
SYNC_MARKER = b"\xf5CSZ3F\r\n"
_SYNC_PREFIX = struct.Struct("<8sIQI")
_TRAILER_LEN = 4 + len(_END)  # u32 count + end marker


def is_v3(buf) -> bool:
    return bytes(buf[: len(MAGIC_V3)]) == MAGIC_V3


def _crc(b) -> int:
    return zlib.crc32(b) & 0xFFFFFFFF


class FrameWriter:
    """Streaming v3 writer over any ``write()``-able object.

    Frames are written (and flushed, when the sink supports it) as they are
    produced, so a slow consumer — disk writeback, a socket — overlaps with
    the encode of the next frame instead of waiting for the whole
    container. ``close()`` appends the trailing frame count + end marker;
    a stream without them is detectably truncated.

    ``sync=True`` writes the per-frame sync marker + sequence number (see
    module docstring) for O(damage) resync; the layout is declared in the
    global header, so it is self-describing.

    Usable as a context manager: a clean ``with`` exit finalizes the
    stream (``close()``); an exception inside the block *aborts* it
    instead — the trailer is deliberately not written, so the
    half-produced stream stays detectably truncated rather than
    masquerading as complete.
    """

    def __init__(self, f, header: dict | None = None, *, sync: bool = False):
        self._f = f
        self._n = 0
        self._closed = False
        self._sync = bool(sync)
        header = dict(header or {})
        if self._sync:
            header["_sync"] = 1
        hb = pack_obj(header)
        f.write(MAGIC_V3)
        f.write(struct.pack("<I", len(hb)))
        f.write(hb)

    def write_frame(self, frame: bytes) -> None:
        if self._closed:
            raise ValueError("FrameWriter is closed")
        if self._sync:
            self._f.write(_SYNC_PREFIX.pack(SYNC_MARKER, self._n, len(frame), _crc(frame)))
        else:
            self._f.write(_FRAME_PREFIX.pack(len(frame), _crc(frame)))
        self._f.write(frame)
        if hasattr(self._f, "flush"):
            self._f.flush()
        self._n += 1

    def close(self) -> int:
        """Finalize the stream; returns the frame count."""
        if not self._closed:
            self._f.write(struct.pack("<I", self._n))
            self._f.write(_END)
            if hasattr(self._f, "flush"):
                self._f.flush()
            self._closed = True
        return self._n

    def abort(self) -> int:
        """Stop writing WITHOUT finalizing: no trailer is appended, so the
        stream reads as truncated — the honest state for an interrupted
        producer. Returns the frames written so far."""
        self._closed = True
        return self._n

    def __enter__(self) -> FrameWriter:
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:
            self.abort()


def pack_frames(header: dict, frames, *, sync: bool = False) -> bytes:
    """One-shot v3 writer: global header + every frame, finalized."""
    bio = io.BytesIO()
    with FrameWriter(bio, header, sync=sync) as w:
        for fr in frames:
            w.write_frame(fr)
    return bio.getvalue()


def _parse_header(buf):
    """Magic + global header; returns (header, payload_offset, sync)."""
    if not is_v3(buf):
        raise ContainerError(f"bad container magic {bytes(buf[:6])!r}; expected {MAGIC_V3!r}")
    off = len(MAGIC_V3)
    if len(buf) < off + 4:
        raise TruncatedContainerError("truncated v3 container: stream ended inside the header length")
    (hlen,) = struct.unpack_from("<I", buf, off)
    off += 4
    if len(buf) < off + hlen:
        raise TruncatedContainerError("truncated v3 container: stream ended inside the global header")
    try:
        header = unpack_obj(bytes(buf[off : off + hlen]))
    except Exception as e:
        raise ContainerError(f"unreadable v3 global header: {e}") from e
    return header, off + hlen, bool(header.get("_sync"))


def _trailer(buf):
    """Locate the trailer; returns (data_end, declared_count | None)."""
    if len(buf) >= _TRAILER_LEN and bytes(buf[-len(_END) :]) == _END:
        (n,) = struct.unpack_from("<I", buf, len(buf) - _TRAILER_LEN)
        return len(buf) - _TRAILER_LEN, int(n)
    return len(buf), None


def read_header(buf) -> dict:
    """Global header alone — parseable even when the frame region is
    damaged (the salvage consumers need the geometry it carries)."""
    header, _, _ = _parse_header(memoryview(buf))
    return header


def frame_table(buf) -> tuple[dict, list[tuple[int, int, int]]]:
    """Parse a v3 stream without touching frame payloads.

    Returns ``(header, table)`` where ``table[i] = (offset, size, crc32)``
    of frame ``i``'s payload. Raises on bad magic or a truncated stream
    (missing end marker / frame-count mismatch). For damaged streams use
    :func:`scan_frames`, which salvages instead of raising.
    """
    buf = memoryview(buf)
    header, off, sync = _parse_header(buf)
    end_at, declared = _trailer(buf)
    prefix = _SYNC_PREFIX if sync else _FRAME_PREFIX
    table = []
    while off < end_at:
        if off + prefix.size > end_at:
            raise TruncatedContainerError(
                f"truncated v3 container: frame {len(table)} prefix runs past the end marker"
            )
        if sync:
            marker, seq, size, crc = prefix.unpack_from(buf, off)
            if marker != SYNC_MARKER:
                raise FrameSyncError(f"bad sync marker at byte {off} (frame {len(table)})")
            if seq != len(table):
                raise FrameSyncError(f"sync sequence mismatch at byte {off}: {seq} != {len(table)}")
        else:
            size, crc = prefix.unpack_from(buf, off)
        off += prefix.size
        if off + size > end_at:
            raise TruncatedContainerError(
                f"truncated v3 container: frame {len(table)} runs past the end marker"
            )
        table.append((off, size, crc))
        off += size
    if declared is None or declared != len(table):
        raise TruncatedContainerError(
            f"truncated v3 container: end marker/frame count invalid "
            f"({declared} declared, {len(table)} found)"
        )
    return header, table


def read_frame(buf, table_entry: tuple[int, int, int], *, verify: bool = True) -> memoryview:
    """Extract one frame payload by its :func:`frame_table` entry.

    Returns a zero-copy ``memoryview`` of the payload (CRC-checked in
    place) — the decode stack is bytes-like-tolerant end to end, so the
    per-frame copy the old ``bytes()`` slice paid is gone. Call
    ``bytes(...)`` on the result if you need an owning copy.
    """
    off, size, crc = table_entry
    frame = memoryview(buf)[off : off + size]
    if verify and _crc(frame) != crc:
        raise FrameCRCError(f"frame CRC mismatch at offset {off} (corrupt container)", offset=off)
    return frame


def unpack_frames(buf, *, verify: bool = True) -> tuple[dict, list[memoryview]]:
    """Parse a whole v3 stream into ``(header, [frame bytes, ...])``."""
    header, table = frame_table(buf)
    return header, [read_frame(buf, t, verify=verify) for t in table]


# ----------------------------------------------------------------- salvage
def _plausible_record(buf, off: int, end_at: int):
    """Heuristic resync probe for plain (non-sync) streams: a record at
    ``off`` is accepted only if its declared length stays in-bounds AND
    the payload's CRC32 matches the prefix — a 2^-32 false-positive gate.
    Returns (size, crc) or None."""
    if off + _FRAME_PREFIX.size > end_at:
        return None
    size, crc = _FRAME_PREFIX.unpack_from(buf, off)
    # zero-size records are rejected during resync: crc32(b"") == 0, so any
    # 12 zero bytes would otherwise look like a valid empty frame
    if size == 0 or off + _FRAME_PREFIX.size + size > end_at:
        return None
    start = off + _FRAME_PREFIX.size
    if _crc(buf[start : start + size]) != crc:
        return None
    return size, crc


def scan_frames(buf, *, resync: bool = True, verify: bool = True):
    """Salvage pass over a (possibly damaged) v3 stream.

    Returns ``(good_frames, report)`` where ``good_frames`` is a list of
    ``(index, payload)`` for every frame that survived intact and
    ``report`` is a :class:`~repro.core.errors.DamageReport`. Never raises
    for recoverable damage — only for an unreadable magic/global header,
    without which there is nothing to salvage against.

    ``index`` is the frame's true sequence number for sync-marked streams
    (the marker carries it); for plain streams it is positional, counting
    each damaged region as one lost frame — exact for single-frame damage,
    best-effort when a damaged region swallowed several frames.

    ``resync=False`` stops at the first damage (everything before it is
    still returned); ``resync=True`` scans forward for the next plausible
    record — the next sync marker, or for plain streams the next offset
    whose (length, CRC) pair is self-consistent — and keeps going.
    """
    buf = memoryview(buf)
    raw = bytes(buf)  # one copy; needed for marker .find() during resync
    header, off, sync = _parse_header(buf)
    end_at, declared = _trailer(buf)
    report = DamageReport(declared_frames=declared, truncated=declared is None)
    if declared is None:
        report.add("trailer", len(raw), detail="end marker missing (stream truncated or torn)")
    prefix = _SYNC_PREFIX if sync else _FRAME_PREFIX
    good: list[tuple[int, bytes]] = []
    idx = 0  # next expected index (positional for plain streams)

    def _resync(from_off: int) -> int | None:
        """Next plausible record offset after ``from_off``, or None."""
        if sync:
            pos = raw.find(SYNC_MARKER, from_off + 1, end_at)
            return pos if pos >= 0 else None
        for cand in range(from_off + 1, end_at - _FRAME_PREFIX.size + 1):
            if _plausible_record(buf, cand, end_at) is not None:
                return cand
        return None

    while off < end_at:
        damage_at = off
        seq = None
        if off + prefix.size > end_at:
            report.add("truncated", off, index=idx, detail="stream ended inside a frame prefix")
            report.frames_damaged += 1
            report.bytes_skipped += end_at - off
            break
        if sync:
            marker, seq, size, crc = prefix.unpack_from(buf, off)
            bad = marker != SYNC_MARKER
            kind = "sync"
            detail = "bad sync marker"
        else:
            size, crc = prefix.unpack_from(buf, off)
            bad = False
        if not bad and off + prefix.size + size > end_at:
            bad, kind, detail = True, "length", f"declared size {size} runs past the stream end"
        if not bad:
            start = off + prefix.size
            payload = raw[start : start + size]
            if verify and _crc(payload) != crc:
                bad, kind, detail = True, "crc", "payload CRC32 mismatch"
                # the record *structure* may still be intact (payload-only
                # damage): skip exactly this record and keep walking — if
                # the length was the damaged field, the next parse fails
                # and the resync below recovers
                report.add(kind, damage_at, index=seq if sync else idx, detail=detail)
                report.frames_damaged += 1
                report.bytes_skipped += prefix.size + size
                idx = (seq + 1) if sync else (idx + 1)
                off = start + size
                continue
            good.append(((seq if sync else idx), payload))
            report.frames_ok += 1
            idx = (seq + 1) if sync else (idx + 1)
            off = start + size
            continue
        # structural damage: bad marker or impossible length
        report.add(kind, damage_at, index=seq if sync else idx, detail=detail)
        report.frames_damaged += 1
        if not resync:
            report.bytes_skipped += end_at - damage_at
            break
        nxt = _resync(damage_at)
        if nxt is None:
            report.bytes_skipped += end_at - damage_at
            break
        report.bytes_skipped += nxt - damage_at
        if not sync:
            idx += 1  # assume the damaged region held one frame
        off = nxt
    if declared is not None and report.frames_ok + report.frames_damaged != declared:
        report.add(
            "trailer", end_at,
            detail=f"{declared} frames declared, {report.frames_ok} intact + {report.frames_damaged} damaged found",
        )
    return good, report


class FrameReader:
    """Streaming v3 reader over any ``read()``-able object.

    Parses the global header eagerly (``.header``); iterating yields frame
    payloads one at a time, CRC-checked, without buffering the rest of the
    stream — the decode loop can start before the producer finished
    writing later frames to the file.

    Degraded mode: :meth:`iter_frames` with ``on_error="skip"`` yields
    ``(index, payload)`` for intact frames only, recording damage in
    ``self.damage`` (a :class:`~repro.core.errors.DamageReport`) instead
    of raising — a CRC-damaged frame is skipped by its length prefix and
    the stream keeps going; structural damage (a record that no longer
    parses) ends the iteration with the damage recorded, since a
    forward-only reader cannot scan backwards (use :func:`scan_frames`
    on a buffered stream for full resync).

    Usable as a context manager; exit closes the underlying stream.
    """

    def __init__(self, f, *, verify: bool = True):
        self._f = f
        self._verify = verify
        self.frames_read = 0  # intact frames yielded
        self._seen = 0        # records walked (intact + skipped): positional index
        self.damage = DamageReport()
        magic = f.read(len(MAGIC_V3))
        if magic != MAGIC_V3:
            raise ContainerError(f"bad container magic {magic!r}; expected {MAGIC_V3!r}")
        (hlen,) = struct.unpack("<I", f.read(4))
        hb = f.read(hlen)
        if len(hb) < hlen:
            raise TruncatedContainerError("truncated v3 container: stream ended inside the global header")
        self.header = unpack_obj(hb)
        self._sync = bool(self.header.get("_sync"))

    def close(self) -> None:
        if hasattr(self._f, "close"):
            self._f.close()

    def __enter__(self) -> FrameReader:
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def _read_record(self):
        """One record: returns ("frame", seq, payload_len, crc),
        ("end", declared, None, None) or raises a typed error."""
        if self._sync:
            head = self._f.read(_SYNC_PREFIX.size)
            if len(head) >= _TRAILER_LEN and head[4 : 4 + len(_END)] == _END:
                (n,) = struct.unpack("<I", head[:4])
                return "end", n, None, None
            if len(head) < _SYNC_PREFIX.size:
                raise TruncatedContainerError("truncated v3 container: stream ended inside a frame prefix")
            marker, seq, size, crc = _SYNC_PREFIX.unpack(head)
            if marker != SYNC_MARKER:
                raise FrameSyncError(f"bad sync marker before frame {self.frames_read}")
            return "frame", seq, size, crc
        head = self._f.read(_FRAME_PREFIX.size)
        if len(head) < _FRAME_PREFIX.size:
            raise TruncatedContainerError("truncated v3 container: stream ended inside a frame prefix")
        # the trailer (u32 count + end marker) is exactly 12 bytes, the
        # same width as a frame prefix: detect it by the end marker
        if head[4:] == _END:
            (n,) = struct.unpack("<I", head[:4])
            return "end", n, None, None
        size, crc = _FRAME_PREFIX.unpack(head)
        return "frame", self._seen, size, crc

    def iter_frames(self, *, on_error: str = "raise"):
        """Yield ``(index, payload)`` per frame. ``on_error="skip"``
        records damage in ``self.damage`` and keeps going where possible
        instead of raising."""
        if on_error not in ("raise", "skip"):
            raise ValueError(f"on_error must be 'raise' or 'skip', got {on_error!r}")
        while True:
            try:
                kind, seq, size, crc = self._read_record()
            except ContainerError:
                if on_error == "raise":
                    raise
                self.damage.add("truncated", -1, index=self._seen,
                                detail="unreadable frame prefix; rest of stream abandoned")
                self.damage.truncated = True
                return
            if kind == "end":
                self.damage.declared_frames = seq
                if seq != self._seen:
                    if on_error == "raise":
                        raise TruncatedContainerError(
                            f"truncated v3 container: {seq} frames declared, {self._seen} read"
                        )
                    self.damage.add("trailer", -1, detail=f"{seq} declared, {self._seen} seen")
                return
            payload = self._f.read(size)
            if len(payload) < size:
                if on_error == "raise":
                    raise TruncatedContainerError("truncated v3 container: stream ended inside a frame")
                self.damage.add("truncated", -1, index=seq, detail="stream ended inside a frame")
                self.damage.frames_damaged += 1
                self.damage.truncated = True
                return
            self._seen += 1
            if self._verify and _crc(payload) != crc:
                if on_error == "raise":
                    raise FrameCRCError(f"frame {seq} CRC mismatch (corrupt container)", index=seq)
                self.damage.add("crc", -1, index=seq, detail="payload CRC32 mismatch")
                self.damage.frames_damaged += 1
                continue
            self.frames_read += 1
            self.damage.frames_ok += 1
            yield seq, payload

    def __iter__(self):
        for _, payload in self.iter_frames(on_error="raise"):
            yield payload
