"""Container v3: chunked frame streams (``CSZH3`` magic).

A v3 container is a sequence of *independently decodable* frames behind one
global header. Each frame is an opaque byte blob — for the compressor it is
a complete v1/v2 container of one shard/chunk, so every frame carries its
own header and section table and replays without any other frame — guarded
by a CRC32 and a length prefix. The layout is streaming-first:

    CSZH3\\n | u32 hlen | header (repro.core.serial) |
    n x [ u64 size | u32 crc32 | frame bytes ] | u32 n_frames | CSZ3END\\n

Frames are length-prefixed (a writer never needs to know sizes up front,
so encode can overlap I/O), and the trailing count + end marker let a
reader detect truncation. The global header is a plain serial dict; the
compressor stores ``kind="chunks"`` plus the split geometry there, other
producers (gradient shards, KV-cache offload) store their own kinds.

Random access walks the length prefixes — n hops of 12 bytes each, no
payload parsing — so partial decode (``frames=[...]``) and out-of-order
decode cost nothing beyond the frames actually read.
"""
from __future__ import annotations

import io
import struct
import zlib

from .serial import pack_obj, unpack_obj

MAGIC_V3 = b"CSZH3\n"
_END = b"CSZ3END\n"
_FRAME_PREFIX = struct.Struct("<QI")  # u64 size, u32 crc32


def is_v3(buf: bytes) -> bool:
    return bytes(buf[: len(MAGIC_V3)]) == MAGIC_V3


class FrameWriter:
    """Streaming v3 writer over any ``write()``-able object.

    Frames are written (and flushed, when the sink supports it) as they are
    produced, so a slow consumer — disk writeback, a socket — overlaps with
    the encode of the next frame instead of waiting for the whole
    container. ``close()`` appends the trailing frame count + end marker;
    a stream without them is detectably truncated.
    """

    def __init__(self, f, header: dict | None = None):
        self._f = f
        self._n = 0
        self._closed = False
        hb = pack_obj(dict(header or {}))
        f.write(MAGIC_V3)
        f.write(struct.pack("<I", len(hb)))
        f.write(hb)

    def write_frame(self, frame: bytes) -> None:
        if self._closed:
            raise ValueError("FrameWriter is closed")
        self._f.write(_FRAME_PREFIX.pack(len(frame), zlib.crc32(frame) & 0xFFFFFFFF))
        self._f.write(frame)
        if hasattr(self._f, "flush"):
            self._f.flush()
        self._n += 1

    def close(self) -> int:
        """Finalize the stream; returns the frame count."""
        if not self._closed:
            self._f.write(struct.pack("<I", self._n))
            self._f.write(_END)
            if hasattr(self._f, "flush"):
                self._f.flush()
            self._closed = True
        return self._n


def pack_frames(header: dict, frames) -> bytes:
    """One-shot v3 writer: global header + every frame, finalized."""
    bio = io.BytesIO()
    w = FrameWriter(bio, header)
    for fr in frames:
        w.write_frame(fr)
    w.close()
    return bio.getvalue()


def frame_table(buf) -> tuple[dict, list[tuple[int, int, int]]]:
    """Parse a v3 stream without touching frame payloads.

    Returns ``(header, table)`` where ``table[i] = (offset, size, crc32)``
    of frame ``i``'s payload. Raises on bad magic or a truncated stream
    (missing end marker / frame-count mismatch).
    """
    buf = memoryview(buf)
    if not is_v3(buf):
        raise ValueError(f"bad container magic {bytes(buf[:6])!r}; expected {MAGIC_V3!r}")
    off = len(MAGIC_V3)
    (hlen,) = struct.unpack_from("<I", buf, off)
    off += 4
    header = unpack_obj(bytes(buf[off : off + hlen]))
    off += hlen
    end_at = len(buf) - len(_END) - 4
    table = []
    while off < end_at:
        size, crc = _FRAME_PREFIX.unpack_from(buf, off)
        off += _FRAME_PREFIX.size
        if off + size > end_at:
            raise ValueError(f"truncated v3 container: frame {len(table)} runs past the end marker")
        table.append((off, size, crc))
        off += size
    (n,) = struct.unpack_from("<I", buf, off)
    if bytes(buf[off + 4 : off + 4 + len(_END)]) != _END or n != len(table):
        raise ValueError(
            f"truncated v3 container: end marker/frame count invalid ({n} declared, {len(table)} found)"
        )
    return header, table


def read_frame(buf, table_entry: tuple[int, int, int], *, verify: bool = True) -> bytes:
    """Extract one frame payload by its :func:`frame_table` entry."""
    off, size, crc = table_entry
    frame = bytes(memoryview(buf)[off : off + size])
    if verify and (zlib.crc32(frame) & 0xFFFFFFFF) != crc:
        raise ValueError(f"frame CRC mismatch at offset {off} (corrupt container)")
    return frame


def unpack_frames(buf, *, verify: bool = True) -> tuple[dict, list[bytes]]:
    """Parse a whole v3 stream into ``(header, [frame bytes, ...])``."""
    header, table = frame_table(buf)
    return header, [read_frame(buf, t, verify=verify) for t in table]


class FrameReader:
    """Streaming v3 reader over any ``read()``-able object.

    Parses the global header eagerly (``.header``); iterating yields frame
    payloads one at a time, CRC-checked, without buffering the rest of the
    stream — the decode loop can start before the producer finished
    writing later frames to the file.
    """

    def __init__(self, f, *, verify: bool = True):
        self._f = f
        self._verify = verify
        self.frames_read = 0
        magic = f.read(len(MAGIC_V3))
        if magic != MAGIC_V3:
            raise ValueError(f"bad container magic {magic!r}; expected {MAGIC_V3!r}")
        (hlen,) = struct.unpack("<I", f.read(4))
        self.header = unpack_obj(f.read(hlen))

    def __iter__(self):
        while True:
            prefix = self._f.read(_FRAME_PREFIX.size)
            if len(prefix) < _FRAME_PREFIX.size:
                raise ValueError("truncated v3 container: stream ended inside a frame prefix")
            # the trailer (u32 count + end marker) is exactly 12 bytes, the
            # same width as a frame prefix: detect it by the end marker
            if prefix[4:] == _END:
                (n,) = struct.unpack("<I", prefix[:4])
                if n != self.frames_read:
                    raise ValueError(
                        f"truncated v3 container: {n} frames declared, {self.frames_read} read"
                    )
                return
            size, crc = _FRAME_PREFIX.unpack(prefix)
            frame = self._f.read(size)
            if len(frame) < size:
                raise ValueError("truncated v3 container: stream ended inside a frame")
            if self._verify and (zlib.crc32(frame) & 0xFFFFFFFF) != crc:
                raise ValueError(f"frame {self.frames_read} CRC mismatch (corrupt container)")
            self.frames_read += 1
            yield frame
