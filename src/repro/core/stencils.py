"""Static interpolation step tables for the cuSZ-Hi predictor.

TPU adaptation (see DESIGN.md §3): each 1-D spline interpolation along a
dimension is expressed as a small banded (B,B) matrix applied along that
axis — an MXU-friendly matmul — instead of the CUDA per-thread gather.  All
index sets are compile-time constants because the block shape (17^ndim) is
fixed, so each (level, sub-step) becomes: up to `ndim` matmuls, a static
blend-weight grid, and a static target mask.

Splines (SZ3/QoZ family, §5.1.2):
  cubic centred  (-1, 9, 9, -1)/16          at (c-3s, c-s, c+s, c+3s)
  natural cubic  (-3, 23, 23, -3)/40        at (c-3s, c-s, c+s, c+3s)
  quad  asym     (3, 6, -1)/8               at (c-s, c+s, c+3s)   [left edge]
                 (-1, 6, 3)/8               at (c-3s, c-s, c+s)   [right edge]
  linear         (1, 1)/2                   at (c-s, c+s)

The natural-cubic weights are the QoZ/HPEZ "natural spline" variant; both
cubics share the quadratic/linear edge fallbacks, so either is usable at
every level.

Multi-dimensional scheme: at each level, sub-step m predicts the points with
exactly m "odd" coordinates by averaging the 1-D interpolations along those
odd dims — restricted to the dims whose stencil order is maximal ("only
prediction values with the highest spline order will be used and averaged").
1-D-sequence scheme: classic SZ3 pass per dim (dim d odd; later dims even;
earlier dims anything). ``"1d"`` sweeps dims in natural order; ``"1d-<perm>"``
(e.g. ``"1d-210"``) sweeps them in the given permutation — the sequential
orderings the per-level autotuner searches over.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

CUBIC = ((-3, -1.0 / 16), (-1, 9.0 / 16), (1, 9.0 / 16), (3, -1.0 / 16))
NAT_CUBIC = ((-3, -3.0 / 40), (-1, 23.0 / 40), (1, 23.0 / 40), (3, -3.0 / 40))
QUAD_L = ((-3, -1.0 / 8), (-1, 6.0 / 8), (1, 3.0 / 8))
QUAD_R = ((-1, 3.0 / 8), (1, 6.0 / 8), (3, -1.0 / 8))
LINEAR = ((-1, 0.5), (1, 0.5))

_FULL_STENCILS = {"cubic": CUBIC, "natural-cubic": NAT_CUBIC}

SPLINES = ("linear", "cubic", "natural-cubic")
SCHEMES = ("1d", "md")
LEVELS = (8, 4, 2, 1)  # anchor stride 16 -> 4-level hierarchy (paper §5.1.1)


def scheme_dims(scheme: str, ndim: int) -> tuple[int, ...] | None:
    """Sweep order of a sequential scheme, or None for the "md" scheme.

    Raises ValueError for malformed scheme names (the error lists the valid
    forms) so typos fail before any step table is built.
    """
    if scheme == "md":
        return None
    if scheme == "1d":
        return tuple(range(ndim))
    if scheme.startswith("1d-"):
        try:
            dims = tuple(int(ch) for ch in scheme[3:])
        except ValueError:
            dims = ()
        if sorted(dims) == list(range(ndim)):
            return dims
    raise ValueError(
        f"unknown scheme {scheme!r} for ndim={ndim}; expected 'md', '1d', or "
        f"'1d-<perm of 0..{ndim - 1}>' (e.g. '1d-{''.join(map(str, reversed(range(ndim))))}')"
    )


def interp_matrix(B: int, s: int, spline: str) -> tuple[np.ndarray, np.ndarray]:
    """(B,B) row-operator + per-coordinate stencil order (3=cubic,2=quad,1=linear)."""
    if spline not in SPLINES:
        raise ValueError(f"unknown spline {spline!r}; one of {SPLINES}")
    full = _FULL_STENCILS.get(spline)
    M = np.zeros((B, B), np.float32)
    order = np.zeros(B, np.int32)
    for c in range(s, B, 2 * s):
        if full is not None and c - 3 * s >= 0 and c + 3 * s <= B - 1:
            stencil, order[c] = full, 3
        elif full is not None and c + 3 * s <= B - 1:
            stencil, order[c] = QUAD_R, 2
        elif full is not None and c - 3 * s >= 0:
            stencil, order[c] = QUAD_L, 2
        else:
            stencil, order[c] = LINEAR, 1
        for off, w in stencil:
            M[c, c + off * s] = w
    return M, order


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: build_steps is lru_cached,
class Step:                                     # so equal configs share Step objects (jit-cache friendly)
    """One vectorized prediction pass: pred = sum_d w_d * (M_d @_axis_d recon)."""

    level: int                      # interpolation stride s
    dims: tuple[int, ...]           # dims with a matmul this step
    matrices: tuple                 # per dim in `dims`: (B,B) np.float32
    weights: tuple                  # per dim in `dims`: (B,)*ndim np.float32 blend grid
    mask: np.ndarray                # (B,)*ndim bool — points assigned this step


def _coord_grids(B: int, ndim: int):
    return np.meshgrid(*([np.arange(B)] * ndim), indexing="ij")


@functools.lru_cache(maxsize=None)
def build_steps(
    ndim: int,
    B: int = 17,
    levels: tuple[int, ...] = LEVELS,
    splines: tuple[str, ...] = ("cubic",) * 4,
    schemes: tuple[str, ...] = ("md",) * 4,
) -> tuple[Step, ...]:
    """Static step list for one (spline, scheme) configuration per level."""
    assert len(splines) == len(levels) and len(schemes) == len(levels)
    coords = _coord_grids(B, ndim)
    steps: list[Step] = []
    for s, spline, scheme in zip(levels, splines, schemes):
        M, order = interp_matrix(B, s, spline)
        on_lattice = np.ones((B,) * ndim, bool)
        odd = []
        for d in range(ndim):
            on_lattice &= coords[d] % s == 0
            odd.append(coords[d] % (2 * s) == s)
        odd = np.stack(odd)  # (ndim, B..)
        ord_d = np.stack([order[coords[d]] for d in range(ndim)])  # (ndim, B..)
        if scheme == "md":
            n_odd = odd.sum(0)
            for m in range(1, ndim + 1):
                mask = on_lattice & (n_odd == m)
                if not mask.any():
                    continue
                # per-point max order among odd dims; dims at max order share weight
                ord_masked = np.where(odd, ord_d, -1)
                omax = ord_masked.max(0)
                used = odd & (ord_masked == omax[None])
                cnt = used.sum(0)
                dims, mats, wts = [], [], []
                for d in range(ndim):
                    w = np.where(mask & used[d], 1.0 / np.maximum(cnt, 1), 0.0).astype(np.float32)
                    if w.any():
                        dims.append(d)
                        mats.append(M)
                        wts.append(w)
                steps.append(Step(s, tuple(dims), tuple(mats), tuple(wts), mask))
        else:
            sweep = scheme_dims(scheme, ndim)  # raises on malformed names
            for i, d in enumerate(sweep):
                mask = on_lattice & odd[d]
                for e in sweep[i + 1 :]:
                    mask &= ~odd[e]  # dims later in the sweep still even at this level
                if not mask.any():
                    continue
                w = mask.astype(np.float32)
                steps.append(Step(s, (d,), (M,), (w,), mask))
    # Invariant (full hierarchies only): every non-anchor point covered once.
    if levels and levels[0] * 2 - 1 <= B and 1 in levels:
        cover = np.zeros((B,) * ndim, np.int32)
        for st in steps:
            cover += st.mask
        anchors = np.ones((B,) * ndim, bool)
        for d in range(ndim):
            anchors &= coords[d] % (2 * levels[0]) == 0
        assert (cover[anchors] == 0).all() and (cover[~anchors] == 1).all(), "step coverage broken"
    return tuple(steps)


def config_key(splines, schemes) -> tuple:
    return (tuple(splines), tuple(schemes))
