"""Block partitioning for the cuSZ-Hi predictor.

The paper (§5.1.1) partitions the field into isotropic 17^ndim blocks whose
corners are the losslessly-stored anchor points (anchor stride 16 per dim).
Adjacent blocks share their boundary faces; face points are predicted
identically by both owners (a face point's stencil never leaves the face),
so overlapping scatter writes are value-identical and ownership is exact.
"""
from __future__ import annotations

import itertools

import numpy as np

ANCHOR_STRIDE = 16
BLOCK = ANCHOR_STRIDE + 1  # 17: closed block [0, 16]^ndim


def padded_shape(shape: tuple[int, ...], stride: int = ANCHOR_STRIDE) -> tuple[int, ...]:
    """Each dim padded up to k*stride + 1 so every block is complete."""
    out = []
    for d in shape:
        k = max(1, -(-max(d - 1, 1) // stride))  # ceil((d-1)/stride), >= 1
        out.append(k * stride + 1)
    return tuple(out)


def pad_field(x: np.ndarray, stride: int = ANCHOR_STRIDE) -> np.ndarray:
    """Edge-replicate pad to the block grid shape."""
    tgt = padded_shape(x.shape, stride)
    pads = [(0, t - s) for s, t in zip(x.shape, tgt)]
    if all(p == (0, 0) for p in pads):
        return x
    return np.pad(x, pads, mode="edge")


def gather_blocks(xp: np.ndarray, stride: int = ANCHOR_STRIDE) -> np.ndarray:
    """(padded field) -> (nb, B, B, ...) overlapping closed blocks.

    nb = prod((dim-1)/stride); block [i] = xp[stride*i : stride*i + B].
    """
    B = stride + 1
    win = np.lib.stride_tricks.sliding_window_view(xp, (B,) * xp.ndim)
    sl = tuple(slice(None, None, stride) for _ in range(xp.ndim))
    blocks = win[sl]  # (nb0, nb1, ..., B, B, ...)
    nb = int(np.prod(blocks.shape[: xp.ndim]))
    return np.ascontiguousarray(blocks.reshape((nb,) + (B,) * xp.ndim))


def block_grid(shape_padded: tuple[int, ...], stride: int = ANCHOR_STRIDE) -> tuple[int, ...]:
    return tuple((d - 1) // stride for d in shape_padded)


def scatter_blocks(blocks: np.ndarray, shape_padded: tuple[int, ...], stride: int = ANCHOR_STRIDE) -> np.ndarray:
    """Inverse of gather_blocks. Overlapping faces are value-identical, so each
    block owns its half-open [0, stride)^ndim cells plus the global far faces."""
    ndim = len(shape_padded)
    nbs = block_grid(shape_padded, stride)
    out = np.empty(shape_padded, dtype=blocks.dtype)
    bl = blocks.reshape(nbs + (stride + 1,) * ndim)
    for far in itertools.product((False, True), repeat=ndim):
        # destination region: interior cells on non-far dims, last plane on far dims
        dst = tuple(slice(0, shape_padded[d] - 1) if not far[d] else slice(shape_padded[d] - 1, shape_padded[d]) for d in range(ndim))
        # source: all blocks/cells 0..stride-1 on non-far dims; last block, cell=stride on far dims
        src_blk = tuple(slice(None) if not far[d] else slice(nbs[d] - 1, nbs[d]) for d in range(ndim))
        src_cell = tuple(slice(0, stride) if not far[d] else slice(stride, stride + 1) for d in range(ndim))
        sub = bl[src_blk + src_cell]  # (nb0',..,c0',..)
        # interleave block/cell axes -> spatial
        perm = []
        for d in range(ndim):
            perm += [d, ndim + d]
        sub = np.transpose(sub, perm)
        new_shape = tuple(sub.shape[2 * d] * sub.shape[2 * d + 1] for d in range(ndim))
        out[dst] = sub.reshape(new_shape)
    return out


def anchor_grid(xp: np.ndarray, stride: int = ANCHOR_STRIDE) -> np.ndarray:
    """Losslessly stored anchors: every coordinate divisible by the stride."""
    sl = tuple(slice(None, None, stride) for _ in range(xp.ndim))
    return np.ascontiguousarray(xp[sl])


def place_anchors(shape_padded: tuple[int, ...], anchors: np.ndarray, stride: int = ANCHOR_STRIDE, dtype=np.float32) -> np.ndarray:
    out = np.zeros(shape_padded, dtype=dtype)
    sl = tuple(slice(None, None, stride) for _ in range(len(shape_padded)))
    out[sl] = anchors
    return out
