"""Block partitioning for the cuSZ-Hi predictor.

The paper (§5.1.1) partitions the field into isotropic 17^ndim blocks whose
corners are the losslessly-stored anchor points (anchor stride 16 per dim).
Adjacent blocks share their boundary faces; face points are predicted
identically by both owners (a face point's stencil never leaves the face),
so overlapping scatter writes are value-identical and ownership is exact.
"""
from __future__ import annotations

import functools
import itertools

import numpy as np

ANCHOR_STRIDE = 16
BLOCK = ANCHOR_STRIDE + 1  # 17: closed block [0, 16]^ndim


def padded_shape(shape: tuple[int, ...], stride: int = ANCHOR_STRIDE) -> tuple[int, ...]:
    """Each dim padded up to k*stride + 1 so every block is complete."""
    out = []
    for d in shape:
        k = max(1, -(-max(d - 1, 1) // stride))  # ceil((d-1)/stride), >= 1
        out.append(k * stride + 1)
    return tuple(out)


def pad_field(x: np.ndarray, stride: int = ANCHOR_STRIDE) -> np.ndarray:
    """Edge-replicate pad to the block grid shape."""
    tgt = padded_shape(x.shape, stride)
    pads = [(0, t - s) for s, t in zip(x.shape, tgt)]
    if all(p == (0, 0) for p in pads):
        return x
    return np.pad(x, pads, mode="edge")


def pad_field_batch(xb: np.ndarray, stride: int = ANCHOR_STRIDE) -> np.ndarray:
    """Batched pad_field: (batch, *spatial) -> (batch, *padded)."""
    tgt = padded_shape(xb.shape[1:], stride)
    pads = [(0, 0)] + [(0, t - s) for s, t in zip(xb.shape[1:], tgt)]
    if all(p == (0, 0) for p in pads[1:]):
        return xb
    return np.pad(xb, pads, mode="edge")


def gather_blocks(xp: np.ndarray, stride: int = ANCHOR_STRIDE) -> np.ndarray:
    """(padded field) -> (nb, B, B, ...) overlapping closed blocks.

    nb = prod((dim-1)/stride); block [i] = xp[stride*i : stride*i + B].
    """
    return gather_blocks_batch(xp[None], stride)


def gather_blocks_batch(xpb: np.ndarray, stride: int = ANCHOR_STRIDE) -> np.ndarray:
    """Batched gather: (batch, *padded) -> (batch*nb, B, B, ...).

    Block order matches per-item gather_blocks concatenated along axis 0.
    """
    B = stride + 1
    ndim = xpb.ndim - 1
    win = np.lib.stride_tricks.sliding_window_view(xpb, (B,) * ndim, axis=tuple(range(1, ndim + 1)))
    sl = (slice(None),) + tuple(slice(None, None, stride) for _ in range(ndim))
    blocks = win[sl]  # (batch, nb0, nb1, ..., B, B, ...)
    nb = int(np.prod(blocks.shape[1 : 1 + ndim]))
    return np.ascontiguousarray(blocks.reshape((xpb.shape[0] * nb,) + (B,) * ndim))


def block_grid(shape_padded: tuple[int, ...], stride: int = ANCHOR_STRIDE) -> tuple[int, ...]:
    return tuple((d - 1) // stride for d in shape_padded)


def scatter_blocks(blocks: np.ndarray, shape_padded: tuple[int, ...], stride: int = ANCHOR_STRIDE) -> np.ndarray:
    """Inverse of gather_blocks. Overlapping faces are value-identical, so each
    block owns its half-open [0, stride)^ndim cells plus the global far faces."""
    return scatter_blocks_batch(blocks, 1, shape_padded, stride)[0]


def scatter_blocks_batch(blocks: np.ndarray, batch: int, shape_padded: tuple[int, ...], stride: int = ANCHOR_STRIDE) -> np.ndarray:
    """Batched inverse of gather_blocks_batch: (batch*nb, B..) -> (batch, *padded)."""
    ndim = len(shape_padded)
    nbs = block_grid(shape_padded, stride)
    out = np.empty((batch,) + shape_padded, dtype=blocks.dtype)
    bl = blocks.reshape((batch,) + nbs + (stride + 1,) * ndim)
    nil = (slice(None),)
    for far in itertools.product((False, True), repeat=ndim):
        # destination region: interior cells on non-far dims, last plane on far dims
        dst = tuple(slice(0, shape_padded[d] - 1) if not far[d] else slice(shape_padded[d] - 1, shape_padded[d]) for d in range(ndim))
        # source: all blocks/cells 0..stride-1 on non-far dims; last block, cell=stride on far dims
        src_blk = tuple(slice(None) if not far[d] else slice(nbs[d] - 1, nbs[d]) for d in range(ndim))
        src_cell = tuple(slice(0, stride) if not far[d] else slice(stride, stride + 1) for d in range(ndim))
        sub = bl[nil + src_blk + src_cell]  # (batch, nb0',.., c0',..)
        # interleave block/cell axes -> spatial
        perm = [0]
        for d in range(ndim):
            perm += [1 + d, 1 + ndim + d]
        sub = np.transpose(sub, perm)
        new_shape = (batch,) + tuple(sub.shape[1 + 2 * d] * sub.shape[2 + 2 * d] for d in range(ndim))
        out[nil + dst] = sub.reshape(new_shape)
    return out


@functools.lru_cache(maxsize=16)
def _scatter_index(shape_padded: tuple[int, ...], stride: int = ANCHOR_STRIDE):
    """Flat gather map realizing scatter_blocks as a single take.

    ``idx[p]`` = index into the flattened (nb, B..) block array of the
    value scatter_blocks writes at padded position ``p`` — produced by
    running the numpy scatter over an arange, so the owner choice (and
    therefore the output bytes) is identical to the reference scatter.
    Cached as an int32 *device* array (block volumes are < 2^31): repeat
    callers — one per frame on the sharded path — pay no host->device
    re-upload, and the cache holds 4 bytes/cell for a handful of shapes
    rather than unbounded int64 host copies.
    """
    import jax.numpy as jnp

    nbs = block_grid(shape_padded, stride)
    nb = int(np.prod(nbs))
    B = stride + 1
    src = np.arange(nb * B ** len(shape_padded), dtype=np.int32)
    idx = scatter_blocks(src.reshape((nb,) + (B,) * len(shape_padded)), shape_padded, stride)
    return jnp.asarray(idx.reshape(-1))  # uncommitted: follows the operand's device


def scatter_blocks_batch_jnp(blocks, batch: int, shape_padded: tuple[int, ...], stride: int = ANCHOR_STRIDE):
    """Device twin of scatter_blocks_batch: one cached-index gather.

    ``blocks`` is a jax array shaped (batch*nb, B..); returns the (batch,
    *padded) grid as a device array, bit-identical to the numpy scatter.
    """
    import jax.numpy as jnp

    idx = _scatter_index(tuple(int(s) for s in shape_padded), stride)
    flat = blocks.reshape(batch, -1)
    return jnp.take(flat, idx, axis=1).reshape((batch,) + tuple(shape_padded))


def gather_blocks_batch_jnp(xpb, stride: int = ANCHOR_STRIDE):
    """Device twin of gather_blocks_batch: (batch, *padded) -> (batch*nb, B..).

    Pure data movement with static indices — bit-identical to the numpy
    sliding-window gather, traceable inside shard_map.
    """
    import jax.numpy as jnp

    B = stride + 1
    ndim = xpb.ndim - 1
    out = xpb
    nbs = []
    for d in range(ndim):
        ax = 1 + d
        nbd = (out.shape[ax] - 1) // stride
        nbs.append(nbd)
        idx = (np.arange(nbd)[:, None] * stride + np.arange(B)[None, :]).reshape(-1)
        out = jnp.take(out, jnp.asarray(idx), axis=ax)
    shp = [out.shape[0]]
    for nbd in nbs:
        shp += [nbd, B]
    out = out.reshape(shp)
    perm = [0] + [1 + 2 * d for d in range(ndim)] + [2 + 2 * d for d in range(ndim)]
    out = jnp.transpose(out, perm)
    return out.reshape((xpb.shape[0] * int(np.prod(nbs)),) + (B,) * ndim)


@functools.lru_cache(maxsize=16)
def _anchor_index(shape_padded: tuple[int, ...], stride: int = ANCHOR_STRIDE):
    """Cached device (idx, mask) realizing place_anchors as a gather.

    ``mask[p]`` marks padded positions whose every coordinate is divisible
    by the stride; ``idx[p]`` is the flat anchor-grid index feeding it
    (0 where masked off). Gather+where instead of a strided scatter — the
    fast direction on XLA:CPU (same trade as _scatter_index).
    """
    import jax.numpy as jnp

    coords = np.meshgrid(*(np.arange(d) for d in shape_padded), indexing="ij")
    mask = np.ones(shape_padded, bool)
    for c in coords:
        mask &= c % stride == 0
    ashape = tuple((d - 1) // stride + 1 for d in shape_padded)
    idx = np.ravel_multi_index(tuple(c // stride for c in coords), ashape).astype(np.int32)
    idx[~mask] = 0
    return jnp.asarray(idx.reshape(-1)), jnp.asarray(mask.reshape(-1))


def place_anchors_batch_jnp(shape_padded: tuple[int, ...], anchors, stride: int = ANCHOR_STRIDE):
    """Device twin of place_anchors_batch; ``anchors`` is a jax array
    (batch, *anchor_shape); returns (batch, *padded) f32, bit-identical."""
    import jax.numpy as jnp

    idx, mask = _anchor_index(tuple(int(s) for s in shape_padded), stride)
    flat = anchors.astype(jnp.float32).reshape(anchors.shape[0], -1)
    rows = jnp.take(flat, idx, axis=1)
    out = jnp.where(mask[None, :], rows, jnp.float32(0.0))
    return out.reshape((anchors.shape[0],) + tuple(shape_padded))


def anchor_grid(xp: np.ndarray, stride: int = ANCHOR_STRIDE) -> np.ndarray:
    """Losslessly stored anchors: every coordinate divisible by the stride."""
    sl = tuple(slice(None, None, stride) for _ in range(xp.ndim))
    return np.ascontiguousarray(xp[sl])


def anchor_grid_batch(xpb: np.ndarray, stride: int = ANCHOR_STRIDE) -> np.ndarray:
    """Batched anchor_grid: (batch, *padded) -> (batch, *anchor_shape)."""
    sl = (slice(None),) + tuple(slice(None, None, stride) for _ in range(xpb.ndim - 1))
    return np.ascontiguousarray(xpb[sl])


def place_anchors(shape_padded: tuple[int, ...], anchors: np.ndarray, stride: int = ANCHOR_STRIDE, dtype=np.float32) -> np.ndarray:
    out = np.zeros(shape_padded, dtype=dtype)
    sl = tuple(slice(None, None, stride) for _ in range(len(shape_padded)))
    out[sl] = anchors
    return out


def place_anchors_batch(shape_padded: tuple[int, ...], anchors: np.ndarray, stride: int = ANCHOR_STRIDE, dtype=np.float32) -> np.ndarray:
    """Batched place_anchors; `anchors` is (batch, *anchor_shape)."""
    out = np.zeros((anchors.shape[0],) + shape_padded, dtype=dtype)
    sl = (slice(None),) + tuple(slice(None, None, stride) for _ in range(len(shape_padded)))
    out[sl] = anchors
    return out
