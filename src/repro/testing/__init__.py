"""repro.testing — deterministic fault injection + adversarial inputs
for the chaos suite."""
from .adversarial import CORPUS, corpus_field  # noqa: F401
from .faults import (  # noqa: F401
    FlakyFile,
    bit_flip,
    corrupt_frame,
    drop_frame,
    fault_rng,
    fault_seed,
    perturb_quant_codes,
    torn_tail,
    truncate_fraction,
)
