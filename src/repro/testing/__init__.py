"""repro.testing — deterministic fault injection for the chaos suite."""
from .faults import (  # noqa: F401
    FlakyFile,
    bit_flip,
    corrupt_frame,
    drop_frame,
    fault_rng,
    fault_seed,
    torn_tail,
    truncate_fraction,
)
