"""Adversarial input corpus for the guaranteed-bound runtime.

Every generator here produces a field class that historically broke (or
silently degraded) an error-bounded compressor: non-finite fill regions,
extreme dynamic ranges that overflow float32 reductions, denormal
magnitudes below the pw_rel transform's resolution, constant planes that
collapse the value range, and single-voxel outliers that stress the
outlier section. The contract every spec must satisfy on every one of
these is **bound-or-typed-error**: either the round-trip honors the
declared bound (bit-exactly on non-finite points), or compress raises a
typed error (``ValueError`` family / ``BoundViolationError``) — silent
corruption is the only forbidden outcome. ``tests/test_adversarial.py``
sweeps the full spec × corpus grid at tier 1 and drives the hypothesis
property sweep at tier 2.

All generators are deterministic under an explicit seed (default: the
chaos-lane :func:`repro.testing.faults.fault_seed`), so a CI failure
names a cell that replays exactly.
"""
from __future__ import annotations

import numpy as np

from .faults import fault_seed


def _rng(seed: int | None) -> np.random.Generator:
    return np.random.default_rng(fault_seed() if seed is None else seed)


def _smooth(rng: np.random.Generator, shape) -> np.ndarray:
    x = rng.standard_normal(shape)
    for ax in range(x.ndim):
        x = np.cumsum(x, axis=ax)
    x /= max(1.0, float(np.max(np.abs(x))))
    return x.astype(np.float32)


def nan_slab(shape=(24, 24, 24), *, frac: float = 0.2, seed: int | None = None) -> np.ndarray:
    """A smooth field with a contiguous NaN slab (masked ocean region)."""
    x = _smooth(_rng(seed), shape)
    k = max(1, int(shape[0] * frac))
    x[:k] = np.nan
    return x


def inf_edges(shape=(24, 24, 24), *, seed: int | None = None) -> np.ndarray:
    """±Inf on the boundary faces (sensor saturation at the domain edge)."""
    x = _smooth(_rng(seed), shape)
    x[0, ...] = np.inf
    x[-1, ...] = -np.inf
    return x


def scattered_nonfinite(shape=(24, 24, 24), *, frac: float = 0.01,
                        seed: int | None = None) -> np.ndarray:
    """NaN / +Inf / -Inf sprinkled at random points (bad pixels)."""
    rng = _rng(seed)
    x = _smooth(rng, shape)
    flat = x.reshape(-1)
    n = max(3, int(flat.size * frac))
    idx = rng.choice(flat.size, size=n, replace=False)
    flat[idx[0::3]] = np.nan
    flat[idx[1::3]] = np.inf
    flat[idx[2::3]] = -np.inf
    return x


def all_nan(shape=(16, 16), **_kw) -> np.ndarray:
    """Entirely non-finite (an unwritten/poisoned allocation)."""
    return np.full(shape, np.nan, np.float32)


def denormal_heavy(shape=(24, 24, 24), *, seed: int | None = None) -> np.ndarray:
    """Magnitudes straddling the float32 denormal range (~1e-38..1e-45)."""
    rng = _rng(seed)
    mag = 10.0 ** rng.uniform(-45.0, -30.0, size=shape)
    sgn = np.where(rng.random(shape) < 0.5, -1.0, 1.0)
    return (mag * sgn).astype(np.float32)


def huge_dynamic_range(shape=(24, 24, 24), *, seed: int | None = None) -> np.ndarray:
    """Values spanning ~1e±30: a float32 max-min overflows to inf."""
    rng = _rng(seed)
    mag = 10.0 ** rng.uniform(-30.0, 30.0, size=shape)
    sgn = np.where(rng.random(shape) < 0.5, -1.0, 1.0)
    x = (mag * sgn).astype(np.float32)
    x.reshape(-1)[0] = np.float32(-3e38)  # pin the range to near-overflow
    x.reshape(-1)[-1] = np.float32(3e38)
    return x


def constant_plane(shape=(24, 24, 24), *, value: float = 2.5, **_kw) -> np.ndarray:
    """A constant field (zero dynamic range)."""
    return np.full(shape, np.float32(value), np.float32)


def constant_with_plane(shape=(24, 24, 24), *, seed: int | None = None) -> np.ndarray:
    """Smooth everywhere except one constant plane (a land/sea mask fill)."""
    x = _smooth(_rng(seed), shape)
    x[shape[0] // 2] = 0.0
    return x


def single_voxel_outlier(shape=(24, 24, 24), *, spike: float = 1e6,
                         seed: int | None = None) -> np.ndarray:
    """A smooth O(1) field with one enormous spike voxel."""
    x = _smooth(_rng(seed), shape)
    c = tuple(d // 2 for d in shape)
    x[c] = np.float32(spike)
    return x


def signed_zeros(shape=(16, 16), *, seed: int | None = None) -> np.ndarray:
    """A field mixing -0.0, +0.0 and small mixed-sign values (the pw_rel
    sign/zero bitmap edge cases)."""
    rng = _rng(seed)
    x = (rng.standard_normal(shape) * 1e-3).astype(np.float32)
    flat = x.reshape(-1)
    flat[0::7] = 0.0
    flat[1::7] = -0.0
    return x


# name -> generator; every cell of the tier-1 sweep and the tier-2
# property test draws from this registry
CORPUS = {
    "nan_slab": nan_slab,
    "inf_edges": inf_edges,
    "scattered_nonfinite": scattered_nonfinite,
    "all_nan": all_nan,
    "denormal_heavy": denormal_heavy,
    "huge_dynamic_range": huge_dynamic_range,
    "constant_plane": constant_plane,
    "constant_with_plane": constant_with_plane,
    "single_voxel_outlier": single_voxel_outlier,
    "signed_zeros": signed_zeros,
}


def corpus_field(name: str, *, seed: int | None = None) -> np.ndarray:
    """One corpus field by registry name, deterministic under ``seed``."""
    return CORPUS[name](seed=seed)
