"""Deterministic fault injectors for the chaos test suite.

Every injector is a pure function ``bytes -> bytes`` (or a thin wrapper
around a file object), parameterized so a committed fixture or a seeded
sweep reproduces the exact same damage forever. Storage faults model the
real failure modes of the v3 container stack:

* :func:`bit_flip` — a single flipped bit (media corruption);
* :func:`truncate_fraction` — the stream cut short (crash mid-transfer);
* :func:`torn_tail` — a torn write: the tail replaced by garbage the
  length of a partially-landed block (power loss inside ``write()``);
* :func:`corrupt_frame` / :func:`drop_frame` — frame-targeted damage for
  v3 streams (flip inside payload i / splice a whole record out);
* :class:`FlakyFile` — a file wrapper raising ``OSError`` on the Nth
  ``write()``/``read()`` call, driving the retry/backoff paths.

Seeding: :func:`fault_seed` reads ``REPRO_FAULTS`` (pinned in the CI
chaos lane) so randomized sweeps are reproducible across runs; pass the
result to :func:`fault_rng` / hypothesis / your own sampler.
"""
from __future__ import annotations

import contextlib
import os

import numpy as np


def fault_seed(default: int = 20260808) -> int:
    """The chaos-suite seed: ``REPRO_FAULTS`` env var, or ``default``."""
    try:
        return int(os.environ.get("REPRO_FAULTS", default))
    except ValueError:
        return default


def fault_rng(seed: int | None = None) -> np.random.Generator:
    return np.random.default_rng(fault_seed() if seed is None else seed)


# ---------------------------------------------------------------- storage
def bit_flip(buf: bytes, offset: int, bit: int = 0) -> bytes:
    """Flip one bit at byte ``offset`` (negative offsets index from the end)."""
    b = bytearray(buf)
    b[offset] ^= 1 << (bit & 7)
    return bytes(b)


def truncate_fraction(buf: bytes, fraction: float) -> bytes:
    """Keep the first ``fraction`` of the stream (crash mid-transfer)."""
    if not 0.0 <= fraction <= 1.0:
        raise ValueError(f"fraction must be in [0, 1], got {fraction}")
    return bytes(buf[: int(len(buf) * fraction)])


def torn_tail(buf: bytes, fraction: float, *, garbage: int = 64, seed: int | None = None) -> bytes:
    """Torn write: truncate at ``fraction`` then append ``garbage`` bytes
    of seeded noise — the on-disk state after a write that half-landed."""
    kept = truncate_fraction(buf, fraction)
    noise = fault_rng(seed).integers(0, 256, size=garbage, dtype=np.uint8).tobytes()
    return kept + noise


# ----------------------------------------------------------- v3 targeted
def _v3_table(buf: bytes):
    from repro.core import frames

    header, table = frames.frame_table(buf)
    sync = bool(header.get("_sync"))
    prefix = 24 if sync else 12  # sync: 8B marker + u32 seq + u64 size + u32 crc
    return table, prefix


def corrupt_frame(buf: bytes, index: int, *, offset: int = 0, bit: int = 0) -> bytes:
    """Flip one bit inside frame ``index``'s payload of a v3 stream."""
    table, _ = _v3_table(buf)
    off, size, _ = table[index]
    if not -size <= offset < size:
        raise ValueError(f"offset {offset} outside frame {index} (size {size})")
    return bit_flip(buf, off + (offset % size), bit)


def drop_frame(buf: bytes, index: int) -> bytes:
    """Splice frame ``index``'s whole record (prefix + payload) out of a
    v3 stream, leaving the trailer count untouched — the reader sees a
    consistent-looking stream whose declared count no longer matches."""
    table, prefix = _v3_table(buf)
    off, size, _ = table[index]
    return bytes(buf[: off - prefix]) + bytes(buf[off + size :])


# ----------------------------------------------------------- encoder fault
@contextlib.contextmanager
def perturb_quant_codes(*, n_calls: int = 1, delta: int = 5, frac: float = 0.01,
                        seed: int | None = None):
    """Arm the compressor's quantization-code fault hook for a ``with``
    block: the first ``n_calls`` predictor runs get ``frac`` of their
    *nonzero* codes shifted by ±``delta`` (clipped into [1, 255] so the
    code==0 <=> outlier invariant survives), after which the hook
    disarms. Each perturbed code lands the reconstruction ``delta * 2eb``
    away from its point — a genuine silent bound violation of the kind a
    predictor/engine bug would produce, which ``CompressorSpec(verify=
    "sample")`` must catch and repair (the repair re-encode runs after
    the hook disarms, so it is clean). Deterministic under
    :func:`fault_seed`; yields a stats dict (``calls``, ``perturbed``).
    """
    from repro.core import compressor as _comp

    rng = fault_rng(seed)
    stats = {"calls": 0, "perturbed": 0}

    def hook(codes: np.ndarray) -> np.ndarray:
        if stats["calls"] >= n_calls:
            return codes
        stats["calls"] += 1
        flat = codes.reshape(-1).copy()
        nz = np.flatnonzero(flat != 0)
        if nz.size == 0:
            return codes
        k = max(1, int(nz.size * frac))
        pick = rng.choice(nz, size=min(k, nz.size), replace=False)
        shift = np.where(rng.random(pick.size) < 0.5, -delta, delta).astype(np.int32)
        moved = np.clip(flat[pick].astype(np.int32) + shift, 1, 255)
        # a shift that lands back on the original value would be a no-op;
        # push those to the other side
        same = moved == flat[pick]
        moved[same] = np.clip(flat[pick][same].astype(np.int32) - shift[same], 1, 255)
        flat[pick] = moved.astype(codes.dtype)
        stats["perturbed"] += int(np.count_nonzero(flat != codes.reshape(-1)))
        return flat.reshape(codes.shape)

    prev = _comp._CODE_FAULT
    _comp._CODE_FAULT = hook
    try:
        yield stats
    finally:
        _comp._CODE_FAULT = prev


# ------------------------------------------------------------------- I/O
class FlakyFile:
    """File-object wrapper that raises on chosen calls.

    ``fail_calls``: 1-based call numbers (counted per wrapped op across
    the object's lifetime) that raise instead of performing the op;
    ``fail_ops``: which methods count/fail (default both ``write`` and
    ``read``); ``exc``: exception factory. The failure happens *before*
    the underlying call, so a retried op is safe to repeat — the
    transient-fault model the retry layer assumes.

        sink = FlakyFile(open(p, "wb"), fail_calls={2, 3})
        sink.write(a)   # ok          (call 1)
        sink.write(b)   # OSError     (call 2)
        sink.write(b)   # OSError     (call 3)
        sink.write(b)   # ok          (call 4) -> retry succeeds
    """

    def __init__(self, f, *, fail_calls=(), fail_ops=("write", "read"),
                 exc=lambda: OSError("injected transient I/O fault")):
        self._f = f
        self._fail_calls = set(int(c) for c in fail_calls)
        self._fail_ops = tuple(fail_ops)
        self._exc = exc
        self.calls = 0
        self.faults = 0

    def _gate(self, op: str):
        if op in self._fail_ops:
            self.calls += 1
            if self.calls in self._fail_calls:
                self.faults += 1
                raise self._exc()

    def write(self, b):
        self._gate("write")
        return self._f.write(b)

    def read(self, *a):
        self._gate("read")
        return self._f.read(*a)

    def flush(self):
        self._gate("flush")
        if hasattr(self._f, "flush"):
            return self._f.flush()

    def __getattr__(self, name):
        return getattr(self._f, name)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        if hasattr(self._f, "close"):
            self._f.close()
