"""Synthetic scientific fields for the compressor benchmarks (6 families).

Real SDRBench files are not redistributable in this container; these
generators produce spectrally-shaped random fields whose roughness/
anisotropy mimics each dataset family (benchmarks accept --data-dir to use
real files instead). Spectral synthesis: white noise filtered by a
power-law |k|^-alpha spectrum; higher alpha -> smoother (more compressible).
"""
from __future__ import annotations

import numpy as np


def _spectral_field(shape, alpha, seed, aniso=None):
    rng = np.random.default_rng(seed)
    white = rng.standard_normal(shape).astype(np.float32)
    F = np.fft.rfftn(white)
    ks = np.meshgrid(*[np.fft.fftfreq(n) for n in shape[:-1]] + [np.fft.rfftfreq(shape[-1])], indexing="ij")
    if aniso is None:
        aniso = (1.0,) * len(shape)
    k2 = sum((a * k) ** 2 for a, k in zip(aniso, ks))
    filt = (k2 + 1e-6) ** (-alpha / 2.0)
    filt.flat[0] = 0.0
    out = np.fft.irfftn(F * filt, s=shape).astype(np.float32)
    out /= max(np.abs(out).max(), 1e-12)
    return out


DATASETS = {
    # name: (shape, generator)
    "cesm": ((1800, 3600), lambda s: _spectral_field((1800, 3600), 2.2, s, aniso=(1.0, 1.0))),
    "jhtdb": ((256, 256, 256), lambda s: _spectral_field((256, 256, 256), 1.9, s)),          # turbulence: ~k^-5/3 energy
    "miranda": ((256, 384, 384), lambda s: np.tanh(4 * _spectral_field((256, 384, 384), 2.6, s))),  # sharp hydro interfaces
    "nyx": ((256, 256, 256), lambda s: np.exp(2.0 * _spectral_field((256, 256, 256), 2.0, s))),     # lognormal density
    "qmcpack": ((64, 115, 69, 69), lambda s: _spectral_field((64, 115, 69, 69), 1.6, s)),
    "rtm": ((256, 256, 235), lambda s: _spectral_field((256, 256, 235), 2.4, s, aniso=(2.0, 1.0, 1.0))),
}


def get_field(name: str, seed: int = 0) -> np.ndarray:
    shape, gen = DATASETS[name]
    return gen(seed)


def load_or_generate(name: str, data_dir: str | None = None, seed: int = 0) -> np.ndarray:
    if data_dir:
        import pathlib

        for f in sorted(pathlib.Path(data_dir).glob(f"{name}*")):
            if f.suffix in (".f32", ".dat", ".bin"):
                return np.fromfile(f, np.float32).reshape(DATASETS[name][0])
    return get_field(name, seed)


def predictor_suite(side: int = 48) -> dict:
    """Synthetic field suite for the predictor-autotuning dimension: one
    stream class per regime a spline/scheme/stride choice discriminates
    (smooth spectra, exact ramps, axis anisotropy, additive noise,
    sparse impulses). Shared by benchmarks.bench_lossless and the
    auto-vs-fixed CR-floor tests so the gate always matches the
    published suite."""
    rng = np.random.default_rng(11)
    g = np.stack(np.meshgrid(*[np.linspace(0, 1, side)] * 3, indexing="ij"))
    smooth = (np.sin(g[0] * 6.3) * np.cos(g[1] * 5.1) + 0.5 * np.sin(g[2] * 9.9 + g[0] * 3)).astype(np.float32)
    return {
        "smooth": smooth,
        "ramp": (2.0 * g[0] - 0.7 * g[1] + 0.3 * g[2]).astype(np.float32),
        "aniso": (np.sin(g[0] * 40.0) + 0.01 * g[1] + 0.01 * g[2]).astype(np.float32),
        "noisy": (smooth + 0.05 * rng.standard_normal((side,) * 3)).astype(np.float32),
        "sparse": np.where(rng.random((side,) * 3) < 0.01, rng.standard_normal((side,) * 3), 0.0).astype(np.float32),
    }
