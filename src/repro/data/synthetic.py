"""Synthetic token pipeline: sharded, deterministic, prefetching.

Per-host iterator yielding numpy batches; in a multi-host deployment each
host draws its own shard (seeded by host id) and device_put's onto its
addressable slice of the batch sharding — here single-host, same code path.
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class TokenPipeline:
    """Deterministic zipfian token stream with doc boundaries (resumable)."""

    def __init__(self, vocab: int, batch: int, seq: int, *, seed: int = 0, start_step: int = 0, extras: dict | None = None):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self.step = start_step
        self.extras = extras or {}

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        self.step += 1
        # zipf-ish marginal so losses have structure to learn
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = np.minimum(z, self.vocab - 1).astype(np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        for k, shape in self.extras.items():
            batch[k] = rng.standard_normal((self.batch,) + shape).astype(np.float32)
        return batch


class Prefetcher:
    """Background-thread prefetch (depth-bounded) over any iterator."""

    def __init__(self, it, depth: int = 2):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        t = threading.Thread(target=self._fill, daemon=True)
        t.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
