from .fields import DATASETS, get_field, load_or_generate, predictor_suite  # noqa: F401
from .realfields import REAL_FIELDS, load_real_fields, real_suite, save_real_fields  # noqa: F401
from .synthetic import Prefetcher, TokenPipeline  # noqa: F401
