from .fields import DATASETS, get_field, load_or_generate, predictor_suite  # noqa: F401
from .synthetic import Prefetcher, TokenPipeline  # noqa: F401
