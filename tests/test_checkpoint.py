"""Checkpoint manager: atomicity, lossless/lossy modes, async, restore."""
import pathlib

import jax
import numpy as np
import pytest

from repro import checkpoint as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((64, 128)).astype(np.float32),
        "b": rng.standard_normal((128,)).astype(np.float32),
        "step": np.int32(7),
        "nested": {"m": rng.standard_normal((4096, 32)).astype(np.float32)},
    }


def test_save_restore_lossless(tmp_path):
    tree = _tree()
    ckpt.save(tree, tmp_path, 10)
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype), tree)
    out, manifest = ckpt.restore(shapes, tmp_path, 10)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert manifest["step"] == 10


def test_save_restore_error_bounded(tmp_path):
    tree = {"w": np.random.default_rng(1).standard_normal((256, 256)).astype(np.float32)}
    ckpt.save(tree, tmp_path, 1, eb=1e-3)
    out, manifest = ckpt.restore(tree, tmp_path, 1)
    rng = tree["w"].max() - tree["w"].min()
    assert np.abs(out["w"] - tree["w"]).max() <= 1e-3 * rng * (1 + 1e-5)
    assert manifest["cr"] > 1.0


def test_latest_and_multiple_steps(tmp_path):
    tree = _tree()
    for s in (5, 20, 15):
        ckpt.save(tree, tmp_path, s)
    assert ckpt.latest_step(tmp_path) == 20


def test_no_partial_checkpoint_visible(tmp_path):
    """A tmp dir left behind by a crash must not count as a checkpoint."""
    tree = _tree()
    ckpt.save(tree, tmp_path, 1)
    fake_tmp = pathlib.Path(tmp_path) / ".tmp_step_00000099"
    fake_tmp.mkdir()
    (fake_tmp / "x.bin").write_bytes(b"garbage")
    assert ckpt.latest_step(tmp_path) == 1


def test_manifest_corruption_detected(tmp_path):
    tree = _tree()
    ckpt.save(tree, tmp_path, 3)
    d = pathlib.Path(tmp_path) / "step_00000003"
    (d / "manifest.json").write_text("{broken")
    with pytest.raises(Exception):
        ckpt.restore(tree, tmp_path, 3)


def test_async_checkpointer(tmp_path):
    saver = ckpt.AsyncCheckpointer(tmp_path)
    tree = _tree()
    for s in (1, 2, 3):
        saver.submit(tree, s)
    saver.close()
    assert ckpt.latest_step(tmp_path) in (1, 2, 3)  # at least one published
    out, _ = ckpt.restore(tree, tmp_path)
    assert np.array_equal(out["w"], tree["w"])
