"""Checkpoint manager: atomicity, lossless/lossy modes, async, restore."""
import pathlib

import jax
import numpy as np
import pytest

from repro import checkpoint as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((64, 128)).astype(np.float32),
        "b": rng.standard_normal((128,)).astype(np.float32),
        "step": np.int32(7),
        "nested": {"m": rng.standard_normal((4096, 32)).astype(np.float32)},
    }


def test_save_restore_lossless(tmp_path):
    tree = _tree()
    ckpt.save(tree, tmp_path, 10)
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype), tree)
    out, manifest = ckpt.restore(shapes, tmp_path, 10)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert manifest["step"] == 10


def test_save_restore_error_bounded(tmp_path):
    tree = {"w": np.random.default_rng(1).standard_normal((256, 256)).astype(np.float32)}
    ckpt.save(tree, tmp_path, 1, eb=1e-3)
    out, manifest = ckpt.restore(tree, tmp_path, 1)
    rng = tree["w"].max() - tree["w"].min()
    assert np.abs(out["w"] - tree["w"]).max() <= 1e-3 * rng * (1 + 1e-5)
    assert manifest["cr"] > 1.0


def test_latest_and_multiple_steps(tmp_path):
    tree = _tree()
    for s in (5, 20, 15):
        ckpt.save(tree, tmp_path, s)
    assert ckpt.latest_step(tmp_path) == 20


def test_no_partial_checkpoint_visible(tmp_path):
    """A tmp dir left behind by a crash must not count as a checkpoint."""
    tree = _tree()
    ckpt.save(tree, tmp_path, 1)
    fake_tmp = pathlib.Path(tmp_path) / ".tmp_step_00000099"
    fake_tmp.mkdir()
    (fake_tmp / "x.bin").write_bytes(b"garbage")
    assert ckpt.latest_step(tmp_path) == 1


def test_manifest_corruption_detected(tmp_path):
    tree = _tree()
    ckpt.save(tree, tmp_path, 3)
    d = pathlib.Path(tmp_path) / "step_00000003"
    (d / "manifest.json").write_text("{broken")
    with pytest.raises(Exception):
        ckpt.restore(tree, tmp_path, 3)


def test_async_checkpointer(tmp_path):
    saver = ckpt.AsyncCheckpointer(tmp_path)
    tree = _tree()
    for s in (1, 2, 3):
        saver.submit(tree, s)
    saver.close()
    assert ckpt.latest_step(tmp_path) in (1, 2, 3)  # at least one published
    out, _ = ckpt.restore(tree, tmp_path)
    assert np.array_equal(out["w"], tree["w"])


# ----------------------------------------------------- fault tolerance


def test_manifest_records_per_leaf_crc32(tmp_path):
    import zlib

    tree = _tree()
    manifest = ckpt.save(tree, tmp_path, 1, eb=1e-3)
    assert manifest["format"] == 2
    d = pathlib.Path(tmp_path) / "step_00000001"
    for key, meta in manifest["leaves"].items():
        payload = (d / meta["file"]).read_bytes()
        assert meta["crc32"] == (zlib.crc32(payload) & 0xFFFFFFFF), key


def _flip(path: pathlib.Path, offset: int = None, bit: int = 6):
    b = bytearray(path.read_bytes())
    i = len(b) // 2 if offset is None else offset
    b[i] ^= 1 << bit
    path.write_bytes(bytes(b))


def test_strict_restore_raises_on_corrupt_leaf(tmp_path):
    from repro.core import CheckpointDamageError

    tree = _tree()
    m = ckpt.save(tree, tmp_path, 1, eb=1e-3)
    _flip(pathlib.Path(tmp_path) / "step_00000001" / m["leaves"]["w"]["file"])
    with pytest.raises(CheckpointDamageError):
        ckpt.restore(tree, tmp_path, 1)


def test_degraded_restore_falls_back_to_previous_step(tmp_path):
    tree = _tree()
    ckpt.save(tree, tmp_path, 1, eb=1e-3)
    m2 = ckpt.save(tree, tmp_path, 2, eb=1e-3)
    _flip(pathlib.Path(tmp_path) / "step_00000002" / m2["leaves"]["w"]["file"])
    out, manifest = ckpt.restore(tree, tmp_path, 2, strict=False)
    sal = manifest["salvage"]
    assert list(sal["damaged"]) == ["w"] and sal["fallback_steps"]["w"] == 1 and not sal["lost"]
    ref, _ = ckpt.restore(tree, tmp_path, 1)  # fallback leaf == step-1 decode
    assert np.array_equal(np.asarray(out["w"]), np.asarray(ref["w"]))
    # undamaged leaves still come from step 2
    assert np.array_equal(np.asarray(out["b"]), np.asarray(tree["b"]))


def test_degraded_restore_lost_leaf_zero_filled(tmp_path):
    tree = _tree()
    m = ckpt.save(tree, tmp_path, 1, eb=1e-3)  # only step: nothing to fall back to
    _flip(pathlib.Path(tmp_path) / "step_00000001" / m["leaves"]["w"]["file"])
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype), tree)
    out, manifest = ckpt.restore(shapes, tmp_path, 1, strict=False)
    assert manifest["salvage"]["lost"] == ["w"]
    assert not np.asarray(out["w"]).any() and np.asarray(out["w"]).shape == tree["w"].shape


def test_degraded_restore_survives_missing_manifest(tmp_path):
    tree = _tree()
    ckpt.save(tree, tmp_path, 1)
    ckpt.save(tree, tmp_path, 2)
    (pathlib.Path(tmp_path) / "step_00000002" / "manifest.json").unlink()
    out, manifest = ckpt.restore(tree, tmp_path, 2, strict=False)
    assert manifest["step"] == 1
    assert manifest["salvage"]["fallback_steps"]["<manifest>"] == 1
    assert np.array_equal(np.asarray(out["w"]), tree["w"])


def test_format1_checkpoints_still_restore(tmp_path):
    """Manifests without per-leaf crc32 (format 1) restore unchanged."""
    import json

    tree = _tree()
    ckpt.save(tree, tmp_path, 1)
    mp = pathlib.Path(tmp_path) / "step_00000001" / "manifest.json"
    manifest = json.loads(mp.read_text())
    manifest["format"] = 1
    for meta in manifest["leaves"].values():
        meta.pop("crc32", None)
    mp.write_text(json.dumps(manifest))
    out, _ = ckpt.restore(tree, tmp_path, 1)
    assert np.array_equal(np.asarray(out["w"]), tree["w"])


def test_stale_tmp_dirs_swept_on_next_save(tmp_path):
    tree = _tree()
    stale = pathlib.Path(tmp_path) / ".tmp_step_00000007_deadbeef"
    stale.mkdir(parents=True)
    (stale / "w.bin").write_bytes(b"orphaned by a killed process")
    ckpt.save(tree, tmp_path, 8)
    assert not stale.exists()
    assert ckpt.latest_step(tmp_path) == 8


def test_failed_save_does_not_leak_tmp_dir(tmp_path, monkeypatch):
    from repro.checkpoint import manager

    tree = _tree()

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(manager, "encode_tensor_to", boom)
    with pytest.raises(OSError):
        ckpt.save(tree, tmp_path, 1)
    assert not list(pathlib.Path(tmp_path).glob(".tmp_step_*"))


def test_async_submit_is_race_safe(tmp_path):
    import threading

    saver = ckpt.AsyncCheckpointer(tmp_path)
    tree = _tree()
    errs = []

    def hammer(base):
        try:
            for i in range(25):
                saver.submit(tree, base + i)
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=hammer, args=(100 * (k + 1),)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    saver.wait()
    saver.close()
    assert not errs and ckpt.latest_step(tmp_path) is not None


def test_async_close_idempotent_and_rejects_late_submit(tmp_path):
    saver = ckpt.AsyncCheckpointer(tmp_path)
    saver.submit(_tree(), 1)
    saver.close()
    saver.close()  # no-op, no deadlock
    with pytest.raises(RuntimeError):
        saver.submit(_tree(), 2)


def test_async_close_surfaces_join_timeout(tmp_path, monkeypatch):
    import threading
    import time

    from repro.checkpoint import manager

    release = threading.Event()
    real_save = manager.save

    def slow_save(*a, **kw):
        release.wait(10)
        return real_save(*a, **kw)

    monkeypatch.setattr(manager, "save", slow_save)
    saver = ckpt.AsyncCheckpointer(tmp_path)
    try:
        saver.submit(_tree(), 1)
        time.sleep(0.05)  # let the worker enter the slow save
        with pytest.raises(TimeoutError):
            saver.close(timeout=0.2)
    finally:
        release.set()
        saver._thread.join(15)


def test_async_save_retries_transient_oserror(tmp_path, monkeypatch):
    from repro.checkpoint import manager

    real_save = manager.save
    attempts = {"n": 0}

    def flaky_save(*a, **kw):
        attempts["n"] += 1
        if attempts["n"] == 1:
            raise OSError("NFS blip")
        return real_save(*a, **kw)

    monkeypatch.setattr(manager, "save", flaky_save)
    saver = ckpt.AsyncCheckpointer(tmp_path)
    saver.submit(_tree(), 1)
    saver.wait()  # no exception: the retry absorbed the fault
    saver.close()
    assert attempts["n"] == 2 and ckpt.latest_step(tmp_path) == 1
