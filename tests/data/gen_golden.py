"""Regenerate the golden container fixtures (run from the repo root):

    PYTHONPATH=src python tests/data/gen_golden.py

Commits one small ragged 3-D field plus the same compression in every
container generation — v1 (JSON header + JSON-meta lossless stream), v2
(binary header + section table), v3 (chunked frames) — and the decoded
array. tests/test_compressor_roundtrip.py decodes the committed blobs
byte-for-byte, so a container-format regression (not just an in-process
round-trip asymmetry) fails loudly.

Only regenerate when the container format changes *intentionally*; the
fixtures are the compatibility contract for already-written archives.

Alongside the intact containers, three *corrupt* v3 fixtures are derived
deterministically from golden_v3.bin through :mod:`repro.testing.faults`
— a mid-payload bit flip in frame 1, a hard truncation, and a torn tail
(truncate + garbage) — so the salvage decoder's behaviour on damaged
archives is pinned byte-for-byte too, not just exercised on fresh
in-process corruption.
"""
import pathlib

import numpy as np

from repro.core import Compressor, CompressorSpec, chunk_compress
from repro.core.compressor import _sections_pack_v1, _sections_unpack
from repro.core.frames import frame_table
from repro.core.lossless import pipelines as pp
from repro.testing import bit_flip, torn_tail, truncate_fraction

HERE = pathlib.Path(__file__).parent
SPEC = CompressorSpec(eb=1e-2, pipeline="cr", autotune=False)


def golden_field() -> np.ndarray:
    rng = np.random.default_rng(20260731)
    g = np.linspace(0, 2 * np.pi, 28)
    X, Y, Z = np.meshgrid(g[:20], g[:24], g, indexing="ij")
    return (np.sin(2 * X) * np.cos(Y) + 0.3 * np.sin(3 * Z)
            + 0.02 * rng.standard_normal((20, 24, 28))).astype(np.float32)


def main():
    x = golden_field()
    comp = Compressor(SPEC)
    v2 = comp.compress(x)
    header, sections = _sections_unpack(v2)
    codes = pp.decode(sections[0])
    v1_header = {k: v for k, v in header.items() if k != "pipeline"}
    v1 = _sections_pack_v1(v1_header, [pp.encode_v1(codes, "cr")] + list(sections[1:]))
    v3 = chunk_compress(x, n_chunks=4, spec=SPEC)
    decoded = comp.decompress(v2)
    assert np.array_equal(comp.decompress(v1), decoded)
    # v3 chunks compress independently (per-chunk eb + padding), so the
    # reconstruction is its own golden — still within the error bound
    decoded_v3 = comp.decompress(v3)
    eb_abs = 1e-2 * float(x.max() - x.min())
    assert float(np.abs(decoded_v3 - x).max()) <= eb_abs * (1 + 1e-5)
    np.save(HERE / "golden_field.npy", x)
    np.save(HERE / "golden_decoded.npy", decoded)
    np.save(HERE / "golden_decoded_v3.npy", decoded_v3)
    (HERE / "golden_v1.bin").write_bytes(v1)
    (HERE / "golden_v2.bin").write_bytes(v2)
    (HERE / "golden_v3.bin").write_bytes(v3)
    # corrupt derivatives: deterministic damage, pinned salvage behaviour
    _, table = frame_table(v3)
    off1, size1, _ = table[1]
    (HERE / "golden_v3_bitflip.bin").write_bytes(bit_flip(v3, off1 + size1 // 2, bit=3))
    # cut inside frame 2's payload: frames 0-1 stay intact
    (HERE / "golden_v3_trunc.bin").write_bytes(truncate_fraction(v3, (table[2][0] + 16) / len(v3)))
    (HERE / "golden_v3_torn.bin").write_bytes(
        torn_tail(v3, (table[3][0] + 8) / len(v3), garbage=96, seed=20260808))
    for f in sorted(HERE.glob("golden_*")):
        print(f.name, f.stat().st_size, "bytes")


if __name__ == "__main__":
    main()
