"""Device decode engine: bit-identity with the numpy reference stages.

PR 5's engine contract covered the encode direction; these tests pin the
symmetric read path: every ``decode_device`` twin reproduces the numpy
decoder's bytes exactly — per stage, per pipeline stream (v2 and legacy
v1 framing), and through the full compressor (v1/v2/v3 containers and
the committed golden fixtures) — and a device decode failure falls back
to the numpy path bit-identically, observable only in telemetry.
"""
import pathlib

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import Compressor, CompressorSpec  # noqa: E402
from repro.core.lossless import bitshuffle as bs  # noqa: E402
from repro.core.lossless import engine as eng  # noqa: E402
from repro.core.lossless import huffman as hf  # noqa: E402
from repro.core.lossless import pipelines as pp  # noqa: E402
from repro.core.lossless import rre, tcms  # noqa: E402
from repro.core.lossless.stages import get_stage, registered_stages  # noqa: E402

_GOLDEN = pathlib.Path(__file__).parent / "data"


def _streams():
    rng = np.random.default_rng(0)
    yield "random", rng.integers(0, 256, 5000, dtype=np.uint8)
    yield "skewed", np.minimum(rng.zipf(1.5, 5000), 255).astype(np.uint8)
    yield "runs", np.repeat(rng.integers(0, 4, 100, dtype=np.uint8), 57)[:5000]
    yield "zeros", np.zeros(4096, np.uint8)
    yield "tiny", np.array([128], np.uint8)
    yield "empty", np.zeros(0, np.uint8)
    yield "single-symbol", np.full(3000, 7, np.uint8)
    yield "chunk", rng.integers(0, 256, hf.CHUNK, dtype=np.uint8)
    yield "chunk-1", rng.integers(0, 256, hf.CHUNK - 1, dtype=np.uint8)
    yield "chunk+1", rng.integers(0, 256, hf.CHUNK + 1, dtype=np.uint8)
    yield "deepskew", np.clip(rng.normal(128, 2.5, 1 << 17), 0, 255).astype(np.uint8)


STREAMS = list(_streams())


# ------------------------------------------------------------ stage twins
@pytest.mark.parametrize("name,data", STREAMS)
def test_hf_decode_device_bit_identical(name, data):
    payload, hdr = hf.encode(data)
    ref = hf.decode(payload, hdr)
    got = eng.hf_decode_device(payload, hdr)
    assert np.array_equal(np.asarray(got), ref), name
    # legacy stream without the offset table: host-fallback, same bytes
    legacy = {k: v for k, v in hdr.items() if k != "offs"}
    got = eng.hf_decode_device(payload, legacy)
    assert np.array_equal(np.asarray(got), ref), name


def test_hf_offset_table_matches_device_encoder():
    """Both encoders must emit the identical versioned header (the engine
    contract extends to the "offs" extension: header dict equality)."""
    rng = np.random.default_rng(5)
    data = np.clip(np.round(rng.laplace(128, 6, 3 * hf.CHUNK + 100)), 0, 255).astype(np.uint8)
    _, hdr = hf.encode(data)
    _, hdev = eng.hf_encode_device(jnp.asarray(data))
    assert "offs" in hdr and hdev == hdr


def test_hf_header_pack_roundtrip_versioned_and_legacy():
    st = get_stage("hf")
    rng = np.random.default_rng(6)
    data = np.clip(np.round(rng.laplace(128, 4, 2 * hf.CHUNK + 7)), 0, 255).astype(np.uint8)
    _, hdr = hf.encode(data)
    assert st.unpack_header(st.pack_header(hdr)) == hdr
    # the bare 8-byte form predates the table and must keep parsing
    import struct

    assert st.unpack_header(struct.pack("<Q", 12345)) == {"n": 12345}
    legacy = {"n": hdr["n"]}
    assert len(st.pack_header(legacy)) == 8
    assert st.unpack_header(st.pack_header(legacy)) == legacy


@pytest.mark.parametrize("k", [1, 4, 8])
@pytest.mark.parametrize("name,data", STREAMS)
def test_rre_rze_decode_device_bit_identical(k, name, data):
    payload, hdr = rre.rre_encode(data, k)
    ref = rre.rre_decode(payload, hdr)
    assert np.array_equal(np.asarray(eng.rre_decode_device(payload, hdr)), ref), name
    payload, hdr = rre.rze_encode(data, k)
    ref = rre.rze_decode(payload, hdr)
    assert np.array_equal(np.asarray(eng.rze_decode_device(payload, hdr)), ref), name


@pytest.mark.parametrize("k", [1, 2, 4, 8])
@pytest.mark.parametrize("name,data", STREAMS)
def test_tcms_decode_device_bit_identical(k, name, data):
    payload, hdr = tcms.tcms_encode(data, k)
    ref = tcms.tcms_decode(payload, hdr)
    assert np.array_equal(np.asarray(eng.tcms_decode_device(payload, hdr)), ref), name


@pytest.mark.parametrize("name,data", STREAMS)
def test_bit1_decode_device_bit_identical(name, data):
    payload, hdr = bs.bitshuffle_encode(data)
    ref = bs.bitshuffle_decode(payload, hdr)
    assert np.array_equal(np.asarray(eng.bit1_decode_device(payload, hdr)), ref), name


@pytest.mark.parametrize("name,data", STREAMS)
def test_encode_device_decode_device_roundtrip(name, data):
    """Full device roundtrip, device payload in, device stream out: the
    decode twin accepts the encode twin's device array directly."""
    d = jnp.asarray(data)
    payload, hdr = eng.hf_encode_device(d)
    assert np.array_equal(np.asarray(eng.hf_decode_device(payload, hdr)), data), name
    payload, hdr = eng.rre_encode_device(d, 4)
    assert np.array_equal(np.asarray(eng.rre_decode_device(payload, hdr)), data), name
    payload, hdr = eng.tcms_encode_device(d, 8)
    assert np.array_equal(np.asarray(eng.tcms_decode_device(payload, hdr)), data), name
    payload, hdr = eng.bit1_encode_device(d)
    assert np.array_equal(np.asarray(eng.bit1_decode_device(payload, hdr)), data), name


def test_hf_decode_device_fuzz():
    """Random multi-chunk streams across symbol laws: the device decoder's
    per-chunk parallel entry points must agree with the sequential
    reference at every chunk seam."""
    rng = np.random.default_rng(9)
    for t in range(40):
        n = int(rng.integers(1, 6 * hf.CHUNK))
        data = np.clip(
            np.round(rng.laplace(rng.integers(0, 256), rng.choice([0.5, 2.0, 8.0, 40.0]), n)),
            0, 255,
        ).astype(np.uint8)
        payload, hdr = hf.encode(data)
        assert np.array_equal(np.asarray(eng.hf_decode_device(payload, hdr)), data), (t, n)


def test_every_builtin_stage_has_decode_twin_except_zstd():
    for name, st in registered_stages().items():
        if name == "zstd":
            assert st.decode_device is None
        else:
            assert st.decode_device is not None, name


# ------------------------------------------------------- pipeline streams
@pytest.mark.parametrize("pipe", sorted(pp.registered_pipelines()))
@pytest.mark.parametrize("name,data", STREAMS[:6])
def test_pipeline_device_decode_bit_identical(pipe, name, data):
    buf = pp.encode(data, pipe)
    out = pp.decode(buf, device=True)
    assert not isinstance(out, np.ndarray)  # device-resident result
    assert np.array_equal(np.asarray(out), data), (pipe, name)


@pytest.mark.parametrize("pipe", ["cr", "tp", "fzh"])
def test_pipeline_device_decode_legacy_v1_stream(pipe):
    """Pre-registry JSON streams lack binary header extensions: the device
    path decodes them through the host reference stages, then uploads."""
    rng = np.random.default_rng(2)
    data = np.clip(np.round(rng.laplace(128, 5, 40_000)), 0, 255).astype(np.uint8)
    buf = pp.encode_v1(data, pipe)
    assert np.array_equal(pp.decode(buf), data)
    assert np.array_equal(np.asarray(pp.decode(buf, device=True)), data)


def test_pipeline_decode_accepts_memoryview_and_ndarray():
    rng = np.random.default_rng(3)
    data = np.clip(np.round(rng.laplace(128, 5, 30_000)), 0, 255).astype(np.uint8)
    buf = pp.encode(data, "cr")
    for view in (memoryview(buf), bytearray(buf), np.frombuffer(buf, np.uint8)):
        assert np.array_equal(pp.decode(view), data), type(view).__name__
    assert np.array_equal(np.asarray(pp.decode(memoryview(buf), device=True)), data)


# ----------------------------------------------------------- compressor
def test_compressor_decode_engines_bit_identical(smooth3d):
    for predictor in ("interp", "lorenzo"):
        spec = CompressorSpec(eb=1e-3, pipeline="cr", autotune=False, predictor=predictor)
        buf = Compressor(spec).compress(smooth3d)
        ref = Compressor(spec).decompress(buf)
        dev = Compressor(CompressorSpec(eb=1e-3, pipeline="cr", autotune=False,
                                        predictor=predictor, engine="device"))
        got = dev.decompress(buf)
        assert isinstance(got, np.ndarray) and np.array_equal(got, ref), predictor
        assert dev.last_telemetry["fallbacks"] == [], predictor


def test_compressor_out_device_returns_device_array(smooth3d):
    comp = Compressor(CompressorSpec(eb=1e-3, pipeline="cr", autotune=False))
    buf = comp.compress(smooth3d)
    ref = comp.decompress(buf)
    got = comp.decompress(buf, out="device")
    assert not isinstance(got, np.ndarray)
    assert np.array_equal(np.asarray(got), ref)
    # engine="numpy" still honours out= (host decode, then upload)
    host = Compressor(CompressorSpec(eb=1e-3, pipeline="cr", autotune=False, engine="numpy"))
    got = host.decompress(buf, out="device")
    assert not isinstance(got, np.ndarray)
    assert np.array_equal(np.asarray(got), ref)
    assert host.last_telemetry["decode"]["engine"] == "numpy"
    with pytest.raises(ValueError, match="out must be"):
        comp.decompress(buf, out="tpu")


def test_decode_telemetry_recorded(smooth3d):
    comp = Compressor(CompressorSpec(eb=1e-3, pipeline="cr", autotune=False))
    buf = comp.compress(smooth3d)
    comp.decompress(buf)
    td = comp.last_telemetry["decode"]
    assert td["engine"] == "numpy" and td["out"] == "numpy"
    assert td["mbps"] > 0 and td["seconds"] > 0 and td["bytes"] == smooth3d.nbytes
    comp.decompress(buf, out="device")
    td = comp.last_telemetry["decode"]
    assert td["engine"] == "device" and td["out"] == "device"


@pytest.mark.parametrize("version", [1, 2, 3])
def test_golden_containers_decode_device_byte_for_byte(version):
    """The committed cross-version blobs must decode identically through
    the device engine — fallbacks allowed (v1 streams host-decode), byte
    differences not."""
    blob = (_GOLDEN / f"golden_v{version}.bin").read_bytes()
    expected = np.load(_GOLDEN / ("golden_decoded_v3.npy" if version == 3 else "golden_decoded.npy"))
    comp = Compressor(CompressorSpec(eb=1e-2, pipeline="cr", autotune=False, engine="device"))
    out = comp.decompress(blob)
    assert out.dtype == np.float32 and np.array_equal(out, expected)
    out = comp.decompress(blob, out="device")
    assert np.array_equal(np.asarray(out), expected)


def test_v3_device_decode_and_frame_selection(smooth3d):
    from repro.core.distributed import chunk_compress, shard_decompress

    x = np.stack([smooth3d * (1 + 0.1 * i) for i in range(3)]).astype(np.float32)
    spec = CompressorSpec(eb=1e-3, pipeline="cr", autotune=False)
    buf = chunk_compress(x, n_chunks=3, spec=spec)
    comp = Compressor(spec)
    ref = comp.decompress(buf)
    got = comp.decompress(buf, out="device")
    assert not isinstance(got, np.ndarray) and np.array_equal(np.asarray(got), ref)
    sub = comp.decompress(buf, frames=[2, 0], out="device")
    assert np.array_equal(np.asarray(sub), np.concatenate([ref[2:3], ref[0:1]]))
    # parallel frame decode straight onto device
    for workers in (1, 2):
        sd = shard_decompress(buf, workers=workers, out="device")
        assert not isinstance(sd, np.ndarray) and np.array_equal(np.asarray(sd), ref)


def test_device_decode_failure_falls_back_bit_identical(smooth3d, monkeypatch):
    """Chaos: a device decode fault must not change the output bytes —
    the numpy fallback engages and the ladder records it."""
    spec = CompressorSpec(eb=1e-3, pipeline="cr", autotune=False, engine="device")
    buf = Compressor(spec).compress(smooth3d)
    ref = Compressor(spec).decompress(buf)

    real_decode = pp.decode

    def sabotaged(buf_, device=False):
        if device:
            raise RuntimeError("injected device decode fault")
        return real_decode(buf_)

    monkeypatch.setattr(pp, "decode", sabotaged)
    # compressor.py binds `pipelines` as a module, so patching pp.decode
    # is visible at the call site
    comp = Compressor(spec)
    out = comp.decompress(buf)
    assert np.array_equal(out, ref)
    fbs = [f for f in comp.last_telemetry["fallbacks"] if f["point"] == "decode"]
    assert fbs and fbs[0]["from"] == "device" and fbs[0]["to"] == "numpy"
    assert "injected" in fbs[0]["error"]


def test_decode_workers_env_override(monkeypatch):
    from repro.core import distributed as dist

    monkeypatch.setenv("REPRO_DECODE_WORKERS", "3")
    assert dist._decode_workers() == 3
    monkeypatch.setenv("REPRO_DECODE_WORKERS", "not-a-number")
    assert dist._decode_workers() == 1
    monkeypatch.setenv("REPRO_DECODE_WORKERS", "-2")
    assert dist._decode_workers() == 1
    monkeypatch.delenv("REPRO_DECODE_WORKERS")
    assert dist._decode_workers() == 1


def test_shard_decompress_default_workers_from_env(smooth3d, monkeypatch):
    from repro.core.distributed import chunk_compress, shard_decompress

    x = np.stack([smooth3d, smooth3d * 1.1]).astype(np.float32)
    spec = CompressorSpec(eb=1e-3, pipeline="cr", autotune=False)
    buf = chunk_compress(x, n_chunks=2, spec=spec)
    ref = shard_decompress(buf, workers=1)
    monkeypatch.setenv("REPRO_DECODE_WORKERS", "2")
    assert np.array_equal(shard_decompress(buf), ref)  # workers=None -> env


def test_frame_reader_zero_copy_memoryview(smooth3d):
    """read_frame hands payloads through as CRC-checked memoryviews; the
    decode stack accepts them without an owning copy."""
    import repro.core.frames as fr
    from repro.core.distributed import chunk_compress

    spec = CompressorSpec(eb=1e-3, pipeline="cr", autotune=False)
    x = np.stack([smooth3d, smooth3d * 1.05]).astype(np.float32)
    buf = chunk_compress(x, n_chunks=2, spec=spec)
    header, table = fr.frame_table(buf)
    frame = fr.read_frame(buf, table[0])
    assert isinstance(frame, memoryview)
    comp = Compressor(spec)
    part = comp.decompress(frame)
    assert part.shape[0] == header["chunk_sizes"][0]
