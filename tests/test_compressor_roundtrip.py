"""End-to-end compressor behaviour: the paper's error-bound contract (Eq. 1)."""
import pathlib

import numpy as np
import pytest

from repro.core import (
    Compressor,
    CompressorSpec,
    compression_ratio,
    cusz_hi_cr,
    cusz_hi_tp,
    cusz_i,
    cusz_l,
    cuszp2_like,
    fzgpu_like,
    max_abs_err,
)

PRESETS = {
    "hi-cr": cusz_hi_cr,
    "hi-tp": cusz_hi_tp,
    "cusz-l": cusz_l,
    "cusz-i": cusz_i,
    "cuszp2": cuszp2_like,
    "fzgpu": fzgpu_like,
}


@pytest.mark.parametrize("preset", list(PRESETS))
@pytest.mark.parametrize("eb", [1e-2, 1e-3])
def test_error_bound_3d(preset, eb, smooth3d):
    c = PRESETS[preset](eb=eb)
    buf = c.compress(smooth3d)
    out = c.decompress(buf)
    rng = float(smooth3d.max() - smooth3d.min())
    assert out.shape == smooth3d.shape and out.dtype == np.float32
    assert max_abs_err(smooth3d, out) <= eb * rng * (1 + 1e-5) + 1e-9
    assert compression_ratio(smooth3d, buf) > 1.0


def test_error_bound_2d(smooth2d):
    for mk in (cusz_hi_cr, cusz_hi_tp, cusz_l):
        c = mk(eb=1e-3)
        out = c.decompress(c.compress(smooth2d))
        rng = float(smooth2d.max() - smooth2d.min())
        assert max_abs_err(smooth2d, out) <= 1e-3 * rng * (1 + 1e-5)


def test_4d_batched():
    x = np.random.default_rng(0).standard_normal((3, 24, 20, 28)).astype(np.float32)
    c = cusz_hi_tp(eb=1e-2)
    out = c.decompress(c.compress(x))
    rng = float(x.max() - x.min())
    assert out.shape == x.shape
    assert max_abs_err(x, out) <= 1e-2 * rng * (1 + 1e-5)


def test_constant_field():
    x = np.full((32, 32, 32), 3.25, np.float32)
    c = cusz_hi_cr(eb=1e-3)
    buf = c.compress(x)
    assert np.array_equal(c.decompress(buf), x)
    assert len(buf) < 1024


def test_abs_eb_mode():
    x = np.random.default_rng(1).standard_normal((40, 40)).astype(np.float32) * 100
    c = Compressor(CompressorSpec(eb=0.5, eb_mode="abs", pipeline="tp"))
    out = c.decompress(c.compress(x))
    assert max_abs_err(x, out) <= 0.5 * (1 + 1e-5)


def test_ragged_shapes():
    x = np.random.default_rng(2).standard_normal((19, 35, 50)).astype(np.float32)
    c = cusz_hi_cr(eb=1e-2)
    out = c.decompress(c.compress(x))
    assert out.shape == x.shape
    rng = float(x.max() - x.min())
    assert max_abs_err(x, out) <= 1e-2 * rng * (1 + 1e-5)


_GOLDEN = pathlib.Path(__file__).parent / "data"


@pytest.mark.parametrize("version", [1, 2, 3])
def test_golden_containers_decode_byte_for_byte(version):
    """Cross-version compat against *committed* blobs (tests/data, written by
    gen_golden.py): every container generation must keep decoding archives
    byte-for-byte, not merely round-trip in-process."""
    blob = (_GOLDEN / f"golden_v{version}.bin").read_bytes()
    expected = np.load(_GOLDEN / ("golden_decoded_v3.npy" if version == 3 else "golden_decoded.npy"))
    out = Compressor(CompressorSpec(eb=1e-2, pipeline="cr", autotune=False)).decompress(blob)
    assert out.dtype == np.float32 and out.shape == expected.shape
    assert np.array_equal(out, expected)


def test_golden_containers_respect_error_bound():
    x = np.load(_GOLDEN / "golden_field.npy")
    eb_abs = 1e-2 * float(x.max() - x.min())
    comp = Compressor(CompressorSpec(eb=1e-2, pipeline="cr", autotune=False))
    for version in (1, 2, 3):
        out = comp.decompress((_GOLDEN / f"golden_v{version}.bin").read_bytes())
        assert max_abs_err(x, out) <= eb_abs * (1 + 1e-5), f"v{version}"


def test_cr_ordering_on_smooth_data(smooth3d_big):
    """Paper's headline: hi modes beat the baselines on smooth fields."""
    crs = {}
    for name, mk in PRESETS.items():
        c = mk(eb=1e-3)
        crs[name] = compression_ratio(smooth3d_big, c.compress(smooth3d_big))
    assert crs["hi-cr"] > crs["cusz-i"] > crs["cuszp2"]
    assert crs["hi-tp"] > crs["cusz-l"]


def test_reorder_and_md_help(smooth3d_big):
    base = Compressor(CompressorSpec(eb=1e-3, pipeline="cr", autotune=False))
    no_re = Compressor(CompressorSpec(eb=1e-3, pipeline="cr", autotune=False, reorder=False))
    oned = Compressor(CompressorSpec(eb=1e-3, pipeline="cr", autotune=False, schemes=("1d",) * 4))
    cr = compression_ratio(smooth3d_big, base.compress(smooth3d_big))
    assert cr >= compression_ratio(smooth3d_big, no_re.compress(smooth3d_big)) * 0.98
    assert cr > compression_ratio(smooth3d_big, oned.compress(smooth3d_big))
