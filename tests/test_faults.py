"""Chaos suite: the fault injectors of :mod:`repro.testing.faults` driven
against the salvage decoder, the degraded consumers, the retry layer, and
the engine fallback ladder.

Deterministic by construction — every random choice flows from
``fault_seed()`` (env ``REPRO_FAULTS``, default 20260808), so a CI chaos
lane can pin or sweep seeds and any failure replays exactly.
"""
import io
import pathlib
import zlib

import numpy as np
import pytest

from repro.core import (
    Compressor,
    CompressorSpec,
    ContainerError,
    FrameCRCError,
    FrameReader,
    FrameWriter,
    RetryPolicy,
    RetryingWriter,
    chunk_compress,
    retry_call,
    scan_frames,
)
from repro.core import frames as fr
from repro.testing import (
    FlakyFile,
    bit_flip,
    corrupt_frame,
    drop_frame,
    fault_rng,
    fault_seed,
    torn_tail,
    truncate_fraction,
)

DATA = pathlib.Path(__file__).parent / "data"
SPEC = CompressorSpec(eb=1e-2, pipeline="cr", autotune=False)


@pytest.fixture(scope="module")
def field():
    g = np.linspace(0, 4 * np.pi, 40)
    X, Y = np.meshgrid(g, np.linspace(0, 2 * np.pi, 64), indexing="ij")
    return (np.sin(X) * np.cos(Y)).astype(np.float32)


@pytest.fixture(scope="module")
def v3(field):
    return chunk_compress(field, n_chunks=4, spec=SPEC)


@pytest.fixture(scope="module")
def v3_sync(field):
    return chunk_compress(field, n_chunks=4, spec=SPEC, sync=True)


def _chunks(field, n=4):
    bounds = np.linspace(0, field.shape[0], n + 1).astype(int)
    return [field[a:b] for a, b in zip(bounds[:-1], bounds[1:])]


# ---------------------------------------------------------------- injectors


def test_bit_flip_flips_exactly_one_bit(v3):
    bad = bit_flip(v3, 100, bit=5)
    assert len(bad) == len(v3)
    diff = [i for i, (a, b) in enumerate(zip(v3, bad)) if a != b]
    assert diff == [100] and v3[100] ^ bad[100] == 1 << 5


def test_truncate_and_torn_tail(v3):
    t = truncate_fraction(v3, 0.5)
    assert len(t) == len(v3) // 2 and t == v3[: len(t)]
    torn = torn_tail(v3, 0.5, garbage=32, seed=7)
    assert len(torn) == len(v3) // 2 + 32 and torn[: len(v3) // 2] == v3[: len(v3) // 2]
    assert torn == torn_tail(v3, 0.5, garbage=32, seed=7)  # deterministic


def test_corrupt_and_drop_frame_target_the_right_record(v3, v3_sync):
    for buf in (v3, v3_sync):
        _, table = fr.frame_table(buf)
        bad = corrupt_frame(buf, 2)
        off = table[2][0]
        assert bad[off] != buf[off] and bad[:off] == buf[:off]
        dropped = drop_frame(buf, 1)
        assert len(dropped) < len(buf)


def test_fault_seed_env(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "1234")
    assert fault_seed() == 1234
    assert fault_rng().integers(0, 1 << 30) == fault_rng().integers(0, 1 << 30)
    monkeypatch.delenv("REPRO_FAULTS")
    assert fault_seed() == 20260808


def test_flaky_file_raises_then_counts():
    sink = io.BytesIO()
    f = FlakyFile(sink, fail_calls=(2, 4))
    f.write(b"a")  # call 1: ok
    with pytest.raises(OSError):
        f.write(b"b")  # call 2: injected fault, nothing written
    f.write(b"c")
    with pytest.raises(OSError):
        f.write(b"d")
    assert sink.getvalue() == b"ac" and f.faults == 2 and f.calls == 4


# ------------------------------------------------------------- salvage scan


@pytest.mark.parametrize("sync", [False, True])
def test_scan_frames_intact(v3, v3_sync, sync):
    buf = v3_sync if sync else v3
    good, report = scan_frames(buf)
    assert [i for i, _ in good] == [0, 1, 2, 3]
    assert report.ok and report.frames_ok == 4 and report.frames_damaged == 0


@pytest.mark.parametrize("sync", [False, True])
def test_scan_frames_single_corrupt_frame_keeps_others(v3, v3_sync, sync):
    buf = v3_sync if sync else v3
    _, table = fr.frame_table(buf)
    for victim in range(4):
        good, report = scan_frames(corrupt_frame(buf, victim))
        assert [i for i, _ in good] == [i for i in range(4) if i != victim]
        assert report.frames_damaged == 1 and not report.ok
        for i, payload in good:  # survivors are byte-identical
            off, size, _ = table[i]
            assert payload == bytes(buf[off : off + size])


@pytest.mark.parametrize("sync", [False, True])
def test_scan_frames_truncation_keeps_prefix(v3, v3_sync, sync):
    buf = v3_sync if sync else v3
    _, table = fr.frame_table(buf)
    cut = table[2][0] + 16  # mid-frame-2
    good, report = scan_frames(truncate_fraction(buf, cut / len(buf)))
    assert [i for i, _ in good] == [0, 1]
    assert report.truncated


def test_scan_frames_sync_resync_after_structural_damage(v3_sync):
    """Garbage splattered over a record boundary: sync markers recover the
    following frames with their *exact* sequence numbers."""
    _, table = fr.frame_table(v3_sync)
    bad = bytearray(v3_sync)
    start = table[1][0] - 12  # wreck frame 1's prefix itself
    rng = fault_rng()
    for i in range(start, start + 24):
        bad[i] = int(rng.integers(0, 256))
    good, report = scan_frames(bytes(bad))
    assert [i for i, _ in good] == [0, 2, 3]
    assert report.frames_damaged >= 1 and report.bytes_skipped > 0


def test_frame_reader_skip_mode(v3_sync):
    bad = corrupt_frame(v3_sync, 1)
    with FrameReader(io.BytesIO(bad)) as r:
        got = dict(r.iter_frames(on_error="skip"))
        assert sorted(got) == [0, 2, 3]
        assert not r.damage.ok and r.damage.frames_damaged == 1


def test_frame_reader_raise_mode(v3):
    bad = corrupt_frame(v3, 1)
    r = FrameReader(io.BytesIO(bad))
    with pytest.raises(FrameCRCError):
        list(r)


def test_frame_writer_abort_leaves_detectable_truncation(v3):
    sink = io.BytesIO()
    with pytest.raises(RuntimeError):
        with FrameWriter(sink, {"k": 1}) as w:
            w.write_frame(b"abc")
            raise RuntimeError("encode blew up")
    with pytest.raises(ContainerError):
        fr.frame_table(sink.getvalue())  # no trailer: honestly truncated
    good, report = scan_frames(sink.getvalue())
    assert [i for i, _ in good] == [0] and report.truncated


# ------------------------------------------------------- degraded consumers


def test_degraded_decompress_skip_and_fill(field, v3):
    comp = Compressor(SPEC)
    chunks = _chunks(field)
    ref = [comp.decompress(chunk_compress(field, n_chunks=4, spec=SPEC), frames=[i])
           for i in range(4)]
    bad = corrupt_frame(v3, 2)
    with pytest.raises((FrameCRCError, ContainerError)):
        comp.decompress(bad)
    skipped = comp.decompress(bad, on_error="skip")
    assert skipped.shape[0] == field.shape[0] - chunks[2].shape[0]
    assert comp.last_damage["chunks_ok"] == [True, True, False, True]
    filled = comp.decompress(bad, on_error="fill", fill_value=-1.0)
    assert filled.shape == field.shape
    a = sum(c.shape[0] for c in chunks[:2])
    assert np.all(filled[a : a + chunks[2].shape[0]] == -1.0)
    np.testing.assert_array_equal(filled[:a], np.concatenate(ref[:2]))


def test_degraded_decompress_all_frames_lost_raises(v3):
    comp = Compressor(SPEC)
    bad = v3
    for i in range(4):
        bad = corrupt_frame(bad, i)
    with pytest.raises(ContainerError):
        comp.decompress(bad, on_error="skip")


def test_inspect_reports_damage(v3):
    bad = corrupt_frame(v3, 1)
    info = Compressor.inspect(bad)
    assert info["frame_crc_ok"] == [True, False, True, True]
    assert not info["damage"].ok


def test_inspect_salvages_truncated_container(v3):
    _, table = fr.frame_table(v3)
    info = Compressor.inspect(truncate_fraction(v3, (table[2][0] + 8) / len(v3)))
    assert info["frame_indices"] == [0, 1] and info["damage"].truncated


# --------------------------------------------------------- golden fixtures


def test_golden_bitflip_salvage(field):
    """Committed bit-flipped archive: frame 1 is lost, every other chunk
    decodes byte-identically to the intact golden decode."""
    buf = (DATA / "golden_v3_bitflip.bin").read_bytes()
    ref = np.load(DATA / "golden_decoded_v3.npy")
    comp = Compressor(SPEC)
    with pytest.raises((FrameCRCError, ContainerError)):
        comp.decompress(buf)
    out = comp.decompress(buf, on_error="fill", fill_value=np.nan)
    assert out.shape == ref.shape
    assert comp.last_damage["chunks_ok"] == [True, False, True, True]
    sizes = Compressor.inspect(buf)["chunk_sizes"]
    lo, hi = sizes[0], sizes[0] + sizes[1]
    assert np.isnan(out[lo:hi]).all()
    mask = np.ones(ref.shape[0], bool)
    mask[lo:hi] = False
    np.testing.assert_array_equal(out[mask], ref[mask])


def test_golden_trunc_salvage():
    buf = (DATA / "golden_v3_trunc.bin").read_bytes()
    ref = np.load(DATA / "golden_decoded_v3.npy")
    comp = Compressor(SPEC)
    out = comp.decompress(buf, on_error="skip")
    assert comp.last_damage["chunks_ok"] == [True, True, False, False]
    sizes = comp.inspect((DATA / "golden_v3.bin").read_bytes())["chunk_sizes"]
    np.testing.assert_array_equal(out, ref[: sizes[0] + sizes[1]])


def test_golden_torn_salvage():
    buf = (DATA / "golden_v3_torn.bin").read_bytes()
    ref = np.load(DATA / "golden_decoded_v3.npy")
    comp = Compressor(SPEC)
    out = comp.decompress(buf, on_error="skip")
    assert comp.last_damage["chunks_ok"] == [True, True, True, False]
    keep = out.shape[0]
    np.testing.assert_array_equal(out, ref[:keep])


def test_golden_v3_still_reads_bytes_for_byte():
    """The intact golden archive predates sync markers: it must keep
    decoding to the committed reconstruction, unchanged."""
    buf = (DATA / "golden_v3.bin").read_bytes()
    ref = np.load(DATA / "golden_decoded_v3.npy")
    np.testing.assert_array_equal(Compressor(SPEC).decompress(buf), ref)


# ------------------------------------------------------------ retry + I/O


def test_retry_call_backs_off_then_succeeds():
    sleeps = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    out = retry_call(flaky, policy=RetryPolicy(attempts=3, jitter=0.0),
                     sleep=sleeps.append, seed=0)
    assert out == "ok" and calls["n"] == 3
    assert sleeps == [0.05, 0.1]  # base * 2**(attempt-1), no jitter


def test_retry_call_exhausts():
    with pytest.raises(OSError):
        retry_call(lambda: (_ for _ in ()).throw(OSError("down")),
                   policy=RetryPolicy(attempts=2), sleep=lambda s: None)


def test_retrying_writer_survives_flaky_sink(v3):
    sink = io.BytesIO()
    flaky = FlakyFile(sink, fail_calls=(1, 4))
    w = RetryingWriter(flaky, policy=RetryPolicy(attempts=3, jitter=0.0), sleep=lambda s: None)
    for i in range(0, len(v3), 1000):
        w.write(v3[i : i + 1000])
    assert sink.getvalue() == v3 and w.retries == 2


def test_chunk_compress_through_flaky_sink_retries(field):
    """End-to-end: transient write faults under the frame writer cost
    retries, not bytes — the container comes out byte-identical."""
    ref = chunk_compress(field, n_chunks=4, spec=SPEC)
    sink = io.BytesIO()
    w = RetryingWriter(FlakyFile(sink, fail_calls=(2, 5)),
                       policy=RetryPolicy(attempts=3, jitter=0.0), sleep=lambda s: None)
    chunk_compress(field, n_chunks=4, spec=SPEC, out=w)
    assert sink.getvalue() == ref and w.retries == 2


def test_encode_tensor_to_retries_transient_oserror(monkeypatch):
    from repro.checkpoint.codec import decode_tensor, encode_tensor_to

    monkeypatch.setenv("REPRO_IO_RETRIES", "4")
    x = np.linspace(0, 1, 100 * 64, dtype=np.float32).reshape(100, 64)
    sink = io.BytesIO()
    meta = encode_tensor_to(FlakyFile(sink, fail_calls=(1, 3)), x, eb=1e-3)
    assert meta["io_retries"] == 2
    assert meta["crc32"] == (zlib.crc32(sink.getvalue()) & 0xFFFFFFFF)
    out = decode_tensor(sink.getvalue(), meta)
    rng = x.max() - x.min()
    assert np.abs(out - x).max() <= 1e-3 * rng * (1 + 1e-5)


# ----------------------------------------------------- engine fallback ladder


def test_device_encode_failure_falls_back_bit_identical(field, monkeypatch):
    comp = Compressor(CompressorSpec(eb=1e-2, pipeline="cr", autotune=False, engine="device"))
    ref = comp.compress(field)
    assert comp.last_telemetry is None or not comp.last_telemetry["fallbacks"]

    from repro.core.lossless import pipelines as pp

    real_encode = pp.encode

    def sabotaged(seq, *a, **kw):
        if not isinstance(seq, np.ndarray):
            raise RuntimeError("injected device-engine failure")
        return real_encode(seq, *a, **kw)

    monkeypatch.setattr(pp, "encode", sabotaged)
    comp2 = Compressor(CompressorSpec(eb=1e-2, pipeline="cr", autotune=False, engine="device"))
    out = comp2.compress(field)
    assert out == ref  # transparent: bit-identical container
    points = [f["point"] for f in comp2.last_telemetry["fallbacks"]]
    assert "encode" in points
    fb = next(f for f in comp2.last_telemetry["fallbacks"] if f["point"] == "encode")
    assert fb["from"] == "device" and fb["to"] == "numpy" and "injected" in fb["error"]


def test_telemetry_resets_between_calls(field):
    comp = Compressor(SPEC)
    comp.compress(field)
    first = comp.last_telemetry
    comp.compress(field)
    assert comp.last_telemetry is not first  # fresh record per call


# --------------------------------------------------- tier-2 property sweep


@pytest.mark.tier2
def test_single_frame_corruption_never_loses_other_frames(field):
    """Property: whatever single frame a random bit flip lands in, every
    *other* frame survives salvage byte-identically, in both layouts."""
    hypothesis = pytest.importorskip("hypothesis", reason="optional dev dependency")
    given, settings, st = hypothesis.given, hypothesis.settings, hypothesis.strategies

    bufs = {s: chunk_compress(field, n_chunks=5, spec=SPEC, sync=s) for s in (False, True)}
    tables = {s: fr.frame_table(b)[1] for s, b in bufs.items()}

    @settings(max_examples=60, deadline=None)
    @given(sync=st.booleans(), victim=st.integers(0, 4),
           rel=st.floats(0, 1, exclude_max=True), bit=st.integers(0, 7))
    def prop(sync, victim, rel, bit):
        buf, table = bufs[sync], tables[sync]
        off, size, _ = table[victim]
        bad = bit_flip(buf, off + int(rel * size), bit=bit)
        good, report = scan_frames(bad)
        got = dict(good)
        for i in range(5):
            if i == victim:
                continue
            o, s_, _ = table[i]
            assert got[i] == bytes(buf[o : o + s_])
        assert report.frames_damaged == 1 and report.frames_ok == 4

    prop()


@pytest.mark.tier2
def test_random_bitflip_sweep_runs_without_hypothesis(field):
    """Same property as above, driven by the pinned chaos seed — runs in
    environments without hypothesis (the CI chaos lane sweeps the seed)."""
    bufs = {s: chunk_compress(field, n_chunks=5, spec=SPEC, sync=s) for s in (False, True)}
    tables = {s: fr.frame_table(b)[1] for s, b in bufs.items()}
    rng = fault_rng()
    for _ in range(40):
        sync = bool(rng.integers(0, 2))
        buf, table = bufs[sync], tables[sync]
        victim = int(rng.integers(0, 5))
        off, size, _ = table[victim]
        bad = bit_flip(buf, off + int(rng.integers(0, size)), bit=int(rng.integers(0, 8)))
        good, report = scan_frames(bad)
        got = dict(good)
        for i in range(5):
            if i == victim:
                continue
            o, s_, _ = table[i]
            assert got[i] == bytes(buf[o : o + s_]), (sync, victim, i)
        assert report.frames_damaged == 1 and report.frames_ok == 4


def test_shard_decompress_degraded_parallel(field):
    from repro.core import shard_decompress

    buf = chunk_compress(field, n_chunks=4, spec=SPEC)
    comp = Compressor(SPEC)
    bad = corrupt_frame(buf, 3)
    out = shard_decompress(bad, workers=4, on_error="fill", fill_value=0.0, compressor=comp)
    assert out.shape == field.shape
    assert comp.last_damage["chunks_ok"] == [True, True, True, False]
    with pytest.raises((FrameCRCError, ContainerError)):
        shard_decompress(bad, workers=4, compressor=comp)
