"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytestmark = pytest.mark.tier2  # property sweeps are the slow tail of the gate

pytest.importorskip("hypothesis", reason="optional dev dependency")
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import Compressor, CompressorSpec, max_abs_err
from repro.core.lossless import decode, encode
from repro.core.lossless.flenc import fl_decode, fl_encode
from repro.core.lossless.tcms import tcms_decode, tcms_encode
from repro.core.reorder import level_permutation
from repro.optim.grad_compress import quantize_shard

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    data=hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=3, min_side=4, max_side=40),
                    elements=st.floats(-1e4, 1e4, width=32)),
    eb=st.sampled_from([1e-1, 1e-2, 1e-3]),
    pipeline=st.sampled_from(["cr", "tp"]),
)
@settings(**SETTINGS)
def test_error_bound_always_holds(data, eb, pipeline):
    c = Compressor(CompressorSpec(eb=eb, pipeline=pipeline, autotune=False))
    out = c.decompress(c.compress(data))
    rng = float(data.max() - data.min()) if data.size else 0.0
    assert out.shape == data.shape
    assert max_abs_err(data, out) <= eb * rng * (1 + 1e-4) + 1e-9


@given(data=hnp.arrays(np.uint8, st.integers(0, 4096)), pipe=st.sampled_from(["cr", "tp", "hf", "fz"]))
@settings(**SETTINGS)
def test_lossless_pipelines_bytes_roundtrip(data, pipe):
    assert np.array_equal(decode(encode(data, pipe)), data)


@given(data=hnp.arrays(np.uint8, st.integers(1, 2048)), k=st.sampled_from([1, 2, 4, 8]))
@settings(**SETTINGS)
def test_tcms_bijection(data, k):
    payload, hdr = tcms_encode(data, k)
    assert np.array_equal(tcms_decode(payload, hdr), data)


@given(codes=hnp.arrays(np.int32, st.integers(0, 3000), elements=st.integers(-(2**30), 2**30 - 1)))
@settings(**SETTINGS)
def test_fixed_length_roundtrip(codes):
    payload, hdr = fl_encode(codes)
    assert np.array_equal(fl_decode(payload, hdr), codes)


@given(dims=st.lists(st.integers(2, 40), min_size=1, max_size=3))
@settings(**SETTINGS)
def test_reorder_is_permutation(dims):
    shape = tuple(dims)
    perm, pos = level_permutation(shape, 16)
    n = int(np.prod(shape))
    assert perm.size <= n
    assert np.unique(perm).size == perm.size
    assert (pos[perm] == np.arange(perm.size)).all()


@given(t=hnp.arrays(np.float32, st.integers(1, 512), elements=st.floats(-1e6, 1e6, width=32)))
@settings(**SETTINGS)
def test_gradient_quantizer_error_bounded(t):
    import jax.numpy as jnp

    q, scale = quantize_shard(jnp.asarray(t))
    deq = np.asarray(q, np.float32) * float(scale)
    assert np.abs(deq - t).max() <= float(scale) * 0.5 + 1e-6 + np.abs(t).max() * 1e-6


# --------------------------------------------------------- device engine
# Stream lengths bias toward the Huffman CHUNK boundary (the seam-repair
# and tail-slab paths) and include empty and single-symbol streams; dtypes
# cover the integer carriers a code stream arrives in (both paths cast to
# uint8 with identical mod-256 semantics).
_ENGINE_LENGTHS = st.one_of(
    st.integers(0, 80),
    st.integers(1020, 1030),  # straddles huffman.CHUNK == 1024
    st.integers(2040, 2060),
    st.integers(0, 3000),
)
_ENGINE_DTYPES = st.sampled_from([np.uint8, np.int32, np.int64])


@given(
    data=st.one_of(
        hnp.arrays(np.uint8, _ENGINE_LENGTHS),
        # single-symbol streams: one code, degenerate Huffman tree
        st.tuples(st.integers(0, 255), _ENGINE_LENGTHS).map(
            lambda t: np.full(t[1], t[0], np.uint8)
        ),
    ),
    dtype=_ENGINE_DTYPES,
)
@settings(**SETTINGS)
def test_engine_stage_bit_identity(data, dtype):
    """numpy-vs-device bit-identity for EVERY registered device stage."""
    import jax.numpy as jnp

    from repro.core.lossless.stages import registered_stages

    arr = data.astype(dtype)
    dev = jnp.asarray(arr)
    for name, stage in sorted(registered_stages().items()):
        if stage.encode_device is None:
            continue
        payload, hdr = stage.encode(np.ascontiguousarray(arr, np.uint8))
        pdev, hdev = stage.encode_device(dev)
        ref = payload if isinstance(payload, bytes) else np.asarray(payload).tobytes()
        assert hdev == hdr, name
        assert np.asarray(pdev).tobytes() == ref, name
