"""Reorder mapping (Eq. 3) and stencil-table invariants."""
import numpy as np
import pytest

from repro.core.reorder import _level_of_shape, flat_permutation, level_permutation
from repro.core.stencils import build_steps, interp_matrix


@pytest.mark.parametrize("shape", [(33,), (17, 33), (17, 17, 33), (49, 33, 17)])
def test_level_permutation_bijection(shape):
    perm, pos = level_permutation(shape, 16)
    n = int(np.prod(shape))
    anchors = n - perm.size
    assert anchors >= 1
    assert np.unique(perm).size == perm.size  # injective
    lev = _level_of_shape(shape, 16).reshape(-1)
    assert (lev[perm[0]] if perm.size else 4) == lev[perm].max()
    # level-descending order (paper: large strides first)
    levels_seq = lev[perm]
    assert (np.diff(levels_seq.astype(int)) <= 0).all()
    # inverse consistency
    assert np.array_equal(pos[perm], np.arange(perm.size))


def test_flat_permutation_sorted():
    perm = flat_permutation((33, 33), 16)
    assert (np.diff(perm) > 0).all()


@pytest.mark.parametrize("spline", ["linear", "cubic", "natural-cubic"])
@pytest.mark.parametrize("s", [8, 4, 2, 1])
def test_interp_matrix_partition_of_unity(spline, s):
    M, order = interp_matrix(17, s, spline)
    rows = np.arange(s, 17, 2 * s)
    assert np.allclose(M[rows].sum(axis=1), 1.0, atol=1e-6)  # reproduces constants
    assert (order[rows] >= 1).all()


@pytest.mark.parametrize("ndim", [1, 2, 3])
@pytest.mark.parametrize("scheme", ["md", "1d"])
@pytest.mark.parametrize("spline", ["linear", "cubic", "natural-cubic"])
def test_step_coverage(ndim, scheme, spline):
    steps = build_steps(ndim, 17, (8, 4, 2, 1), (spline,) * 4, (scheme,) * 4)
    cover = np.zeros((17,) * ndim, np.int32)
    for st in steps:
        cover += st.mask
        # weights only on masked points, summing to 1
        wsum = sum(np.asarray(w) for w in st.weights)
        assert np.allclose(wsum[st.mask], 1.0, atol=1e-6)
        assert np.allclose(wsum[~st.mask], 0.0)
    coords = np.meshgrid(*([np.arange(17)] * ndim), indexing="ij")
    anchors = np.ones((17,) * ndim, bool)
    for c in coords:
        anchors &= c % 16 == 0
    assert (cover[anchors] == 0).all()
    assert (cover[~anchors] == 1).all()


@pytest.mark.parametrize("scheme", ["1d-210", "1d-120", "1d-021"])
def test_sequential_ordering_coverage_and_distinct_masks(scheme):
    """Every sweep permutation still tiles each level exactly once, and a
    non-natural ordering really changes the per-step masks vs "1d"."""
    steps = build_steps(3, 17, (8, 4, 2, 1), ("cubic",) * 4, (scheme,) * 4)
    base = build_steps(3, 17, (8, 4, 2, 1), ("cubic",) * 4, ("1d",) * 4)
    cover = np.zeros((17,) * 3, np.int32)
    for st in steps:
        cover += st.mask
    coords = np.meshgrid(*([np.arange(17)] * 3), indexing="ij")
    anchors = np.ones((17,) * 3, bool)
    for c in coords:
        anchors &= c % 16 == 0
    assert (cover[anchors] == 0).all() and (cover[~anchors] == 1).all()
    assert any(not np.array_equal(a.mask, b.mask) for a, b in zip(steps, base))


def test_scheme_dims_validation():
    from repro.core.stencils import scheme_dims

    assert scheme_dims("md", 3) is None
    assert scheme_dims("1d", 3) == (0, 1, 2)
    assert scheme_dims("1d-210", 3) == (2, 1, 0)
    for bad in ("1d-21", "1d-0122", "1d-ab", "zigzag"):
        with pytest.raises(ValueError, match="scheme"):
            scheme_dims(bad, 3)


def test_exact_on_cubic_polynomial():
    """Cubic splines reproduce cubic polynomials away from block borders."""
    import jax.numpy as jnp

    from repro.core.predictor import compress_blocks

    t = np.linspace(-1, 1, 17).astype(np.float32)
    X, Y, Z = np.meshgrid(t, t, t, indexing="ij")
    poly = (X**3 + Y**3 - Z**3 + X * Y * Z).astype(np.float32)[None]
    steps = build_steps(3, 17, (8, 4, 2, 1), ("cubic",) * 4, ("md",) * 4)
    codes, outl, recon = compress_blocks(jnp.asarray(poly), jnp.float32(1e-3), steps, 16)
    # reconstruction within eb everywhere (quantization guarantees it)
    assert float(jnp.max(jnp.abs(recon - poly))) <= 1e-3 + 1e-6
