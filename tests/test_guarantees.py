"""Guaranteed-bound runtime: the chaos-suite acceptance tests.

Two guarantees, asserted end to end:

* **non-finite-safe ingest** — a field with NaN/Inf round-trips through
  every entry path (``Compressor``, ``shard_compress``, ``repro.io``,
  ``compressd``) with the non-finite points restored bit-exactly and the
  finite points within the declared bound;
* **runtime bound verification** — an injected encoder fault
  (:func:`repro.testing.faults.perturb_quant_codes`) that silently
  violates the bound is caught by ``verify="sample"``, repaired within
  the bounded retry ladder (surfaced in ``last_telemetry``), and raises
  a typed :class:`repro.core.errors.BoundViolationError` when the ladder
  is exhausted.
"""
import numpy as np
import pytest

from repro.core import Compressor, CompressorSpec, shard_compress, shard_decompress
from repro.core.errors import BoundViolationError
from repro.testing import perturb_quant_codes
from repro.testing.faults import fault_rng


def _field(shape=(32, 32, 32), seed=None):
    rng = fault_rng(seed)
    x = rng.standard_normal(shape)
    for ax in range(x.ndim):
        x = np.cumsum(x, axis=ax)
    return (x / max(1.0, float(np.max(np.abs(x))))).astype(np.float32)


def _poison(x):
    x = x.copy()
    x[0, :2] = np.nan
    x[3, 4, 5] = np.inf
    x[-1, -1, -1] = -np.inf
    return x


def _bits(a):
    return np.ascontiguousarray(a, np.float32).view(np.uint32)


def _assert_nfsafe_roundtrip(x, y, eb_rel):
    y = np.asarray(y)
    fin = np.isfinite(x)
    assert np.array_equal(_bits(x[~fin]), _bits(y[~fin]))
    xf, yf = x[fin].astype(np.float64), y[fin].astype(np.float64)
    rng = float(np.max(xf)) - float(np.min(xf))
    assert np.max(np.abs(xf - yf)) <= eb_rel * rng * (1 + 2e-4)


# --------------------------------------------------- entry path 1: Compressor
def test_nfsafe_compressor():
    x = _poison(_field())
    comp = Compressor(CompressorSpec(eb=1e-3))
    buf = comp.compress(x)
    tel = comp.last_telemetry
    assert tel["nonfinite"]["n"] == 66  # 2*32 NaN + 2 Inf
    _assert_nfsafe_roundtrip(x, comp.decompress(buf), 1e-3)


def test_nfsafe_inspect_exposes_inner():
    x = _poison(_field())
    comp = Compressor(CompressorSpec(eb=1e-3))
    info = Compressor.inspect(comp.compress(x))
    assert info["mode"] == "nfsafe"
    assert info["inner"]["mode"] == "interp"


# ----------------------------------------------- entry path 2: shard_compress
def test_nfsafe_shard_compress():
    x = _poison(_field())
    comp = Compressor(CompressorSpec(eb=1e-3))
    buf = shard_compress(x, compressor=comp)
    tel = comp.last_telemetry or {}
    import jax

    if jax.device_count() > 1 and x.shape[0] % jax.device_count() == 0:
        # the device pass has no nfsafe stage: it must detect the poison in
        # its min/max reduction and fall back to per-chunk host compression
        points = [(f["point"], f["to"]) for f in tel.get("fallbacks", ())]
        assert ("shard", "chunk_compress") in points
    _assert_nfsafe_roundtrip(x, shard_decompress(buf), 1e-3)


# ---------------------------------------------------- entry path 3: repro.io
def test_nfsafe_io_write(tmp_path):
    from repro.io import rw
    from repro.io.dataset import Dataset

    x = _poison(_field((24, 30, 16)))
    p = str(tmp_path / "nf.cszh")
    rw.write(Dataset({"t2m": x}), p, compression="lossy,rel,1e-3")
    _assert_nfsafe_roundtrip(x, rw.read_variable(p, "t2m"), 1e-3)


# ------------------------------------------------- entry path 3b: checkpoint
def test_nfsafe_checkpoint_codec():
    from repro.checkpoint.codec import decode_tensor, encode_tensor

    x = _poison(_field((32, 32, 8)))
    payload, meta = encode_tensor(x, eb=1e-3)
    assert meta["mode"] == "cuszhi3"  # took the lossy path, not a silent raw fallback
    _assert_nfsafe_roundtrip(x, decode_tensor(payload, meta), 1e-3)


# --------------------------------------------------- entry path 4: compressd
def test_nfsafe_compressd():
    from repro.launch.compressd import CompressdClient, CompressdServer

    x = _poison(_field((24, 24, 24)))
    with CompressdServer("127.0.0.1:0", workers=2) as srv:
        srv.start()
        with CompressdClient(srv.address) as c:
            buf = c.compress(x, spec="lossy,rel,1e-3,verify=sample")
            _assert_nfsafe_roundtrip(x, c.decompress(buf), 1e-3)


def test_all_nonfinite_field_trivial_container():
    x = np.full((64, 64), np.inf, np.float32)
    x[1::3] = np.nan
    x[2::3] = -np.inf
    comp = Compressor(CompressorSpec(eb=1e-3))
    buf = comp.compress(x)
    assert len(buf) < 512
    assert np.array_equal(_bits(x), _bits(comp.decompress(buf)).reshape(x.shape))


# --------------------------------------------------------- verify and repair
def test_injected_violation_caught_and_repaired():
    # 32^3 < the verify sample size, so sampling covers every point: the
    # injected violation cannot slip through
    x = _field()
    comp = Compressor(CompressorSpec(eb=1e-3, verify="sample"))
    with perturb_quant_codes(n_calls=1, delta=8, frac=0.02) as stats:
        buf = comp.compress(x)
    assert stats["perturbed"] > 0
    tel = comp.last_telemetry
    assert tel["verify"]["mode"] == "sample"
    assert tel["verify"]["repairs"] >= 1  # the fault was seen and repaired
    y = comp.decompress(buf)
    rng = float(np.max(x)) - float(np.min(x))
    assert np.max(np.abs(x.astype(np.float64) - y.astype(np.float64))) <= 1e-3 * rng * (1 + 2e-4)


def test_injected_violation_off_mode_is_silent():
    """Sanity check on the injector itself: with verify=off the perturbed
    container really does violate the bound (i.e. the repair test above is
    exercising a genuine violation, not a benign shuffle)."""
    x = _field()
    comp = Compressor(CompressorSpec(eb=1e-3, verify="off"))
    with perturb_quant_codes(n_calls=1, delta=8, frac=0.02) as stats:
        buf = comp.compress(x)
    assert stats["perturbed"] > 0
    y = comp.decompress(buf)
    rng = float(np.max(x)) - float(np.min(x))
    assert np.max(np.abs(x.astype(np.float64) - y.astype(np.float64))) > 1e-3 * rng


def test_persistent_fault_exhausts_ladder():
    # a fault armed for every call survives each repair re-encode; the
    # ladder must give up with the typed error, never return bad bytes
    x = _field()
    comp = Compressor(CompressorSpec(eb=1e-3, verify="sample"))
    with perturb_quant_codes(n_calls=99, delta=16, frac=0.05):
        with pytest.raises(BoundViolationError) as ei:
            comp.compress(x)
    assert ei.value.repairs >= 1
    assert ei.value.max_err > ei.value.bound > 0


def test_verify_full_clean_field_telemetry():
    x = _field((24, 24))
    comp = Compressor(CompressorSpec(eb=1e-3, verify="full"))
    comp.compress(x)
    v = (comp.last_telemetry or {})["verify"]
    assert v["mode"] == "full"
    assert v["repairs"] == 0
    assert v["checked"] == x.size
    assert v["max_err"] <= v["bound"] * (1 + 1e-4) + 1e-12


def test_verify_sample_through_shard_frames():
    x = _field()
    comp = Compressor(CompressorSpec(eb=1e-3, verify="sample"))
    # one faulty predictor run: the first frame's initial encode is
    # perturbed, its repair re-encode (and every later frame) is clean
    with perturb_quant_codes(n_calls=1, delta=8, frac=0.02) as stats:
        buf = shard_compress(x, compressor=comp)
    y = np.asarray(shard_decompress(buf))
    rng = float(np.max(x)) - float(np.min(x))
    assert stats["perturbed"] > 0
    assert np.max(np.abs(x.astype(np.float64) - y.astype(np.float64))) <= 1e-3 * rng * (1 + 2e-4)
