"""compressd daemon: protocol, concurrency, backpressure, degradation.

Daemon tests carry explicit ``pytest.mark.timeout`` marks (active when
pytest-timeout is installed, as in CI; inert otherwise) so a wedged
socket or a deadlocked admission queue fails the run instead of hanging
it.
"""
import socket
import struct
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import Compressor, CompressorSpec, PlanCache
from repro.core.errors import (
    RequestTooLargeError,
    ServiceError,
    ServiceOverloadedError,
    ServiceProtocolError,
)
from repro.launch.compressd import (
    MAGIC,
    CompressdClient,
    CompressdServer,
    default_workers,
    pack_frame,
    parse_addr,
    read_frame,
    wait_ready,
)

pytestmark = pytest.mark.timeout(120)


def _field(seed=0, n=24):
    g = np.linspace(0, 4 * np.pi, n)
    X, Y, Z = np.meshgrid(g, g, g, indexing="ij")
    rng = np.random.default_rng(seed)
    return (np.sin(X + seed) * np.cos(Y) * np.sin(Z)
            + 0.01 * rng.standard_normal(X.shape)).astype(np.float32)


@pytest.fixture(scope="module")
def server():
    with CompressdServer("127.0.0.1:0", workers=4).start() as srv:
        wait_ready(srv.address, timeout=10)
        yield srv


# ----------------------------------------------------------------- protocol
def test_parse_addr():
    assert parse_addr("127.0.0.1:7733") == (socket.AF_INET, ("127.0.0.1", 7733))
    assert parse_addr("unix:/tmp/x.sock") == (socket.AF_UNIX, "/tmp/x.sock")
    with pytest.raises(ValueError):
        parse_addr("7733")


def test_ping_and_stats_shape(server):
    with CompressdClient(server.address) as c:
        assert c.ping()
        st = c.stats()
    assert st["workers"] == 4
    assert {"inflight_bytes", "queued", "queue_depth", "rejected_overload",
            "rejected_oversize"} <= set(st["queue"])
    assert {"entries", "hits", "misses", "hit_rate"} <= set(st["plan_cache"])


def test_bad_magic_gets_protocol_error(server):
    family, sockaddr = parse_addr(server.address)
    with socket.socket(family, socket.SOCK_STREAM) as s:
        s.settimeout(10)
        s.connect(sockaddr)
        s.sendall(b"NOPE" + b"\x00" * 12)
        rh, _ = read_frame(s)
    assert rh["ok"] is False and rh["error"] == "ServiceProtocolError"


def test_unknown_op_and_bad_shape(server):
    with CompressdClient(server.address) as c:
        with pytest.raises(ServiceProtocolError):
            c.request({"op": "frobnicate"}, b"x")
        # connection survives a typed rejection
        with pytest.raises(ServiceProtocolError):
            c.request({"op": "compress", "shape": [10, 10], "dtype": "float32"},
                      b"\x00" * 12)  # 12 B != 400 B
        assert c.ping()


def test_unknown_spec_field_rejected(server):
    with CompressdClient(server.address) as c:
        with pytest.raises(ServiceProtocolError, match="unknown spec field"):
            c.compress(_field(), ebb=1e-3)  # typo must not silently default
        with pytest.raises(ValueError):
            c.compress(_field(), eb=1e-3, pipeline="not-a-pipeline")
        assert c.ping()


# ------------------------------------------------------------ compress path
def test_roundtrip_and_plan_cache_hit(server):
    x = _field(3)
    with CompressdClient(server.address, stream="t-roundtrip") as c:
        buf = c.compress(x, eb=1e-3, predictor="auto", pipeline="auto")
        first = dict(c.last_info)
        c.compress(x, eb=1e-3, predictor="auto", pipeline="auto")
        second = dict(c.last_info)
        y = c.decompress(buf)
        st = c.stats()
    assert first["plan_cache"] == "miss" and second["plan_cache"] == "hit"
    assert second["pipeline"] == first["pipeline"]
    assert y.shape == x.shape and y.dtype == np.float32
    assert np.max(np.abs(x - y)) <= 1e-3 * (x.max() - x.min()) * (1 + 1e-5)
    rec = st["streams"]["t-roundtrip"]
    assert rec["requests"] == 3 and rec["plan_cache_hits"] >= 1
    assert rec["cr"] > 0 and rec["mbps"] > 0


def test_spec_variants_roundtrip(server):
    x = _field(4)
    with CompressdClient(server.address) as c:
        for spec in ({"eb": 1e-2}, {"eb": 1e-3, "eb_mode": "abs"},
                     {"eb": 1e-3, "pipeline": "tp", "autotune": False}):
            buf = c.compress(x, **spec)
            y = c.decompress(buf)
            assert y.shape == x.shape


def test_daemon_matches_local_compressor(server):
    """A daemon container is a normal container: local decode, same bound."""
    x = _field(5)
    with CompressdClient(server.address) as c:
        buf = c.compress(x, eb=1e-3, pipeline="tp", autotune=False)
    local = Compressor(CompressorSpec(eb=1e-3, pipeline="tp", autotune=False))
    assert np.array_equal(local.decompress(buf), local.decompress(local.compress(x)))


# ---------------------------------------------------------------- concurrency
def test_concurrent_clients(server):
    """N clients hammer concurrently; every roundtrip lands within bound."""
    n_clients, per_client = 6, 3
    fields = [_field(seed, n=20) for seed in range(n_clients)]
    errors = []

    def run(k):
        try:
            with CompressdClient(server.address, stream=f"conc-{k}") as c:
                for _ in range(per_client):
                    buf = c.compress(fields[k], eb=1e-3)
                    y = c.decompress(buf)
                    assert np.max(np.abs(fields[k] - y)) <= \
                        1e-3 * (fields[k].max() - fields[k].min()) * (1 + 1e-5)
        except Exception as e:  # pragma: no cover - failure path
            errors.append((k, repr(e)))

    threads = [threading.Thread(target=run, args=(k,)) for k in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=110)
    assert not errors, errors
    st = CompressdClient(server.address).stats()
    for k in range(n_clients):
        assert st["streams"][f"conc-{k}"]["requests"] == 2 * per_client
        assert st["streams"][f"conc-{k}"]["errors"] == 0


# ------------------------------------------------------------- backpressure
@pytest.mark.timeout(60)
def test_backpressure_queue_then_shed():
    """In-flight byte budget: 1st holds it, 2nd queues, 3rd is shed."""
    with CompressdServer("127.0.0.1:0", workers=4, max_request_bytes=1 << 20,
                         max_inflight_bytes=1 << 20, queue_depth=1).start() as srv:
        hold = b"\x00" * (1 << 20)
        results = {}

        def sleeper(name, seconds):
            try:
                with CompressdClient(srv.address) as c:
                    rh, _ = c.request({"op": "sleep", "seconds": seconds}, hold)
                    results[name] = rh
            except ServiceError as e:
                results[name] = e

        t1 = threading.Thread(target=sleeper, args=("a", 1.2))
        t1.start()
        time.sleep(0.4)  # a is admitted and holds the whole budget
        t2 = threading.Thread(target=sleeper, args=("b", 0.1))
        t2.start()
        time.sleep(0.4)  # b is parked in the admission queue (depth 1)
        t3 = threading.Thread(target=sleeper, args=("c", 0.1))
        t3.start()
        t3.join(timeout=30)
        assert isinstance(results["c"], ServiceOverloadedError)  # shed, typed
        t1.join(timeout=30)
        t2.join(timeout=30)
        assert results["a"]["ok"] and results["b"]["ok"]  # queued b completed
        st = srv.stats()
        assert st["queue"]["rejected_overload"] == 1
        assert st["queue"]["inflight_bytes"] == 0  # budget fully released


@pytest.mark.timeout(60)
def test_oversized_request_rejected_and_connection_survives():
    with CompressdServer("127.0.0.1:0", workers=2,
                         max_request_bytes=1 << 16).start() as srv:
        with CompressdClient(srv.address) as c:
            with pytest.raises(RequestTooLargeError):
                c.compress(np.zeros((256, 256), np.float32))  # 256 KiB > 64 KiB
            # payload was drained, not buffered: framing intact, daemon alive
            assert c.ping()
            small = np.zeros((64, 64), np.float32)
            assert isinstance(c.compress(small, eb=1e-3), bytes)
            assert srv.stats()["queue"]["rejected_oversize"] == 1


def test_compress_error_is_typed_and_worker_survives(server):
    with CompressdClient(server.address) as c:
        bad = np.full((20, 20, 20), np.nan, np.float32)
        try:
            c.compress(bad, eb=1e-3)  # NaN field may or may not raise...
        except Exception:
            pass
        with pytest.raises((ServiceError, ValueError)):
            c.decompress(b"this is not a container")
        assert c.ping()  # ...but the daemon always survives


# --------------------------------------------------------- shared plan cache
def test_shared_cache_across_connections():
    cache = PlanCache(max_entries=8)
    with CompressdServer("127.0.0.1:0", workers=2, plan_cache=cache).start() as srv:
        x = _field(7)
        with CompressdClient(srv.address) as c1:
            c1.compress(x, eb=1e-3, predictor="auto", pipeline="auto")
            assert c1.last_info["plan_cache"] == "miss"
        with CompressdClient(srv.address) as c2:  # new connection, same cache
            c2.compress(x, eb=1e-3, predictor="auto", pipeline="auto")
            assert c2.last_info["plan_cache"] == "hit"
        assert cache.stats()["hits"] == 1


# -------------------------------------------------- telemetry thread-safety
@pytest.mark.timeout(60)
def test_compressor_telemetry_is_per_thread():
    """Regression: one Compressor shared across threads must not cross-wire
    ``last_telemetry`` between concurrent calls (the daemon's worker pool
    shares per-spec instances)."""
    comp = Compressor(CompressorSpec(eb=1e-3, pipeline="tp", autotune=False))
    sizes = [16, 20, 24, 28]
    bufs = {n: comp.compress(_field(1, n=n)) for n in sizes}
    barrier = threading.Barrier(len(sizes))
    failures = []

    def run(n):
        try:
            for _ in range(5):
                barrier.wait(timeout=30)
                out = comp.decompress(bufs[n])
                tel = comp.last_telemetry
                # this thread's view must describe THIS call
                assert tel["decode"]["bytes"] == out.nbytes == n ** 3 * 4
        except Exception as e:  # pragma: no cover - failure path
            failures.append((n, repr(e)))

    threads = [threading.Thread(target=run, args=(n,)) for n in sizes]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=50)
    assert not failures, failures


# ------------------------------------------------------------------ CLI/env
def test_env_knob_workers(monkeypatch):
    monkeypatch.setenv("REPRO_COMPRESSD_WORKERS", "7")
    assert default_workers() == 7
    monkeypatch.setenv("REPRO_COMPRESSD_WORKERS", "bogus")
    assert default_workers() == 4
    monkeypatch.delenv("REPRO_COMPRESSD_WORKERS")
    srv = CompressdServer("127.0.0.1:0", workers=3, queue_depth=5)
    try:
        assert srv.workers == 3 and srv.queue_depth == 5
    finally:
        srv.close()


def test_unix_socket_roundtrip(tmp_path):
    addr = f"unix:{tmp_path}/compressd.sock"
    with CompressdServer(addr, workers=2).start() as srv:
        assert srv.address == addr
        with CompressdClient(addr) as c:
            x = _field(8, n=16)
            y = c.decompress(c.compress(x, eb=1e-2))
            assert y.shape == x.shape
    assert not (tmp_path / "compressd.sock").exists()  # unlinked on close


@pytest.mark.timeout(120)
def test_cli_subprocess_serves_and_shuts_down():
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.compressd", "--addr", "127.0.0.1:0",
         "--workers", "2"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    try:
        line = proc.stdout.readline()
        assert "compressd listening on " in line, line
        addr = line.split("compressd listening on ")[1].split()[0]
        wait_ready(addr, timeout=60)
        with CompressdClient(addr) as c:
            x = _field(9, n=16)
            y = c.decompress(c.compress(x, eb=1e-2))
            assert np.max(np.abs(x - y)) <= 1e-2 * (x.max() - x.min()) * (1 + 1e-5)
            c.shutdown()
        assert proc.wait(timeout=30) == 0
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)


def test_frame_codec_symmetry():
    hdr = {"op": "ping", "n": 3}
    frame = pack_frame(hdr, b"payload")
    assert frame.startswith(MAGIC)
    # decode through a socketpair to exercise the exact recv path
    a, b = socket.socketpair()
    try:
        a.sendall(frame)
        rh, rp = read_frame(b)
    finally:
        a.close()
        b.close()
    assert rh == hdr and rp == b"payload"
    (hlen,) = struct.unpack_from("<I", frame, 4)
    assert len(frame) == 4 + 4 + hlen + 8 + len(b"payload")


# ------------------------------------------------------- spec-string surface
def test_spec_string_roundtrip_no_warning(server):
    import warnings as W

    from repro.core import SpecError

    x = _field(9)
    with CompressdClient(server.address, stream="t-specstr") as c:
        with W.catch_warnings():
            W.simplefilter("error", DeprecationWarning)
            buf = c.compress(x, spec="lossy,abs,1e-3,autotune=false")
            y = c.decompress(buf)
        assert np.max(np.abs(x - y)) <= 1e-3 * (1 + 1e-4) + 1e-9
        # CompressorSpec objects are accepted and canonicalized client-side
        buf2 = c.compress(x, spec=CompressorSpec(eb=1e-3, eb_mode="abs", autotune=False))
        assert len(buf2) == len(buf)
        # bad grammar fails client-side with the typed error, nothing sent
        with pytest.raises(SpecError):
            c.compress(x, spec="lossy,abs,oops")


def test_legacy_spec_kwargs_deprecated_but_equivalent(server):
    x = _field(9)
    with CompressdClient(server.address, stream="t-legacy") as c:
        with pytest.warns(DeprecationWarning, match="deprecated"):
            legacy = c.compress(x, eb=1e-3, eb_mode="abs", autotune=False)
        modern = c.compress(x, spec="lossy,abs,1e-3,autotune=false")
        y = c.decompress(legacy)
        assert np.max(np.abs(x - y)) <= 1e-3 * (1 + 1e-4) + 1e-9
        assert len(legacy) == len(modern)  # same spec through either surface
        with pytest.raises(TypeError, match="not both"):
            c.compress(x, spec="lossy,abs,1e-3", eb=1e-3)


# ------------------------------------------------------------ survivability
def test_deadline_exceeded_typed_and_bytes_released():
    from repro.core.errors import DeadlineExceededError

    with CompressdServer("127.0.0.1:0", workers=1, deadline_ms=150).start() as srv:
        with CompressdClient(srv.address) as c:
            t0 = time.monotonic()
            with pytest.raises(DeadlineExceededError):
                c.request({"op": "sleep", "seconds": 3.0}, b"x" * 256)
            assert time.monotonic() - t0 < 2.0  # responded at the deadline, not after
            assert c.ping()  # connection framing survived
            # the stranded worker's reservation drains once the sleep ends
            for _ in range(100):
                q = c.stats()["queue"]
                if q["inflight_bytes"] == 0:
                    break
                time.sleep(0.1)
            assert q["inflight_bytes"] == 0
            assert q["deadline_exceeded"] >= 1


def test_deadline_off_by_default(server):
    assert server.deadline_ms == 0.0
    with CompressdClient(server.address) as c:
        rh, _ = c.request({"op": "sleep", "seconds": 0.2}, b"y" * 16)
        assert rh["ok"]


def test_health_op_bypasses_admission(server):
    with CompressdClient(server.address) as c:
        h = c.health()
        assert h["healthy"] and not h["draining"]
        assert "inflight_bytes" in h and "queued" in h


def test_drain_finishes_inflight_sheds_new():
    srv = CompressdServer("127.0.0.1:0", workers=2, drain_s=15).start()
    slow = CompressdClient(srv.address)
    probe = CompressdClient(srv.address)
    probe.ping()  # connection established before the drain begins
    done = {}

    def run_slow():
        done["resp"] = slow.request({"op": "sleep", "seconds": 1.0}, b"z" * 64)

    t = threading.Thread(target=run_slow)
    t.start()
    time.sleep(0.3)  # the slow request is in flight
    drainer = threading.Thread(target=srv.drain)
    drainer.start()
    time.sleep(0.3)
    # new work on a live connection is shed while draining...
    with pytest.raises(ServiceOverloadedError):
        probe.request({"op": "sleep", "seconds": 0.1}, b"w" * 16)
    # ...but health still answers, reporting the drain
    assert probe.health()["draining"]
    t.join(timeout=10)
    drainer.join(timeout=10)
    # the in-flight request completed during the drain window
    assert done["resp"][0]["ok"]
    # and the daemon is fully closed: new connections are refused
    with pytest.raises((ConnectionError, OSError)):
        CompressdClient(srv.address).ping()
    slow.close()
    probe.close()


def test_drain_unlinks_unix_socket(tmp_path):
    import os

    path = str(tmp_path / "drain.sock")
    srv = CompressdServer(f"unix:{path}").start()
    wait_ready(srv.address, timeout=10)
    srv.drain()
    assert not os.path.exists(path)


@pytest.mark.timeout(120)
def test_sigterm_drains_under_load():
    """SIGTERM to the CLI daemon with a request in flight: the in-flight
    request completes, new work is shed, and the process exits cleanly."""
    import signal as _signal

    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.compressd", "--addr", "127.0.0.1:0",
         "--workers", "2", "--drain-s", "20"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    try:
        line = proc.stdout.readline()
        assert "compressd listening on " in line, line
        addr = line.split("compressd listening on ")[1].split()[0]
        wait_ready(addr, timeout=60)
        inflight = {}

        def slow_request():
            with CompressdClient(addr) as c:
                rh, _ = c.request({"op": "sleep", "seconds": 1.5}, b"x" * 64)
                inflight["rh"] = rh

        t = threading.Thread(target=slow_request)
        t.start()
        time.sleep(0.4)  # the sleep is in flight on a worker
        proc.send_signal(_signal.SIGTERM)
        t.join(timeout=30)
        assert inflight["rh"]["ok"]  # in-flight work finished during the drain
        assert proc.wait(timeout=30) == 0
        with pytest.raises((ConnectionError, OSError)):
            CompressdClient(addr).ping()  # daemon is gone, not wedged
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=10)


def test_stale_unix_socket_reclaimed(tmp_path):
    import os

    path = str(tmp_path / "stale.sock")
    # a dead daemon's leftover: bound once, never unlinked
    leftover = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    leftover.bind(path)
    leftover.close()
    assert os.path.exists(path)
    with CompressdServer(f"unix:{path}").start() as srv:
        wait_ready(srv.address, timeout=10)
        with CompressdClient(srv.address) as c:
            assert c.ping()


def test_live_unix_socket_not_hijacked(tmp_path):
    path = str(tmp_path / "live.sock")
    with CompressdServer(f"unix:{path}").start() as srv:
        wait_ready(srv.address, timeout=10)
        with pytest.raises(OSError, match="live daemon"):
            CompressdServer(f"unix:{path}")
        with CompressdClient(srv.address) as c:
            assert c.ping()  # the incumbent is untouched


def test_idle_connection_reaped():
    with CompressdServer("127.0.0.1:0", idle_s=0.3).start() as srv:
        c = CompressdClient(srv.address)
        assert c.ping()
        time.sleep(0.9)
        with pytest.raises((ConnectionError, OSError)):
            c.ping()
        c.close()
        with CompressdClient(srv.address) as c2:  # daemon itself is fine
            assert c2.stats()["queue"]["idle_reaped"] >= 1


def test_client_retry_rides_out_restart_window():
    """A client with retries enabled survives transient connection loss:
    first attempt hits a dead port, the daemon 'comes back' before the
    retry (simulated by binding the listener between attempts)."""
    srv = CompressdServer("127.0.0.1:0").start()
    addr = srv.address
    srv.close()  # daemon gone: first attempt gets ECONNREFUSED
    revived = {}

    def revive():
        time.sleep(0.3)
        host, port = addr.rsplit(":", 1)
        revived["srv"] = CompressdServer(f"{host}:{port}").start()

    threading.Thread(target=revive).start()
    try:
        c = CompressdClient(addr, retries=8, retry_backoff_s=0.2)
        assert c.ping()  # retried through the dead window
        c.close()
    finally:
        for _ in range(50):
            if "srv" in revived:
                break
            time.sleep(0.1)
        revived["srv"].close()


def test_client_retry_default_off():
    srv = CompressdServer("127.0.0.1:0", workers=1, max_request_bytes=1 << 20,
                          max_inflight_bytes=1 << 20, queue_depth=0).start()
    with srv:
        blocker = CompressdClient(srv.address)
        t = threading.Thread(target=lambda: blocker.request(
            {"op": "sleep", "seconds": 1.5}, b"b" * (1 << 20)))
        t.start()
        time.sleep(0.3)
        with CompressdClient(srv.address) as c:  # retries=0: shed is surfaced raw
            with pytest.raises(ServiceOverloadedError):
                c.request({"op": "sleep", "seconds": 0.1}, b"c" * (1 << 19))
        t.join()
        blocker.close()


def test_verify_spec_key_accepted(server):
    x = _field(3)
    with CompressdClient(server.address) as c:
        buf = c.compress(x, spec="lossy,rel,1e-3,verify=full")
        y = c.decompress(buf)
        rng = float(x.max() - x.min())
        assert float(np.max(np.abs(x - y))) <= 1e-3 * rng * (1 + 2e-4)
