"""Stage-level lossless roundtrips."""
import numpy as np
import pytest

from repro.core.lossless import bitshuffle as bs
from repro.core.lossless import huffman as hf
from repro.core.lossless import pipelines as pp
from repro.core.lossless import rre
from repro.core.lossless import tcms
from repro.core.lossless.flenc import fl_decode, fl_encode


def _streams():
    rng = np.random.default_rng(0)
    yield "random", rng.integers(0, 256, 5000, dtype=np.uint8)
    yield "skewed", np.minimum(rng.zipf(1.5, 5000), 255).astype(np.uint8)
    yield "runs", np.repeat(rng.integers(0, 4, 100, dtype=np.uint8), 57)[:5000]
    yield "zeros", np.zeros(4096, np.uint8)
    yield "tiny", np.array([128], np.uint8)
    yield "empty", np.zeros(0, np.uint8)


@pytest.mark.parametrize("name,data", list(_streams()))
def test_huffman_roundtrip(name, data):
    payload, hdr = hf.encode(data)
    out = hf.decode(payload, hdr)
    assert np.array_equal(out, data), name


@pytest.mark.parametrize("k", [1, 4, 8])
@pytest.mark.parametrize("name,data", list(_streams()))
def test_rre_rze_roundtrip(k, name, data):
    payload, hdr = rre.rre_encode(data, k)
    assert np.array_equal(rre.rre_decode(payload, hdr), data)
    payload, hdr = rre.rze_encode(data, k)
    assert np.array_equal(rre.rze_decode(payload, hdr), data)


@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_tcms_bijective(k):
    rng = np.random.default_rng(k)
    data = rng.integers(0, 256, 4096, dtype=np.uint8)
    payload, hdr = tcms.tcms_encode(data, k)
    assert np.array_equal(tcms.tcms_decode(payload, hdr), data)


def test_tcms_concentrates_small_values():
    """Codes near 128 (zero-centered) must map to few set bits."""
    data = np.array([128, 129, 127, 130, 126], np.uint8)
    payload, _ = tcms.tcms_encode(data ^ 0x80, 1)  # center first
    out = np.frombuffer(payload, np.uint8)
    assert int(np.unpackbits(out).sum()) <= int(np.unpackbits(data).sum())


@pytest.mark.parametrize("name,data", list(_streams()))
def test_bitshuffle_roundtrip(name, data):
    payload, hdr = bs.bitshuffle_encode(data)
    assert np.array_equal(bs.bitshuffle_decode(payload, hdr), data)


@pytest.mark.parametrize("pipe", sorted(pp.registered_pipelines()))
@pytest.mark.parametrize("name,data", list(_streams()))
def test_pipelines_roundtrip(pipe, name, data):
    buf = pp.encode(data, pipe)
    assert np.array_equal(pp.decode(buf), data)


def test_cr_pipeline_beats_hf_on_runs():
    data = np.repeat(np.array([128, 129, 127, 128], np.uint8), 4096)
    assert len(pp.encode(data, "cr")) < len(pp.encode(data, "hf"))


def test_fl_roundtrip():
    rng = np.random.default_rng(3)
    codes = (rng.standard_normal(10000) * 40).astype(np.int32)
    payload, hdr = fl_encode(codes)
    assert np.array_equal(fl_decode(payload, hdr), codes)
