"""Sharded/streaming compression: container v3 framing + shard_compress.

In-process tests adapt to whatever device count jax initialized with (the
CI ``distributed`` job sets ``XLA_FLAGS=--xla_force_host_platform_device_
count=8``; plain tier-1 runs them on 1 device through the chunked
fallback — the container format is identical either way). The acceptance
bit-identity test forces 8 fake CPU devices in a subprocess, like
tests/test_distributed.py, because the device count must be set before
jax initializes.
"""
import io
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    Compressor,
    CompressorSpec,
    FrameReader,
    FrameWriter,
    chunk_compress,
    max_abs_err,
    shard_compress,
    shard_decompress,
)
from repro.core import frames as fr

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _field(n=4, side=32, seed=0):
    rng = np.random.default_rng(seed)
    g = np.linspace(0, 4 * np.pi, side)
    X, Y, Z = np.meshgrid(g, g, g, indexing="ij")
    base = (np.sin(X) * np.cos(Y) * np.sin(Z)).astype(np.float32)
    return np.stack([base * (1 + 0.1 * i) + 0.02 * rng.standard_normal(base.shape).astype(np.float32)
                     for i in range(n)])


# ------------------------------------------------------------- frames layer
def test_frames_pack_unpack_roundtrip():
    payloads = [b"alpha", b"", b"\x00" * 1000, os.urandom(257)]
    buf = fr.pack_frames({"kind": "test", "n": 4}, payloads)
    header, out = fr.unpack_frames(buf)
    assert header["kind"] == "test" and out == payloads


def test_frames_writer_reader_streaming():
    bio = io.BytesIO()
    w = FrameWriter(bio, {"kind": "test"})
    for i in range(5):
        w.write_frame(bytes([i]) * (i + 1))
    assert w.close() == 5
    r = FrameReader(io.BytesIO(bio.getvalue()))
    assert r.header == {"kind": "test"}
    assert [len(p) for p in r] == [1, 2, 3, 4, 5]
    assert r.frames_read == 5


def test_frames_crc_detects_corruption():
    buf = bytearray(fr.pack_frames({}, [b"payload-bytes"]))
    header, table = fr.frame_table(bytes(buf))
    off = table[0][0]
    buf[off + 3] ^= 0xFF
    with pytest.raises(ValueError, match="CRC"):
        fr.read_frame(bytes(buf), table[0])
    with pytest.raises(ValueError, match="CRC"):
        list(FrameReader(io.BytesIO(bytes(buf))))


def test_frames_truncation_detected():
    buf = fr.pack_frames({}, [b"abc", b"defg"])
    with pytest.raises(ValueError, match="truncated"):
        fr.frame_table(buf[:-5])  # end marker gone
    with pytest.raises(ValueError, match="truncated"):
        list(FrameReader(io.BytesIO(buf[:-5])))
    with pytest.raises(ValueError, match="magic"):
        fr.frame_table(b"JUNK" + buf)


# ------------------------------------------------------- v3 chunk containers
def test_chunk_compress_roundtrip_and_partial_decode():
    x = _field(n=5, side=24)
    spec = CompressorSpec(eb=1e-3, pipeline="cr", autotune=False)
    buf = chunk_compress(x, n_chunks=5, spec=spec)
    comp = Compressor(spec)
    out = comp.decompress(buf)
    rng = float(x.max() - x.min())
    assert out.shape == x.shape
    assert max_abs_err(x, out) <= 1e-3 * rng * (1 + 1e-5)
    # frames decode individually and in any order
    header, frames_b = fr.unpack_frames(buf)
    assert header["kind"] == "chunks" and len(frames_b) == 5
    solo = comp.decompress(frames_b[2])
    assert np.array_equal(solo, out[2:3])
    swapped = comp.decompress(buf, frames=[3, 1])
    assert np.array_equal(swapped, np.concatenate([out[3:4], out[1:2]], 0))


def test_chunk_frames_bit_equal_independent_compress():
    """Every v3 frame is byte-identical to Compressor.compress of its chunk."""
    x = _field(n=3, side=24)
    spec = CompressorSpec(eb=1e-3, pipeline="cr", autotune=False)
    buf = chunk_compress(x, n_chunks=3, spec=spec)
    comp = Compressor(spec)
    _, frames_b = fr.unpack_frames(buf)
    for i in range(3):
        assert frames_b[i] == comp.compress(x[i : i + 1]), f"chunk {i}"


def test_shard_compress_adapts_to_device_count():
    """shard_compress produces a valid v3 stream on any device count (the
    chunked fallback covers 1-device hosts and non-divisible axes)."""
    x = _field(n=6, side=24)
    spec = CompressorSpec(eb=1e-3, pipeline="cr", autotune=False)
    buf = shard_compress(x, spec=spec)
    comp = Compressor(spec)
    out = comp.decompress(buf)
    rng = float(x.max() - x.min())
    assert out.shape == x.shape
    assert max_abs_err(x, out) <= 1e-3 * rng * (1 + 1e-5)
    hdr = Compressor.inspect(buf)
    assert hdr["kind"] == "chunks" and hdr["n_frames"] >= 1
    assert all(f["mode"] in ("interp", "const") for f in hdr["frames"])
    # parallel decode matches serial decode
    assert np.array_equal(shard_decompress(buf, workers=4), out)


def test_shard_compress_pytree():
    tree = {"a": _field(n=2, side=20), "b": _field(n=2, side=20, seed=1)}
    spec = CompressorSpec(eb=1e-2, pipeline="tp", autotune=False)
    bufs = shard_compress(tree, spec=spec)
    comp = Compressor(spec)
    for k in tree:
        out = comp.decompress(bufs[k])
        rng = float(tree[k].max() - tree[k].min())
        assert max_abs_err(tree[k], out) <= 1e-2 * rng * (1 + 1e-5)
    # scalar leaves (step counters, ...) fail loudly, not by infinite recursion
    with pytest.raises(TypeError, match="scalar"):
        shard_compress({"w": tree["a"], "step": 3}, spec=spec)
    # one sink cannot hold a pytree of containers
    with pytest.raises(ValueError, match="pytree"):
        shard_compress(tree, spec=spec, out=io.BytesIO())


def test_shard_compress_streaming_sink(tmp_path):
    x = _field(n=4, side=20)
    spec = CompressorSpec(eb=1e-3, pipeline="cr", autotune=False)
    p = tmp_path / "field.csz3"
    with open(p, "wb") as f:
        nf = shard_compress(x, spec=spec, out=f)
    assert nf >= 1
    blob = p.read_bytes()
    assert blob == shard_compress(x, spec=spec)
    # streamed read: FrameReader sees the same frames as the random-access table
    with open(p, "rb") as f:
        r = FrameReader(f)
        streamed = list(r)
    assert streamed == fr.unpack_frames(blob)[1]


def test_constant_chunks_use_const_frames():
    x = np.zeros((4, 20, 20), np.float32)
    x[2:] = 7.5  # two constant chunk values
    buf = chunk_compress(x, n_chunks=4, spec=CompressorSpec(eb=1e-3, pipeline="cr"))
    hdr = Compressor.inspect(buf)
    assert [f["mode"] for f in hdr["frames"]] == ["const"] * 4
    assert np.array_equal(Compressor(CompressorSpec(eb=1e-3)).decompress(buf), x)


def test_v3_rejects_foreign_kinds_and_frames_on_v2():
    comp = Compressor(CompressorSpec(eb=1e-3))
    foreign = fr.pack_frames({"kind": "gradq"}, [b"x"])
    with pytest.raises(ValueError, match="kind"):
        comp.decompress(foreign)
    v2 = comp.compress(_field(n=1, side=20)[0])
    with pytest.raises(ValueError, match="v3"):
        comp.decompress(v2, frames=[0])


# ---------------------------------------------------------------- consumers
def test_grad_pack_sharded_roundtrip():
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.distributed import default_mesh
    from repro.optim.grad_compress import pack_quantized_sharded, unpack_quantized_sharded

    ndev = jax.device_count()
    mesh = default_mesh()
    qnp = np.random.default_rng(0).integers(-50, 50, (ndev * 2, 512), dtype=np.int8)
    qd = jax.device_put(jnp.asarray(qnp), NamedSharding(mesh, P("shards")))
    buf = pack_quantized_sharded(qd, 0.25)
    q2, scale = unpack_quantized_sharded(buf)
    assert scale == 0.25 and np.array_equal(q2, qnp)
    header, table = fr.frame_table(buf)
    assert header["kind"] == "gradq" and len(table) == ndev
    # partial reassembly: only the first shard's slice is filled
    part, _ = unpack_quantized_sharded(buf, frames=[0])
    sl = tuple(slice(a, b) for a, b in header["slices"][0])
    assert np.array_equal(part[sl], qnp[sl])
    outside = np.ones_like(part, bool)
    outside[sl] = False
    assert not part[outside].any()


def test_checkpoint_codec_v3_frames():
    from repro.checkpoint.codec import decode_tensor, encode_tensor

    x = (np.sin(np.linspace(0, 80, 128 * 1024)).astype(np.float32) * 2).reshape(256, 512)
    payload, meta = encode_tensor(x, eb=1e-3)
    assert meta["mode"] == "cuszhi3" and meta["n_frames"] >= 1
    assert meta["bytes"] == len(payload)
    assert fr.is_v3(payload)
    y = decode_tensor(payload, meta)
    rng = float(x.max() - x.min())
    assert y.shape == x.shape and max_abs_err(x, y) <= 1e-3 * rng * (1 + 1e-5)


def test_async_checkpointer_surfaces_worker_error_on_wait(tmp_path):
    """The async saver must not park worker exceptions until the next
    submit: wait() raises, with the worker's original traceback attached."""
    import traceback

    from repro.checkpoint.manager import AsyncCheckpointer

    ac = AsyncCheckpointer(tmp_path / "unwritable" / "\0bad")  # save() will fail
    ac.submit({"w": np.ones(4, np.float32)}, 1)
    with pytest.raises(Exception) as ei:
        ac.wait()
    tb = "".join(traceback.format_exception(ei.type, ei.value, ei.value.__traceback__))
    assert "_worker" in tb or "save" in tb  # original worker frames preserved
    ac.close()  # error already consumed: close is clean


def test_async_checkpointer_wait_drains(tmp_path):
    from repro.checkpoint import manager as mgr

    ac = mgr.AsyncCheckpointer(tmp_path)
    tree = {"w": np.arange(16, dtype=np.float32)}
    ac.submit(tree, 7)
    ac.wait()
    assert mgr.latest_step(tmp_path) == 7
    restored, _ = mgr.restore({"w": np.zeros(16, np.float32)}, tmp_path, 7)
    assert np.array_equal(restored["w"], tree["w"])
    ac.close()


# --------------------------------------------------- multi-device acceptance
def _run(script: str, devices: int = 8, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}\nstdout:\n{r.stdout[-2000:]}"
    return r.stdout


@pytest.mark.slow
def test_shard_compress_bit_identical_on_8_devices():
    """Acceptance: on 8 fake CPU devices, shard_compress of a (8,64,64,64)
    field is bit-identical per shard to 8 independent Compressor.compress
    calls; frames decode individually and in any order; v1/v2 still decode."""
    out = _run("""
        import numpy as np, jax
        from repro.core import Compressor, CompressorSpec, shard_compress
        from repro.core import frames as fr
        from repro.core.compressor import _sections_pack_v1, _sections_unpack
        from repro.core.lossless import pipelines as pp
        assert jax.device_count() == 8
        rng = np.random.default_rng(0)
        g = np.linspace(0, 4 * np.pi, 64)
        X, Y, Z = np.meshgrid(g, g, g, indexing="ij")
        base = (np.sin(X) * np.cos(Y) * np.sin(Z)).astype(np.float32)
        x = np.stack([base * (1 + 0.1 * i)
                      + 0.02 * rng.standard_normal(base.shape).astype(np.float32)
                      for i in range(8)])
        spec = CompressorSpec(eb=1e-3, pipeline="cr")  # default legacy autotune ON
        buf = shard_compress(x, spec=spec)
        header, frames_b = fr.unpack_frames(buf)
        assert header["chunk_sizes"] == [1] * 8
        comp = Compressor(spec)
        for i in range(8):  # the acceptance contract, byte for byte
            assert frames_b[i] == comp.compress(x[i:i+1]), f"shard {i} not bit-identical"
        full = comp.decompress(buf)
        assert full.shape == x.shape
        rngv = float(x.max() - x.min())
        assert float(np.abs(full - x).max()) <= 1e-3 * rngv * (1 + 1e-5)
        # frames decode individually and in any order
        assert np.array_equal(comp.decompress(frames_b[5]), full[5:6])
        assert np.array_equal(comp.decompress(buf, frames=[6, 2, 4]),
                              np.concatenate([full[6:7], full[2:3], full[4:5]], 0))
        # v1/v2 containers written by earlier generations still decode
        v2 = comp.compress(x[0])
        h2, sections = _sections_unpack(v2)
        v1 = _sections_pack_v1({k: v for k, v in h2.items() if k != "pipeline"},
                               [pp.encode_v1(pp.decode(sections[0]), "cr")] + list(sections[1:]))
        assert np.array_equal(comp.decompress(v1), comp.decompress(v2))
        print("BIT_IDENTICAL_OK")
    """)
    assert "BIT_IDENTICAL_OK" in out


def test_shard_compress_autoplan_and_pallas_on_4_devices():
    """predictor="auto" (per-shard PredictorPlan) and the Pallas backend both
    keep the per-shard bit-identity contract under shard_map."""
    out = _run("""
        import numpy as np, jax
        from repro.core import Compressor, CompressorSpec, shard_compress
        from repro.core import frames as fr
        assert jax.device_count() == 4
        rng = np.random.default_rng(1)
        g = np.linspace(0, 3 * np.pi, 32)
        X, Y, Z = np.meshgrid(g, g, g, indexing="ij")
        base = (np.cos(X) * np.cos(2 * Y) + 0.5 * np.sin(Z)).astype(np.float32)
        x = np.stack([base * (1 + 0.2 * i)
                      + 0.01 * rng.standard_normal(base.shape).astype(np.float32)
                      for i in range(4)])
        for label, spec in [
            ("autoplan", CompressorSpec(eb=1e-3, predictor="auto", pipeline="auto")),
            ("pallas", CompressorSpec(eb=1e-2, pipeline="cr", autotune=False, backend="pallas")),
        ]:
            buf = shard_compress(x, spec=spec)
            _, frames_b = fr.unpack_frames(buf)
            comp = Compressor(spec)
            for i in range(4):
                assert frames_b[i] == comp.compress(x[i:i+1]), (label, i)
            if label == "autoplan":  # every frame records its own plan
                plans = [Compressor.inspect(f).get("pplan") for f in frames_b]
                assert all(p is not None for p in plans)
        print("VARIANTS_OK")
    """, devices=4)
    assert "VARIANTS_OK" in out
