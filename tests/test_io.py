"""repro.io: dataset write/read round-trip, per-chunk random access,
spec handling, and adapters."""
import numpy as np
import pytest

import repro.io as rio
from repro.core import CompressorSpec, SpecError, frames as frames_mod, max_abs_err
from repro.data import load_real_fields


@pytest.fixture(scope="module")
def weather():
    suite = load_real_fields()
    return {
        "t2m": suite["temperature"][:48, :64],
        "q": suite["humidity"][:48, :64],
        "vort": suite["vorticity"][:24, :24, :24],
    }


def _dataset(weather):
    ds = rio.Dataset(attrs={"title": "unit", "run": 3})
    ds["t2m"] = rio.Variable(weather["t2m"], ("lat", "lon"), {"units": "K"})
    ds["q"] = rio.Variable(weather["q"], ("lat", "lon"))
    ds["vort"] = rio.Variable(weather["vort"], ("z", "y", "x"))
    ds["step"] = rio.Variable(np.arange(10, dtype=np.int32), ("step",))
    return ds


# ------------------------------------------------------------------ lossless
def test_lossless_round_trip_byte_identity(tmp_path, weather):
    ds = _dataset(weather)
    path = tmp_path / "ds.cszh3"
    man = rio.write(ds, path, compression="lossless", chunks=(24, 32))
    assert man["bytes_written"] == path.stat().st_size
    back = rio.read(path)
    assert back.attrs == {"title": "unit", "run": 3}
    for name in ds:
        assert np.array_equal(back[name].data, ds[name].data), name
        assert back[name].dtype == ds[name].dtype
        assert back[name].dims == ds[name].dims
    assert back["t2m"].attrs["units"] == "K"


def test_lossless_single_chunk_random_access(tmp_path, weather):
    ds = _dataset(weather)
    path = tmp_path / "ds.cszh3"
    rio.write(ds, path, compression="lossless", chunks={"t2m": (24, 32)})
    # grid is 2x2: chunk (1, 1) is the bottom-right block, byte-identical
    c = rio.read_variable(path, "t2m", chunks=(1, 1))
    assert np.array_equal(c, weather["t2m"][24:48, 32:64])
    # flat index addresses the same grid in C order
    assert np.array_equal(rio.read_variable(path, "t2m", chunks=3), c)
    with pytest.raises(IndexError):
        rio.read_variable(path, "t2m", chunks=(2, 0))
    with pytest.raises(KeyError):
        rio.read_variable(path, "nope")


# --------------------------------------------------------------------- lossy
def test_lossy_round_trip_bound_per_variable(tmp_path, weather):
    ds = _dataset(weather)
    path = tmp_path / "ds.cszh3"
    rio.write(ds, path, compression={
        None: "lossy,abs,1e-2,pipeline=cr,autotune=false",
        "q": "lossy,pw_rel,1e-2,pipeline=cr,autotune=false",
        "step": "lossless",
    }, chunks={"t2m": (24, 32)})
    back = rio.read(path)
    # slack: contract slop plus one f32 ULP at the field's magnitude (~300 K)
    tol = 1e-2 * (1 + 1e-4) + float(np.spacing(np.float32(350.0)))
    assert max_abs_err(weather["t2m"], back["t2m"].data) <= tol
    assert max_abs_err(weather["vort"], back["vort"].data) <= 1e-2 * (1 + 1e-4) + 1e-6
    # pw_rel on the humidity variable: point-wise relative bound
    from repro.core import max_rel_err

    assert max_rel_err(weather["q"], back["q"].data) <= 1e-2
    # int variable survives losslessly even under a lossy default
    assert np.array_equal(back["step"].data, ds["step"].data)
    assert back["step"].dtype == np.int32


def test_lossy_chunk_bound_holds_per_chunk(tmp_path, weather):
    ds = rio.Dataset({"t2m": rio.Variable(weather["t2m"], ("lat", "lon"))})
    path = tmp_path / "c.cszh3"
    rio.write(ds, path, compression="lossy,abs,5e-3,pipeline=cr,autotune=false",
              chunks=(24, 32))
    tol = 5e-3 * (1 + 1e-4) + float(np.spacing(np.float32(350.0)))
    for idx, sl in [((0, 0), np.s_[:24, :32]), ((1, 1), np.s_[24:, 32:])]:
        c = rio.read_variable(path, "t2m", chunks=idx)
        assert max_abs_err(weather["t2m"][sl], c) <= tol


# ------------------------------------------------------------------ manifest
def test_manifest_and_frame_layout(tmp_path, weather):
    ds = _dataset(weather)
    path = tmp_path / "ds.cszh3"
    rio.write(ds, path, compression="lossless", chunks={"t2m": (24, 32)})
    man = rio.manifest(path)
    assert man["kind"] == "dataset"
    by_name = {v["name"]: v for v in man["variables"]}
    assert by_name["t2m"]["n_chunks"] == 4
    assert by_name["t2m"]["spec"] == "lossless"
    # frame ranges tile [0, total) contiguously in manifest order
    total = sum(v["n_chunks"] for v in man["variables"])
    starts = [v["frame_start"] for v in man["variables"]]
    assert starts == sorted(starts) and starts[0] == 0
    buf = path.read_bytes()
    _, table = frames_mod.frame_table(buf)
    assert len(table) == total


def test_spec_validation_and_errors(tmp_path, weather):
    ds = rio.Dataset({"a": weather["t2m"]})
    with pytest.raises(SpecError):
        rio.write(ds, tmp_path / "x.cszh3", compression="lossy,abs,nope")
    with pytest.raises(SpecError):
        rio.write(ds, tmp_path / "x.cszh3", compression=42)
    assert rio.parse_compression("lossless") is None
    assert rio.parse_compression(None) is None
    sp = rio.parse_compression("lossy,abs,1e-3")
    assert isinstance(sp, CompressorSpec) and sp.eb == 1e-3
    assert rio.parse_compression(sp) is sp
    # reading a non-dataset v3 stream is a typed refusal
    other = frames_mod.pack_frames({"kind": "chunks"}, [b"x"])
    p = tmp_path / "other.cszh3"
    p.write_bytes(other)
    with pytest.raises(ValueError, match="dataset"):
        rio.read(p)


# ------------------------------------------------------------------ adapters
def test_npz_adapter_round_trip(tmp_path, weather):
    ds = _dataset(weather)
    ds.to_npz(tmp_path / "w.npz")
    back = rio.open_dataset(tmp_path / "w.npz")
    for name in ds:
        assert np.array_equal(back[name].data, ds[name].data)


def test_hdf5_adapter_round_trip(tmp_path, weather):
    pytest.importorskip("h5py")
    ds = _dataset(weather)
    ds.to_hdf5(tmp_path / "w.h5")
    back = rio.open_dataset(tmp_path / "w.h5")
    for name in ds:
        assert np.array_equal(back[name].data, ds[name].data)
        assert back[name].dims == ds[name].dims
    assert back["t2m"].attrs["units"] == "K"


def test_dataset_model_validation():
    with pytest.raises(ValueError):
        rio.Variable(np.zeros((2, 2)), dims=("only-one",))
    ds = rio.Dataset({"x": np.zeros((3, 4))})
    assert ds["x"].dims == ("x_d0", "x_d1")
    assert "x" in ds and len(ds) == 1


def test_scalar_and_empty_variables(tmp_path):
    ds = rio.Dataset({"pi": np.float64(3.14159), "empty": np.zeros((0, 4), np.float32)})
    path = tmp_path / "s.cszh3"
    rio.write(ds, path, compression="lossless")
    back = rio.read(path)
    assert back["pi"].data == np.float64(3.14159)
    assert back["empty"].shape == (0, 4)
