"""Quality metrics: edge cases (empty/constant fields), SSIM, spectral
error, and the quality_report bundle."""
import numpy as np
import pytest

from repro.core import metrics as M
from repro.data import load_real_fields


def test_bit_rate_empty_array_is_zero():
    assert M.bit_rate(np.zeros((0,), np.float32), b"") == 0.0
    assert M.bit_rate(np.zeros((0, 4), np.float32), b"1234") == 0.0


def test_bit_rate_basic():
    x = np.zeros((8, 8), np.float32)
    assert M.bit_rate(x, b"\x00" * 64) == pytest.approx(8.0)  # 512/64 bytes


def test_psnr_constant_field_defined():
    x = np.full((16, 16), 3.0, np.float32)
    # perfect reconstruction of a constant field: infinite, not NaN
    assert M.psnr(x, x) == np.inf
    # imperfect reconstruction still yields a finite, ordered number
    y = x + 1e-3
    v = M.psnr(x, y)
    assert np.isfinite(v) and v > 0
    worse = M.psnr(x, x + 1e-2)
    assert worse < v


def test_psnr_orders_by_error():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, 64)).astype(np.float32)
    a = M.psnr(x, x + 1e-4 * rng.standard_normal(x.shape).astype(np.float32))
    b = M.psnr(x, x + 1e-2 * rng.standard_normal(x.shape).astype(np.float32))
    assert a > b > 0


def test_max_rel_err_zero_handling():
    x = np.array([0.0, 1.0, -2.0], np.float32)
    y = np.array([0.0, 1.01, -2.0], np.float32)
    assert M.max_rel_err(x, y) == pytest.approx(0.01, rel=1e-3)
    # turning a zero into a nonzero has no finite relative bound
    assert M.max_rel_err(x, np.array([0.1, 1.0, -2.0], np.float32)) == np.inf


def test_ssim_bounds_and_identity():
    x = load_real_fields()["temperature"][:48, :64]
    assert M.ssim(x, x) == pytest.approx(1.0)
    noisy = x + np.random.default_rng(1).normal(0, 2.0, x.shape).astype(np.float32)
    s = M.ssim(x, noisy)
    assert -1.0 <= s < 1.0
    # mild noise scores better than heavy noise
    mild = x + np.random.default_rng(1).normal(0, 0.2, x.shape).astype(np.float32)
    assert M.ssim(x, mild) > s


def test_ssim_3d():
    v = load_real_fields()["vorticity"][:24, :24, :24]
    assert M.ssim(v, v) == pytest.approx(1.0)


def test_spectral_error_identity_and_ordering():
    x = load_real_fields()["temperature"][:48, :64]
    assert M.spectral_error(x, x) == pytest.approx(0.0, abs=1e-12)
    rng = np.random.default_rng(2)
    mild = x + rng.normal(0, 0.05, x.shape).astype(np.float32)
    heavy = x + rng.normal(0, 1.0, x.shape).astype(np.float32)
    assert 0 <= M.spectral_error(x, mild) < M.spectral_error(x, heavy)


def test_compression_ratio():
    x = np.zeros((32, 32), np.float32)
    assert M.compression_ratio(x, b"\x00" * 1024) == pytest.approx(4.0)


def test_quality_report_bundle():
    x = load_real_fields()["pressure"][:48, :64]
    y = x + np.float32(1e-3)
    rep = M.quality_report(x, y, compressed=b"\x00" * 100)
    for key in ("psnr", "ssim", "spectral_error", "max_abs_err", "max_rel_err",
                "cr", "bit_rate"):
        assert key in rep, key
    assert rep["max_abs_err"] == pytest.approx(1e-3, rel=0.05)  # f32 rounding
    assert rep["cr"] == pytest.approx(x.nbytes / 100)
    # without the payload the size-dependent entries are omitted
    rep2 = M.quality_report(x, y)
    assert "cr" not in rep2 and "bit_rate" not in rep2


# ------------------------------------------------------- non-finite hygiene
def test_nonfinite_count_union():
    x = np.zeros((4, 4), np.float32)
    y = np.zeros((4, 4), np.float32)
    x[0, 0] = np.nan
    x[0, 1] = np.inf
    y[0, 1] = np.nan  # overlaps x's inf: union counts the point once
    y[3, 3] = -np.inf
    assert M.nonfinite_count(x) == 2
    assert M.nonfinite_count(x, y) == 3


def test_metrics_mask_nonfinite_points():
    rng = np.random.default_rng(7)
    x = rng.standard_normal((32, 32)).astype(np.float32)
    y = x + np.float32(1e-3)
    ref = {k: getattr(M, k)(x, y) for k in ("psnr", "max_abs_err", "ssim")}
    xp = x.copy()
    xp[0, :5] = np.nan
    xp[1, 0] = np.inf
    for k, v in ref.items():
        got = getattr(M, k)(xp, y)
        assert np.isfinite(got), k
        assert got == pytest.approx(v, rel=0.15), k
    # neutralizing the masked points perturbs the spectrum slightly; the
    # guarantee is finite-and-small, not bit equality with the clean field
    se = M.spectral_error(xp, y)
    assert np.isfinite(se) and se < 1e-3


def test_max_rel_err_nonfinite_masked():
    x = np.full((8, 8), 2.0, np.float32)
    y = x + np.float32(0.5)
    x[0, 0] = np.nan
    assert np.isfinite(M.max_rel_err(x, y))
    assert M.max_rel_err(x, y) == pytest.approx(0.25)


def test_all_nonfinite_degenerate():
    x = np.full((4, 4), np.nan, np.float32)
    assert M.max_abs_err(x, x) == 0.0
    assert M.psnr(x, x) == np.inf
    assert M.nonfinite_count(x) == 16


def test_quality_report_counts_nonfinite():
    rng = np.random.default_rng(8)
    x = rng.standard_normal((24, 24)).astype(np.float32)
    x[2, :3] = np.nan
    y = np.where(np.isfinite(x), x, 0.0).astype(np.float32)
    rep = M.quality_report(x, y)
    assert rep["n_nonfinite"] == 3
    for k in ("psnr", "ssim", "spectral_error", "max_abs_err"):
        assert np.isfinite(rep[k]) or rep[k] == np.inf, k
