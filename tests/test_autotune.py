"""Predictor autotuning: PredictorPlan, serialization, CR floors, kernels."""
import numpy as np
import pytest

from repro.core import (
    Compressor,
    CompressorSpec,
    PredictorPlan,
    autotune_plan,
    compression_ratio,
)
from repro.core import blocks as blk
from repro.core.autotune import candidate_schemes, levels_for_stride
from repro.core.compressor import _sections_pack, _sections_pack_v1, _sections_unpack
from repro.core.serial import pack_obj, unpack_obj
from repro.core.stencils import build_steps

from repro.core.autotune import fixed_step_baselines
from repro.data import predictor_suite

EB = 1e-3

# The bench's stream classes and fixed-steps grid (same importable modules
# benchmarks.bench_lossless uses) at a smaller side — 8 blocks, still
# exhaustive for the planner — so the CR-floor gate matches the published suite.
FIELDS = predictor_suite(side=32)
FIXED_STEPS = fixed_step_baselines()


def _plan_for(x: np.ndarray) -> PredictorPlan:
    padded = blk.pad_field_batch(x[None], blk.ANCHOR_STRIDE)
    blocks = blk.gather_blocks_batch(padded, blk.ANCHOR_STRIDE)
    eb_abs = EB * float(x.max() - x.min())
    return autotune_plan(blocks, 2.0 * eb_abs, field_shape=(1,) + padded.shape[1:])


# ------------------------------------------------------------------- plan API
def test_plan_header_roundtrip():
    plan = _plan_for(FIELDS["smooth"])
    assert plan.sampled_blocks > 0 and plan.candidates
    # dict form, with and without the diagnostics payload
    assert PredictorPlan.from_header(plan.to_header(include_candidates=True)) == plan
    lean = PredictorPlan.from_header(plan.to_header())
    assert (lean.anchor_stride, lean.splines, lean.schemes) == (plan.anchor_stride, plan.splines, plan.schemes)
    # through the binary header codec the container uses
    assert unpack_obj(pack_obj(plan.to_header())) == plan.to_header()


def test_plan_levels_match_stride_and_steps_build():
    plan = _plan_for(FIELDS["ramp"])
    assert plan.levels == levels_for_stride(plan.anchor_stride)
    assert len(plan.splines) == len(plan.levels)
    steps = plan.steps(blk.BLOCK)
    assert steps == build_steps(plan.ndim, blk.BLOCK, plan.levels, plan.splines, plan.schemes)


def test_plan_rejects_wrong_level_count():
    with pytest.raises(ValueError, match="per-level"):
        PredictorPlan(ndim=3, anchor_stride=16, splines=("cubic",) * 3, schemes=("md",) * 3)


def test_candidate_schemes_cover_orderings():
    assert candidate_schemes(1) == ("md",)
    assert set(candidate_schemes(2)) == {"md", "1d-01", "1d-10"}
    assert set(candidate_schemes(3)) == {"md", "1d-012", "1d-210"}


# ------------------------------------------------------- compressor threading
def test_auto_predictor_roundtrip_and_inspect():
    x = FIELDS["smooth"]
    c = Compressor(CompressorSpec(eb=EB, predictor="auto", pipeline="cr"))
    buf = c.compress(x)
    y = c.decompress(buf)
    rng = float(x.max() - x.min())
    assert np.abs(y - x).max() <= EB * rng * (1 + 1e-4) + 1e-9
    hdr = Compressor.inspect(buf)
    assert hdr["predictor"] == "auto"
    plan = c.last_plan
    assert hdr["pplan"]["anchor_stride"] == plan.anchor_stride
    assert tuple(hdr["pplan"]["splines"]) == plan.splines
    assert tuple(hdr["pplan"]["schemes"]) == plan.schemes
    # the serialized plan reconstructs to the same step tables
    rt = PredictorPlan.from_header(hdr["pplan"])
    assert rt.steps(blk.BLOCK) == plan.steps(blk.BLOCK)


def test_spec_validates_plan_fields():
    with pytest.raises(ValueError, match="anchor stride"):
        CompressorSpec(predictor="auto", plan_anchor_strides=(13,))
    with pytest.raises(ValueError, match="pipeline_candidates"):
        CompressorSpec(pipeline="auto", pipeline_candidates=())
    with pytest.raises(ValueError, match="spline"):
        CompressorSpec(splines=("quintic",) * 4)
    with pytest.raises(ValueError, match="scheme"):
        CompressorSpec(schemes=("zigzag",) * 4)
    CompressorSpec(predictor="auto", plan_anchor_strides=(8,))  # valid


def test_plan_stride_restriction_respected():
    c = Compressor(CompressorSpec(eb=EB, predictor="auto", pipeline="cr", plan_anchor_strides=(8,)))
    buf = c.compress(FIELDS["smooth"])
    assert Compressor.inspect(buf)["anchor_stride"] == 8
    assert c.last_plan.anchor_stride == 8
    y = c.decompress(buf)
    rng = float(FIELDS["smooth"].max() - FIELDS["smooth"].min())
    assert np.abs(y - FIELDS["smooth"]).max() <= EB * rng * (1 + 1e-4) + 1e-9


# --------------------------------------------------------------- CR floor
@pytest.mark.parametrize("stream", sorted(FIELDS))
def test_auto_matches_or_beats_fixed_steps(stream):
    """predictor="auto" CR floor: within noise of the best fixed-steps
    configuration on every stream class (deterministically >= on the pinned
    environment; the small slack absorbs cross-version float drift)."""
    x = FIELDS[stream]
    crs = {}
    for name, cfg in FIXED_STEPS.items():
        c = Compressor(CompressorSpec(eb=EB, pipeline="cr", autotune=False, **cfg))
        crs[name] = compression_ratio(x, c.compress(x))
    ca = Compressor(CompressorSpec(eb=EB, predictor="auto", pipeline="cr"))
    cr_auto = compression_ratio(x, ca.compress(x))
    assert cr_auto >= max(crs.values()) * 0.995, (crs, cr_auto, ca.last_plan)


# ----------------------------------------------------- plan-less compat decode
def _strip(header: dict) -> dict:
    return {k: v for k, v in header.items() if k not in ("splines", "schemes")}


def test_planless_v2_container_decodes_with_default_steps():
    x = FIELDS["smooth"]
    c = Compressor(CompressorSpec(eb=EB, pipeline="cr", autotune=False))  # default cubic/md
    buf = c.compress(x)
    header, sections = _sections_unpack(buf)
    bare = _sections_pack(_strip(header), sections)
    assert np.array_equal(c.decompress(bare), c.decompress(buf))


def test_planless_v1_container_decodes_with_default_steps():
    from repro.core.lossless import pipelines as pp

    x = FIELDS["smooth"]
    c = Compressor(CompressorSpec(eb=EB, pipeline="cr", autotune=False))
    buf = c.compress(x)
    header, sections = _sections_unpack(buf)
    codes = pp.decode(sections[0])
    v1 = _sections_pack_v1(_strip({k: v for k, v in header.items() if k != "pipeline"}),
                           [pp.encode_v1(codes, "cr")] + list(sections[1:]))
    assert np.array_equal(c.decompress(v1), c.decompress(buf))


def test_tuner_stream_matches_engine_stream():
    """The planner's trial passes share predictor.quantize_pred with the
    engine: merging the per-level code grids must reproduce the codes
    compress_blocks emits (fp tie-breaks from jit-boundary fusion aside)."""
    import jax.numpy as jnp

    from repro.core.autotune import _level_codes_pass
    from repro.core.predictor import _anchor_mask, compress_blocks

    x = FIELDS["smooth"]
    blocks = blk.gather_blocks_batch(blk.pad_field_batch(x[None], blk.ANCHOR_STRIDE), blk.ANCHOR_STRIDE)
    twoeb = jnp.float32(2 * EB * float(x.max() - x.min()))
    levels, splines, schemes = (8, 4, 2, 1), ("cubic",) * 4, ("md",) * 4
    codes_ref = np.asarray(compress_blocks(
        jnp.asarray(blocks), twoeb, build_steps(3, blk.BLOCK, levels, splines, schemes), 16)[0])
    recon = jnp.where(jnp.asarray(_anchor_mask(blocks.shape[1:], 16)), jnp.asarray(blocks), 0.0)
    merged = np.full(blocks.shape, -1, np.int32)
    for s, sp, sc in zip(levels, splines, schemes):
        recon, codes = _level_codes_pass(recon, jnp.asarray(blocks), twoeb,
                                         build_steps(3, blk.BLOCK, (s,), (sp,), (sc,)))
        g = np.asarray(codes)
        merged = np.where(g >= 0, g, merged)
    nonanchor = merged >= 0
    assert (merged[nonanchor] == codes_ref[nonanchor].astype(np.int32)).mean() > 0.9999


# ------------------------------------------------------------------- kernels
def test_pallas_interpret_matches_ref_under_nondefault_plan():
    from repro.kernels.interp3d import compress_blocks_pallas_plan, compress_blocks_ref

    rng = np.random.default_rng(5)
    blocks = rng.standard_normal((3, 17, 17, 17)).astype(np.float32)
    plan = PredictorPlan(ndim=3, anchor_stride=8,
                         splines=("natural-cubic", "linear", "cubic"),
                         schemes=("1d-210", "md", "1d-120"))
    ck, ok, rk = compress_blocks_pallas_plan(blocks, 0.02, plan, interpret=True)
    cr, orf, rr = compress_blocks_ref(blocks, 0.02, plan.steps(17), plan.anchor_stride)
    assert (ck == cr).mean() > 0.9999  # fp tie-breaks only
    assert np.allclose(rk, rr, atol=2 * 0.02)
    assert np.abs(rk - blocks)[~ok].max() <= 0.02 + 1e-6


def test_auto_predictor_pallas_backend_roundtrip():
    x = FIELDS["ramp"]
    c = Compressor(CompressorSpec(eb=EB, predictor="auto", pipeline="cr", backend="pallas"))
    buf = c.compress(x)
    y = c.decompress(buf)
    rng = float(x.max() - x.min())
    assert np.abs(y - x).max() <= EB * rng * (1 + 1e-4) + 1e-9
