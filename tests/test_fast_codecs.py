"""Equivalence tests for the vectorized hot path.

The word-packed Huffman encoder must produce the exact bit layout of the
reference per-bit packer (the seed implementation, kept here as the oracle),
and the batched block/reorder kernels must match their per-item references.
"""
import numpy as np
import pytest

from repro.core import blocks as blk
from repro.core import reorder as ro
from repro.core.lossless import huffman as hf


def _streams():
    rng = np.random.default_rng(0)
    yield "random", rng.integers(0, 256, 5000, dtype=np.uint8)
    yield "skewed", np.minimum(rng.zipf(1.5, 5000), 255).astype(np.uint8)
    yield "runs", np.repeat(rng.integers(0, 4, 100, dtype=np.uint8), 57)[:5000]
    yield "zeros", np.zeros(4096, np.uint8)
    yield "halfchunk", np.zeros(512, np.uint8)
    yield "tiny", np.array([128], np.uint8)
    yield "empty", np.zeros(0, np.uint8)
    yield "odd", rng.integers(0, 256, hf.CHUNK - 1, dtype=np.uint8)
    yield "chunk+1", np.minimum(rng.zipf(1.5, hf.CHUNK + 1), 255).astype(np.uint8)
    yield "deepskew", np.clip(rng.normal(128, 2.5, 1 << 18), 0, 255).astype(np.uint8)


def _reference_bits(data: np.ndarray, chunk: int = hf.CHUNK, lens: np.ndarray | None = None):
    """Seed-style per-bit chunked packer (the oracle for both the current and
    the legacy chunked layouts). `lens` overrides the tree (legacy deep trees
    exceed the current MAXLEN cap, so they cannot come from code_lengths)."""
    data = np.ascontiguousarray(data, np.uint8)
    n = data.size
    if lens is None:
        lens = hf.code_lengths(np.bincount(data, minlength=256))
    codes, lens, *_ = hf.canonical_codes(lens)
    sym_lens = lens[data].astype(np.int64)
    nchunks = max(1, -(-n // chunk))
    sl = np.zeros(nchunks * chunk, np.int64)
    sl[:n] = sym_lens
    within = sl.reshape(nchunks, chunk)
    chunk_bytes = (within.sum(1) + 7) >> 3
    off = np.zeros(nchunks + 1, np.int64)
    np.cumsum(chunk_bytes, out=off[1:])
    out_bits = np.zeros(int(off[-1]) * 8, np.uint8)
    start = np.cumsum(within, 1) - within
    bitpos = (off[:-1, None] * 8 + start).reshape(-1)[:n]
    cw = codes[data].astype(np.int64)
    L = sym_lens
    reps = np.repeat(np.arange(n), L)
    j = np.arange(int(L.sum())) - np.repeat(np.cumsum(L) - L, L)
    out_bits[bitpos[reps] + j] = (cw[reps] >> (L[reps] - 1 - j)) & 1
    return np.packbits(out_bits).tobytes(), chunk_bytes, lens


@pytest.mark.parametrize("name,data", list(_streams()))
def test_huffman_bitstream_matches_reference(name, data):
    payload, hdr = hf.encode(data)
    ref_bits, ref_chunk_bytes, _ = _reference_bits(data)
    nchunks = max(1, -(-data.size // hf.CHUNK))
    blob = 256 + 2 * nchunks
    got = np.frombuffer(payload[blob:], np.uint8)
    assert np.array_equal(
        np.frombuffer(payload[256:blob], "<u2").astype(np.int64), ref_chunk_bytes
    ), name
    assert got.tobytes() == ref_bits, name
    assert np.array_equal(hf.decode(payload, hdr), data), name


def _deep_lens() -> np.ndarray:
    """A complete 24-deep tree (legacy MAXLEN): lengths 1..23 + two 24s."""
    lens = np.zeros(256, np.uint8)
    lens[:23] = np.arange(1, 24)
    lens[23:25] = 24
    return lens


def _legacy_cases():
    rng = np.random.default_rng(0)
    for name, data in _streams():
        if data.size:
            yield name, data, None
    # deep-tree stream: codes up to 24 bits, beyond the current MAXLEN cap
    deep = np.minimum(rng.geometric(0.5, 20000) - 1, 24).astype(np.uint8)
    yield "deeptree", deep, _deep_lens()


@pytest.mark.parametrize("name,data,lens", list(_legacy_cases()))
def test_huffman_legacy_header_decodes(name, data, lens):
    """Containers written by the seed (hex headers, 4096-chunks, <=24-bit
    codes) must keep decoding through the fast path's legacy branch."""
    bits, chunk_bytes, lens = _reference_bits(data, hf._LEGACY_CHUNK, lens)
    header = {
        "n": int(data.size),
        "lens": lens.tobytes().hex(),
        "chunk_bytes": np.asarray(chunk_bytes, np.uint32).tobytes().hex(),
    }
    assert np.array_equal(hf.decode(bits, header), data), name


def test_huffman_decode_grouping_matches(monkeypatch):
    """Payloads beyond the u32 bit-cursor range decode in rebased chunk
    groups; shrinking the group size must not change the output."""
    rng = np.random.default_rng(5)
    data = rng.integers(0, 256, (1 << 18) + 321, dtype=np.uint8)
    payload, hdr = hf.encode(data)
    ref = hf.decode(payload, hdr)
    monkeypatch.setattr(hf, "_DECODE_GROUP_BYTES", 1 << 14)  # force many groups
    assert np.array_equal(hf.decode(payload, hdr), ref)
    assert np.array_equal(ref, data)


def test_huffman_threaded_matches_single():
    """Slab-parallel encode must be byte-identical to the single-slab path."""
    rng = np.random.default_rng(3)
    data = np.minimum(rng.zipf(1.3, (1 << 21) + 137), 255).astype(np.uint8)
    payload, hdr = hf.encode(data)
    tbl_lens = np.frombuffer(payload[:256], np.uint8)
    codes, lens, *_ = hf.canonical_codes(tbl_lens.copy())
    tbl = (lens.astype(np.uint32) << hf._U16) | codes
    step = 1 << 20  # any CHUNK-aligned split must give identical bytes
    bits_single = b"".join(
        hf._encode_slab(data[i : i + step], tbl)[0] for i in range(0, data.size, step)
    )
    nchunks = -(-data.size // hf.CHUNK)
    assert payload[256 + 2 * nchunks :] == bits_single
    assert np.array_equal(hf.decode(payload, hdr), data)


@pytest.mark.parametrize("batch", [1, 3])
@pytest.mark.parametrize("shape", [(24, 20, 28), (33, 17), (40,)])
def test_batched_blocks_match_per_item(batch, shape):
    rng = np.random.default_rng(batch)
    xb = rng.standard_normal((batch,) + shape).astype(np.float32)
    padded_b = blk.pad_field_batch(xb)
    blocks_b = blk.gather_blocks_batch(padded_b)
    per_item = [blk.pad_field(xb[i]) for i in range(batch)]
    assert np.array_equal(padded_b, np.stack(per_item))
    ref_blocks = np.concatenate([blk.gather_blocks(p) for p in per_item], axis=0)
    assert np.array_equal(blocks_b, ref_blocks)
    # scatter inverts gather, batched
    back = blk.scatter_blocks_batch(blocks_b, batch, padded_b.shape[1:])
    assert np.array_equal(back, padded_b)
    # anchors
    anc_b = blk.anchor_grid_batch(padded_b)
    assert np.array_equal(anc_b, np.stack([blk.anchor_grid(p) for p in per_item]))
    placed = blk.place_anchors_batch(padded_b.shape[1:], anc_b)
    assert np.array_equal(placed[0], blk.place_anchors(padded_b.shape[1:], anc_b[0]))


@pytest.mark.parametrize("reorder", [True, False])
def test_batched_reorder_matches_per_item(reorder):
    rng = np.random.default_rng(0)
    grids = rng.integers(0, 256, (3, 33, 33), dtype=np.uint8)
    seq = ro.reorder_codes_batch(grids, 16, reorder)
    ref = np.concatenate([ro.reorder_codes(grids[i], 16, reorder) for i in range(3)])
    assert np.array_equal(seq, ref)
    back = ro.restore_codes_batch(seq, 3, grids.shape[1:], fill=128, dtype=np.uint8, reorder=reorder)
    ref_back = np.stack(
        [ro.restore_codes(ref[i * (ref.size // 3) : (i + 1) * (ref.size // 3)], grids.shape[1:], 128, np.uint8, reorder=reorder) for i in range(3)]
    )
    assert np.array_equal(back, ref_back)


def test_batched_compressor_roundtrip_and_cr():
    """End-to-end: the batched plan roundtrips a multi-field batch within the
    bound and compresses no worse than fields stored separately."""
    from repro.core import Compressor, CompressorSpec, max_abs_err

    rng = np.random.default_rng(7)
    g = np.stack(np.meshgrid(*[np.linspace(0, 3, 24)] * 3, indexing="ij"))
    base = np.sin(g[0] * 2.1) * np.cos(g[1] * 1.7) + 0.5 * np.sin(g[2] * 3.3)
    xb = np.stack([base + 0.05 * rng.standard_normal(base.shape) for _ in range(4)]).astype(np.float32)
    c = Compressor(CompressorSpec(eb=1e-2, pipeline="cr", autotune=False))
    buf = c.compress(xb)
    out = c.decompress(buf)
    rngv = float(xb.max() - xb.min())
    assert out.shape == xb.shape
    assert max_abs_err(xb, out) <= 1e-2 * rngv * (1 + 1e-5)
    per_item = sum(len(c.compress(xb[i])) for i in range(xb.shape[0]))
    assert len(buf) <= per_item
