"""Per-arch reduced-config smoke: one forward + one train step on CPU,
shape and finiteness assertions; decode-path consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import active_param_count, param_count
from repro.models import decode_step, forward, init_cache, init_params, prefill
from repro.runtime.steps import make_train_state, make_train_step

B, S = 2, 32


def _batch(sc, rng, seq=S):
    toks = jax.random.randint(rng, (B, seq + 1), 0, sc.vocab)
    batch = {"tokens": toks[:, :seq], "labels": toks[:, 1 : seq + 1]}
    if sc.stub_frontend == "vit":
        batch["img"] = jax.random.normal(rng, (B, sc.n_img_tokens, sc.d_model), jnp.bfloat16)
    if sc.enc_layers:
        batch["frames"] = jax.random.normal(rng, (B, sc.enc_seq, sc.d_model), jnp.bfloat16)
    return batch


@pytest.mark.slow  # ~1 min across the arch sweep, but it IS the smoke gate
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_train_step(arch):
    sc = get_config(arch).scaled()
    rng = jax.random.PRNGKey(0)
    params = init_params(sc, rng)
    batch = _batch(sc, rng)
    logits, aux = forward(params, sc, batch)
    exp_S = S + (sc.n_img_tokens if sc.stub_frontend == "vit" else 0)
    assert logits.shape == (B, exp_S, sc.vocab)
    assert bool(jnp.isfinite(logits).all())
    state = make_train_state(sc, rng)
    step = jax.jit(make_train_step(sc, None, lr=1e-3))
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    d0 = jax.tree.leaves(state.params)[0]
    d1 = jax.tree.leaves(new_state.params)[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_forward(arch):
    cfg = get_config(arch)
    # uncap MoE capacity so capacity drops can't cause asymmetry
    sc = cfg.scaled(capacity_factor=100.0) if cfg.n_experts else cfg.scaled()
    rng = jax.random.PRNGKey(1)
    params = init_params(sc, rng)
    seq = 16
    toks = jax.random.randint(rng, (B, seq + 1), 0, sc.vocab)
    batch = {"tokens": toks[:, :seq], "labels": toks[:, 1 : seq + 1]}
    if sc.stub_frontend == "vit":
        batch["img"] = jnp.zeros((B, 0, sc.d_model), jnp.bfloat16)
    if sc.enc_layers:
        batch["frames"] = jax.random.normal(rng, (B, sc.enc_seq, sc.d_model), jnp.bfloat16)
    logits_full, _ = forward(params, sc, batch)
    _, cache = prefill(params, sc, dict(batch, tokens=toks[:, : seq - 1]), cache_len=seq + 1)
    ld, _ = decode_step(params, sc, toks[:, seq - 1], jnp.int32(seq - 1), cache)
    tol = 0.15 if ("ssm" in sc.pattern or "rglru" in sc.pattern) else 0.05
    assert float(jnp.max(jnp.abs(logits_full[:, -1] - ld))) < tol


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_init_cache_structure(arch):
    sc = get_config(arch).scaled()
    cache = init_cache(sc, B, 64)
    logits, new_cache = decode_step(init_params(sc, jax.random.PRNGKey(0)), sc, jnp.zeros((B,), jnp.int32), jnp.int32(0), cache)
    assert logits.shape == (B, sc.vocab)
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(new_cache)


def test_param_counts_sane():
    """Full-config param counts should be near the published sizes."""
    expect = {
        "yi-34b": 34e9,
        "granite-34b": 34e9,
        "codeqwen1.5-7b": 7e9,
        "gemma3-12b": 12e9,
        "olmoe-1b-7b": 7e9,
        "deepseek-moe-16b": 16e9,
        "mamba2-370m": 0.37e9,
        "recurrentgemma-2b": 2.7e9,
        "whisper-small": 0.24e9,
        "internvl2-1b": 0.8e9,
    }
    for arch, n in expect.items():
        got = param_count(get_config(arch))
        assert 0.5 * n < got < 1.8 * n, f"{arch}: {got:.2e} vs {n:.2e}"
    # MoE active << total
    assert active_param_count(get_config("olmoe-1b-7b")) < 0.4 * param_count(get_config("olmoe-1b-7b"))
