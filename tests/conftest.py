import numpy as np
import pytest


@pytest.fixture(scope="session")
def smooth3d():
    g = np.linspace(0, 4 * np.pi, 48)
    X, Y, Z = np.meshgrid(g, g, g, indexing="ij")
    return (np.sin(X) * np.cos(Y) * np.sin(Z) + 0.05 * np.cos(3 * X)).astype(np.float32)


@pytest.fixture(scope="session")
def smooth2d():
    g = np.linspace(0, 6 * np.pi, 96)
    X, Y = np.meshgrid(g, g, indexing="ij")
    return (np.sin(X) * np.cos(0.7 * Y)).astype(np.float32)


@pytest.fixture(scope="session")
def smooth3d_big():
    """Large smooth field: the regime where the paper's CR ordering holds
    (small edge-dominated fields don't discriminate the designs)."""
    g = np.linspace(0, 4 * np.pi, 96)
    X, Y, Z = np.meshgrid(g, g, g, indexing="ij")
    return (np.sin(X) * np.cos(Y) * np.sin(Z) + 0.3 * np.exp(-((X - 6) ** 2 + (Y - 6) ** 2) / 8)).astype(np.float32)
