"""Stage registry, adaptive orchestration, and container v2/v1 compat."""
import numpy as np
import pytest

from repro.core import Compressor, CompressorSpec, compression_ratio, cusz_hi_auto
from repro.core.compressor import _sections_pack_v1, _sections_unpack
from repro.core.lossless import orchestrate as orc
from repro.core.lossless import pipelines as pp
from repro.core.lossless import stages as stg
from repro.core.serial import pack_obj, unpack_obj

_RNG = np.random.default_rng(0)
STREAMS = {
    "empty": np.zeros(0, np.uint8),
    "constant": np.full(20000, 128, np.uint8),
    "sparse": np.where(_RNG.random(20000) < 0.01, _RNG.integers(1, 256, 20000), 0).astype(np.uint8),
    "dense-random": _RNG.integers(0, 256, 20000, dtype=np.uint8),
}


# ------------------------------------------------------------------ registry
def test_every_registered_pipeline_uses_registered_stages():
    for name, stage_names in pp.registered_pipelines().items():
        for s in stage_names:
            assert stg.get_stage(s).name == s, (name, s)


@pytest.mark.parametrize("pipe", sorted(pp.PIPELINES))
@pytest.mark.parametrize("stream", sorted(STREAMS))
def test_registered_pipelines_roundtrip(pipe, stream):
    data = STREAMS[stream]
    assert np.array_equal(pp.decode(pp.encode(data, pipe)), data)


@pytest.mark.parametrize("pipe", sorted(pp.PIPELINES))
@pytest.mark.parametrize("stream", sorted(STREAMS))
def test_legacy_v1_streams_decode(pipe, stream):
    data = STREAMS[stream]
    assert np.array_equal(pp.decode(pp.encode_v1(data, pipe)), data)


def test_register_stage_collision_raises():
    with pytest.raises(ValueError, match="already registered"):
        stg.register_stage("hf", lambda d: (b"", {}), lambda p, h: np.zeros(0, np.uint8))


def test_unknown_stage_lists_registered_names():
    with pytest.raises(ValueError, match="registered stages"):
        stg.get_stage("definitely-not-a-stage")
    with pytest.raises(ValueError, match="registered stages"):
        pp.register_pipeline("broken", ("hf", "definitely-not-a-stage"))


def test_unknown_pipeline_lists_registered_names():
    with pytest.raises(ValueError, match="registered pipelines"):
        pp.get_pipeline("definitely-not-a-pipeline")


def test_spec_validates_at_construction():
    with pytest.raises(ValueError, match="registered pipelines"):
        CompressorSpec(pipeline="definitely-not-a-pipeline")
    with pytest.raises(ValueError, match="backend"):
        CompressorSpec(backend="cuda")
    CompressorSpec(pipeline="auto")  # auto is always valid


def test_third_party_stage_rides_pipelines():
    """A stage registered outside core works in a pipeline without core edits."""
    name, pipe = "test-xor7", "test-xor7-pipe"
    if name not in stg.registered_stages():
        stg.register_stage(
            name,
            lambda d: ((np.ascontiguousarray(d, np.uint8) ^ 7).tobytes(), {"n": int(d.size)}),
            lambda p, h: np.frombuffer(p, np.uint8)[: h["n"]] ^ 7,
        )
        pp.register_pipeline(pipe, (name, "zstd"))
    data = STREAMS["sparse"]
    assert np.array_equal(pp.decode(pp.encode(data, pipe)), data)


# -------------------------------------------------------------- orchestrator
def test_stream_stats_sanity():
    s = orc.stream_stats(STREAMS["constant"])
    assert s["entropy"] == pytest.approx(0.0) and s["run_frac"] == pytest.approx(1.0)
    s = orc.stream_stats(STREAMS["dense-random"])
    assert s["entropy"] > 7.5 and s["run_frac"] < 0.05
    s = orc.stream_stats(np.zeros(1000, np.uint8))
    assert s["zero_frac"] == pytest.approx(1.0)


def test_stream_stats_accepts_histogram_hook():
    calls = []

    def hist(d):
        calls.append(d.size)
        return np.bincount(d, minlength=256)

    s = orc.stream_stats(STREAMS["sparse"], histogram=hist)
    assert calls and s["sample_n"] == STREAMS["sparse"].size


def test_sample_stream_windows_are_contiguous_and_bounded():
    data = np.arange(1 << 20, dtype=np.uint64).astype(np.uint8)
    s = orc.sample_stream(data, 1 << 14)
    assert s.size == 1 << 14
    small = np.arange(100, dtype=np.uint8)
    assert np.array_equal(orc.sample_stream(small, 1 << 14), small)


@pytest.mark.parametrize("stream", sorted(STREAMS))
def test_auto_roundtrip_and_record(stream):
    data = STREAMS[stream]
    buf, record = orc.encode_auto(data)
    assert np.array_equal(pp.decode(buf), data)
    assert record["pipeline"] in pp.PIPELINES
    assert set(record["trial_bytes"]) <= set(pp.PIPELINES)
    assert {"entropy", "zero_frac", "run_frac", "outlier_frac"} <= set(record["stats"])


def test_portable_pipelines_exclude_optional_codecs():
    portable = orc.portable_pipelines()
    assert "crz" not in portable  # zstd tail may need the optional package
    assert {"cr", "tp", "hf", "fz", "none", "fzh", "lvl"} <= set(portable)


def test_roadmap_pipeline_variants_registered():
    """The bit1-first and per-level variants promised in the ROADMAP
    follow-up: registered, stage-valid, and in the orchestrator's
    search space (the pipeline x stream sweeps above cover roundtrips)."""
    assert pp.get_pipeline("fzh")[0] == "bit1"  # bit1-first
    assert pp.get_pipeline("lvl")[0].startswith("rre")  # run-reduction first
    data = STREAMS["sparse"]
    _, record = orc.encode_auto(data)
    assert {"fzh", "lvl"} <= set(record["trial_bytes"]) | set(record["estimates"])


def test_encode_auto_small_stream_reuses_trial_encoding():
    data = STREAMS["sparse"]  # fits the sample budget entirely
    buf, record = orc.encode_auto(data)
    assert buf == pp.encode(data, record["pipeline"])
    assert len(buf) == record["trial_bytes"][record["pipeline"]]


def test_encode_auto_portable_only_and_candidates():
    data = STREAMS["sparse"]
    buf, record = orc.encode_auto(data, portable_only=True)
    assert record["pipeline"] in orc.portable_pipelines()
    assert np.array_equal(pp.decode(buf), data)
    buf, record = orc.encode_auto(data, candidates=("tp", "none"))
    assert record["pipeline"] in ("tp", "none")
    with pytest.raises(ValueError, match="registered pipelines"):
        orc.encode_auto(data, candidates=("not-a-pipeline",))


def test_spec_pipeline_candidates_restrict_auto():
    x = _smooth()
    c = Compressor(CompressorSpec(eb=1e-3, pipeline="auto", autotune=False,
                                  pipeline_candidates=("tp", "hf")))
    hdr = Compressor.inspect(c.compress(x))
    assert hdr["pipeline"] in ("tp", "hf")
    with pytest.raises(ValueError, match="registered pipelines"):
        CompressorSpec(pipeline="auto", pipeline_candidates=("bogus",))


@pytest.mark.parametrize("stream", sorted(STREAMS))
def test_auto_matches_or_beats_worst_fixed(stream):
    data = STREAMS[stream]
    if data.size == 0:
        pytest.skip("CR undefined on empty streams")
    sizes = {pipe: len(pp.encode(data, pipe)) for pipe in ("cr", "tp", "hf", "fz", "none")}
    buf, _ = orc.encode_auto(data)
    assert len(buf) <= max(sizes.values())
    # the sample covers these streams entirely, so auto IS the argmin
    assert len(buf) <= min(sizes.values()) * 1.01


# ----------------------------------------------------- container v2 + compat
def _smooth(side=32):
    g = np.stack(np.meshgrid(*[np.linspace(0, 3, side)] * 3, indexing="ij"))
    return (np.sin(g[0] * 2.1) * np.cos(g[1] * 1.7) + 0.5 * np.sin(g[2] * 3.3 + g[0])).astype(np.float32)


def test_auto_compressor_records_choice_per_field():
    x = _smooth()
    c = cusz_hi_auto(eb=1e-3, autotune=False)
    buf = c.compress(x)
    hdr = Compressor.inspect(buf)
    assert hdr["pipeline"] in pp.PIPELINES
    assert hdr["pchoice"]["stats"]["n"] > 0
    out = c.decompress(buf)
    rng = float(x.max() - x.min())
    assert np.abs(out - x).max() <= 1e-3 * rng * (1 + 1e-5)


def test_auto_compressor_cr_not_worse_than_worst_fixed():
    x = _smooth(40)
    crs = {}
    for pipe in ("cr", "tp", "hf", "fz"):
        c = Compressor(CompressorSpec(eb=1e-3, pipeline=pipe, autotune=False))
        crs[pipe] = compression_ratio(x, c.compress(x))
    c = cusz_hi_auto(eb=1e-3, autotune=False)
    cr_auto = compression_ratio(x, c.compress(x))
    assert cr_auto >= min(crs.values())


def test_container_v1_reads_back_bit_exactly():
    """A pre-registry container (v1 JSON header + v1 JSON-meta lossless
    stream) must decompress identically to its v2 twin."""
    x = _smooth()
    c = Compressor(CompressorSpec(eb=1e-3, pipeline="cr", autotune=False))
    v2 = c.compress(x)
    header, sections = _sections_unpack(v2)
    codes = pp.decode(sections[0])
    v1_header = {k: v for k, v in header.items() if k != "pipeline"}
    v1 = _sections_pack_v1(v1_header, [pp.encode_v1(codes, "cr")] + list(sections[1:]))
    assert np.array_equal(c.decompress(v1), c.decompress(v2))


def test_container_v1_const_mode_reads_back():
    x = np.full((16, 16, 16), 2.5, np.float32)
    c = Compressor(CompressorSpec(eb=1e-3, pipeline="cr"))
    header, sections = _sections_unpack(c.compress(x))
    v1 = _sections_pack_v1({k: v for k, v in header.items() if k != "pipeline"}, list(sections))
    assert np.array_equal(c.decompress(v1), x)


def test_bad_magic_rejected():
    with pytest.raises(ValueError, match="container magic"):
        _sections_unpack(b"NOTMAGICxxxxxxxx")


def test_serial_roundtrip():
    obj = {
        "shape": [3, 4, 5],
        "eb": 1e-3,
        "name": "interp",
        "flag": True,
        "none": None,
        "nested": {"trial": {"cr": 12.5}, "raw": b"\x00\x01"},
    }
    assert unpack_obj(pack_obj(obj)) == obj
    assert unpack_obj(pack_obj(np.int64(7))) == 7
    assert unpack_obj(pack_obj(np.float32(0.5))) == 0.5


# ------------------------------------------------------------------ consumers
def test_checkpoint_meta_records_pipeline_and_legacy_decodes():
    from repro.checkpoint.codec import _as_field, decode_tensor, encode_tensor

    x = np.random.default_rng(3).standard_normal((128, 64)).astype(np.float32)
    payload, meta = encode_tensor(x, eb=1e-3)
    assert meta["mode"] == "cuszhi3" and meta["pipeline"] == "auto"
    # the recorded per-frame choices must be restorable without optional deps
    hdr = Compressor.inspect(payload)
    assert hdr["kind"] == "chunks" and len(hdr["frames"]) == meta["n_frames"]
    assert all(f["pipeline"] in orc.portable_pipelines() for f in hdr["frames"])
    rng = float(x.max() - x.min())
    assert np.abs(decode_tensor(payload, meta) - x).max() <= 1e-3 * rng * (1 + 1e-5)
    # a checkpoint written before the pipeline was recorded (hardcoded "tp")
    comp = Compressor(CompressorSpec(eb=1e-3, pipeline="tp", autotune=False))
    legacy_payload = comp.compress(_as_field(x))
    legacy_meta = {
        "shape": list(x.shape), "dtype": "float32", "mode": "cuszhi",
        "eb": 1e-3, "field_shape": list(_as_field(x).shape),
    }
    assert np.abs(decode_tensor(legacy_payload, legacy_meta) - x).max() <= 1e-3 * rng * (1 + 1e-5)


def test_grad_pack_roundtrip_auto_and_fixed():
    from repro.optim.grad_compress import pack_quantized, unpack_quantized

    rng = np.random.default_rng(4)
    q = np.clip(np.round(rng.laplace(0, 2, 50000)), -127, 127).astype(np.int8)
    for pipe in ("auto", "tp", "none"):
        buf = pack_quantized(q.reshape(250, 200), 0.125, pipeline=pipe)
        q2, scale = unpack_quantized(buf)
        assert np.array_equal(q2, q.reshape(250, 200)) and scale == 0.125
    assert len(pack_quantized(q, 1.0)) < q.nbytes  # sparse-ish grads compress
