"""§Perf feature correctness: KV-cache quantization, expert parallelism,
CRZ pipeline, bf16-before-gather step."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params
from repro.runtime.steps import make_train_state, make_train_step


@pytest.mark.tier2  # ~80 s of token-by-token decode; heaviest test in the suite
@pytest.mark.parametrize("arch", ["gemma3-12b", "yi-34b"])
def test_kv_quant_decode_matches_exact(arch):
    cfg = get_config(arch).scaled()
    cfgq = dataclasses.replace(cfg, kv_quant=1)
    rng = jax.random.PRNGKey(0)
    params = init_params(cfg, rng)
    B, S = 2, 12
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    outs = {}
    for c in (cfg, cfgq):
        cache = init_cache(c, B, S + 2)
        logits = None
        for i in range(S):
            logits, cache = decode_step(params, c, toks[:, i], jnp.int32(i), cache)
        outs[c.kv_quant] = logits
    assert jnp.argmax(outs[0], -1).tolist() == jnp.argmax(outs[1], -1).tolist()
    assert float(jnp.max(jnp.abs(outs[0] - outs[1]))) < 0.05


def test_kv_quant_cache_is_int8():
    cfg = dataclasses.replace(get_config("gemma3-12b").scaled(), kv_quant=1)
    cache = init_cache(cfg, 2, 32)
    leaves = {k: v for p in cache["stack"] for k, v in p.items()}
    assert leaves["k"].dtype == jnp.int8 and leaves["v"].dtype == jnp.int8


def test_expert_parallel_single_device_fallback():
    """EP flag must be harmless without a mesh (E_loc == E path)."""
    cfg = dataclasses.replace(get_config("olmoe-1b-7b").scaled(capacity_factor=100.0), moe_expert_parallel=True)
    base = get_config("olmoe-1b-7b").scaled(capacity_factor=100.0)
    rng = jax.random.PRNGKey(0)
    params = init_params(base, rng)
    batch = {"tokens": jax.random.randint(rng, (2, 16), 0, base.vocab),
             "labels": jax.random.randint(rng, (2, 16), 0, base.vocab)}
    from repro.models import forward

    l0, _ = forward(params, base, batch)
    l1, _ = forward(params, cfg, batch)
    assert float(jnp.max(jnp.abs(l0 - l1))) < 1e-5


def test_bf16_params_step_trains():
    cfg = dataclasses.replace(get_config("mamba2-370m").scaled(), bf16_params=True)
    rng = jax.random.PRNGKey(0)
    state = make_train_state(cfg, rng)
    step = jax.jit(make_train_step(cfg, None, lr=1e-3))
    batch = {"tokens": jax.random.randint(rng, (4, 32), 0, cfg.vocab),
             "labels": jax.random.randint(rng, (4, 32), 0, cfg.vocab)}
    losses = []
    for _ in range(6):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)) and losses[-1] < losses[0]


def test_crz_roundtrip_and_beats_cr(smooth3d_big):
    from repro.core import compression_ratio, cusz_hi_cr, cusz_hi_crz, max_abs_err

    cr = cusz_hi_cr(eb=1e-3)
    crz = cusz_hi_crz(eb=1e-3)
    b1, b2 = cr.compress(smooth3d_big), crz.compress(smooth3d_big)
    y = crz.decompress(b2)
    rng = smooth3d_big.max() - smooth3d_big.min()
    assert max_abs_err(smooth3d_big, y) <= 1e-3 * rng * (1 + 1e-5)
    assert compression_ratio(smooth3d_big, b2) >= compression_ratio(smooth3d_big, b1) * 0.98
