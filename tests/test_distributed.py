"""Multi-device behaviour on fake CPU devices (subprocess: device count must
be set before jax initializes — conftest keeps the main process at 1)."""
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)], capture_output=True, text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}\nstdout:\n{r.stdout[-2000:]}"
    return r.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_config
        from repro.launch.mesh import make_mesh
        from repro.runtime import partitioning as part, sharding_rules as rules_mod
        from repro.runtime.steps import make_train_state, make_train_step, state_pspecs, batch_pspecs
        cfg = get_config("olmoe-1b-7b").scaled()
        rng = jax.random.PRNGKey(0)
        toks = jax.random.randint(rng, (4, 33), 0, cfg.vocab)
        batch = {"tokens": toks[:, :32], "labels": toks[:, 1:]}
        # single device
        state = make_train_state(cfg, rng)
        _, m0 = jax.jit(make_train_step(cfg, None))(state, batch)
        # 2x2 mesh
        mesh = make_mesh((2, 2), ("data", "model"))
        rules = rules_mod.activation_rules(cfg, mesh)
        with part.mesh_rules(mesh, rules):
            state = make_train_state(cfg, rng)
            shapes = jax.eval_shape(lambda: state)
            st_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), state_pspecs(shapes, cfg, mesh))
            b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_pspecs(jax.eval_shape(lambda: batch), mesh))
            state = jax.device_put(state, st_sh)
            batch = jax.device_put(batch, b_sh)
            step = jax.jit(make_train_step(cfg, mesh), in_shardings=(st_sh, b_sh))
            _, m1 = step(state, batch)
        print("LOSS0", float(m0["loss"]), "LOSS1", float(m1["loss"]))
        assert abs(float(m0["loss"]) - float(m1["loss"])) < 0.05
    """, devices=4)
    assert "LOSS0" in out


def test_compressed_pod_gradient_exchange():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_config
        from repro.launch.mesh import make_mesh
        from repro.runtime import partitioning as part, sharding_rules as rules_mod
        from repro.runtime.steps import make_train_state, make_train_step, state_pspecs, batch_pspecs
        cfg = get_config("mamba2-370m").scaled()
        rng = jax.random.PRNGKey(0)
        toks = jax.random.randint(rng, (8, 33), 0, cfg.vocab)
        batch = {"tokens": toks[:, :32], "labels": toks[:, 1:]}
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = rules_mod.activation_rules(cfg, mesh)
        with part.mesh_rules(mesh, rules):
            state = make_train_state(cfg, rng, npods=2)
            shapes = jax.eval_shape(lambda: state)
            st_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), state_pspecs(shapes, cfg, mesh))
            b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), batch_pspecs(jax.eval_shape(lambda: batch), mesh))
            state = jax.device_put(state, st_sh)
            batch = jax.device_put(batch, b_sh)
            step = jax.jit(make_train_step(cfg, mesh, compress_pods=True),
                           in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None))
            losses = []
            for i in range(8):
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
        print("LOSSES", losses)
        assert all(np.isfinite(losses))
        assert losses[-1] < losses[0]  # training proceeds through int8 exchange
        # residuals populated (error feedback active)
        rmax = max(float(jnp.abs(r).max()) for r in jax.tree.leaves(state.resid))
        print("RESID", rmax)
        assert rmax > 0
    """, devices=8)
    assert "RESID" in out


def test_elastic_restore_across_meshes(tmp_path):
    out = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro import checkpoint as ckpt
        from repro.configs import get_config
        from repro.launch.mesh import make_mesh
        from repro.runtime import partitioning as part, sharding_rules as rules_mod
        from repro.runtime.steps import make_train_state, state_pspecs
        cfg = get_config("gemma3-12b").scaled()
        state = make_train_state(cfg, jax.random.PRNGKey(0))
        ckpt.save(state, r"{tmp_path}", 5)
        # restore onto a 4-device mesh with sharding placement
        mesh = make_mesh((2, 2), ("data", "model"))
        shapes = jax.eval_shape(lambda: state)
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), state_pspecs(shapes, cfg, mesh))
        restored, manifest = ckpt.restore(shapes, r"{tmp_path}", 5, shardings=sh)
        a = jax.tree.leaves(state.params)[0]
        b = jax.tree.leaves(restored.params)[0]
        assert np.array_equal(np.asarray(a), np.asarray(b))
        print("ELASTIC_OK", manifest["step"])
    """, devices=4)
    assert "ELASTIC_OK 5" in out


def test_dryrun_entrypoint_small():
    """The real dryrun module on a tiny arch/shape (full 512-device mesh)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-370m", "--shape", "decode_32k"],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK " in r.stdout
