"""Fault-tolerant loop behaviour: restart, straggler detection, NaN rollback,
end-to-end loss decrease on a tiny model."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import TokenPipeline
from repro.runtime.steps import make_train_state, make_train_step
from repro.runtime.train_loop import LoopConfig, Trainer


def _setup(tmp_path, total=30, arch="mamba2-370m"):
    cfg = get_config(arch).scaled()
    state = make_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, None, lr=1e-3))
    data = TokenPipeline(cfg.vocab, 4, 32)
    lc = LoopConfig(total_steps=total, save_every=10, ckpt_dir=str(tmp_path), log_every=1000)
    return step, state, data, lc


def test_loss_decreases_e2e(tmp_path):
    step, state, data, lc = _setup(tmp_path, total=40)
    tr = Trainer(step, state, data, lc, log=lambda *a: None)
    tr.run()
    k = 8
    assert np.mean(tr.losses[-k:]) < np.mean(tr.losses[:k]) - 0.3


def test_restart_resumes(tmp_path):
    step, state, data, lc = _setup(tmp_path, total=20)
    Trainer(step, state, data, lc, log=lambda *a: None).run()
    # second trainer resumes from step 20 checkpoint and runs to 25
    lc2 = LoopConfig(total_steps=25, save_every=10, ckpt_dir=str(tmp_path), log_every=1000)
    step2, state2, data2, _ = _setup(tmp_path, total=25)
    tr2 = Trainer(step2, state2, data2, lc2, log=lambda *a: None)
    assert tr2.step == 20  # restored
    tr2.run()
    assert tr2.step == 25


def test_straggler_detection(tmp_path):
    step, state, data, lc = _setup(tmp_path, total=12)
    lc.straggler_factor = 1.5

    slow = {"n": 0}

    def slow_step(s, b):
        slow["n"] += 1
        if slow["n"] == 10:
            time.sleep(0.5)
        return step(s, b)

    tr = Trainer(slow_step, state, data, lc, log=lambda *a: None)
    tr.run()
    assert tr.stragglers >= 1


def test_nan_rollback(tmp_path):
    step, state, data, lc = _setup(tmp_path, total=15)
    calls = {"n": 0}

    def flaky_step(s, b):
        calls["n"] += 1
        new_s, m = step(s, b)
        if calls["n"] == 12:
            m = dict(m, loss=jnp.float32(float("nan")))
        return new_s, m

    tr = Trainer(flaky_step, state, data, lc, log=lambda *a: None)
    tr.run()
    assert tr.step == 15
    assert all(np.isfinite(l) for l in tr.losses)
