"""Device encoding engine: bit-identity with the numpy reference stages.

The engine's contract (repro.core.lossless.engine) is that every
``encode_device`` twin produces a payload byte-for-byte equal to the numpy
encoder's, so device-encoded sections drop into existing containers and a
sharded writer stays interchangeable with a single-host one. These tests
pin that contract at every level: stage, pipeline stream, orchestrator
choice, and full compressor container.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.lossless import bitshuffle as bs  # noqa: E402
from repro.core.lossless import engine as eng  # noqa: E402
from repro.core.lossless import huffman as hf  # noqa: E402
from repro.core.lossless import orchestrate as orc  # noqa: E402
from repro.core.lossless import pipelines as pp  # noqa: E402
from repro.core.lossless import rre, tcms  # noqa: E402
from repro.core.lossless.stages import registered_stages  # noqa: E402


def _streams():
    rng = np.random.default_rng(0)
    yield "random", rng.integers(0, 256, 5000, dtype=np.uint8)
    yield "skewed", np.minimum(rng.zipf(1.5, 5000), 255).astype(np.uint8)
    yield "runs", np.repeat(rng.integers(0, 4, 100, dtype=np.uint8), 57)[:5000]
    yield "zeros", np.zeros(4096, np.uint8)
    yield "tiny", np.array([128], np.uint8)
    yield "empty", np.zeros(0, np.uint8)
    yield "single-symbol", np.full(3000, 7, np.uint8)
    yield "chunk", rng.integers(0, 256, hf.CHUNK, dtype=np.uint8)
    yield "chunk-1", rng.integers(0, 256, hf.CHUNK - 1, dtype=np.uint8)
    yield "chunk+1", rng.integers(0, 256, hf.CHUNK + 1, dtype=np.uint8)
    yield "deepskew", np.clip(rng.normal(128, 2.5, 1 << 17), 0, 255).astype(np.uint8)


STREAMS = list(_streams())


@pytest.mark.parametrize("name,data", STREAMS)
def test_hf_device_bit_identical(name, data):
    payload, hdr = hf.encode(data)
    pdev, hdev = eng.hf_encode_device(jnp.asarray(data))
    assert hdev == hdr, name
    assert np.asarray(pdev).tobytes() == payload, name


@pytest.mark.parametrize("k", [1, 4, 8])
@pytest.mark.parametrize("name,data", STREAMS)
def test_rre_rze_device_bit_identical(k, name, data):
    d = jnp.asarray(data)
    payload, hdr = rre.rre_encode(data, k)
    pdev, hdev = eng.rre_encode_device(d, k)
    assert (hdev, np.asarray(pdev).tobytes()) == (hdr, payload), name
    payload, hdr = rre.rze_encode(data, k)
    pdev, hdev = eng.rze_encode_device(d, k)
    assert (hdev, np.asarray(pdev).tobytes()) == (hdr, payload), name


@pytest.mark.parametrize("k", [1, 2, 4, 8])
@pytest.mark.parametrize("name,data", STREAMS)
def test_tcms_device_bit_identical(k, name, data):
    payload, hdr = tcms.tcms_encode(data, k)
    pdev, hdev = eng.tcms_encode_device(jnp.asarray(data), k)
    assert (hdev, np.asarray(pdev).tobytes()) == (hdr, payload), name


@pytest.mark.parametrize("name,data", STREAMS)
def test_bit1_device_bit_identical(name, data):
    payload, hdr = bs.bitshuffle_encode(data)
    pdev, hdev = eng.bit1_encode_device(jnp.asarray(data))
    assert (hdev, np.asarray(pdev).tobytes()) == (hdr, payload), name


def test_hf_device_seam_skip_fuzz():
    """Chunk seams are byte- (not word-) aligned: the gap between pair
    starts can hop a whole 32-bit word. Random multi-chunk streams across
    several symbol laws exercise the seam-repair path."""
    rng = np.random.default_rng(7)
    for t in range(60):
        n = int(rng.integers(1, 6 * hf.CHUNK))
        data = np.clip(
            np.round(rng.laplace(rng.integers(0, 256), rng.choice([0.5, 2.0, 8.0, 40.0]), n)),
            0, 255,
        ).astype(np.uint8)
        ref, _ = hf.encode(data)
        got, _ = eng.hf_encode_device(jnp.asarray(data))
        assert np.asarray(got).tobytes() == ref, (t, n)


def test_hf_device_multi_slab_bit_identical(monkeypatch):
    """Streams beyond _PAR_SLAB split into async-dispatched slabs whose
    payloads must concatenate byte-exactly. Shrinking the slab size forces
    several slabs (plus a partial tail chunk) without a huge stream."""
    rng = np.random.default_rng(11)
    data = np.clip(np.round(rng.laplace(128.0, 8.0, 5 * (1 << 16) + 777)), 0, 255).astype(np.uint8)
    ref, ref_hdr = hf.encode(data)
    monkeypatch.setattr(eng, "_PAR_SLAB", 1 << 16)  # 5 slabs + tail
    got, hdr = eng.hf_encode_device(jnp.asarray(data))
    assert hdr == ref_hdr
    assert np.asarray(got).tobytes() == ref


def test_every_builtin_stage_has_device_twin_except_zstd():
    stages = registered_stages()
    for name, st in stages.items():
        if name == "zstd":
            assert st.encode_device is None
        else:
            assert st.encode_device is not None, name


@pytest.mark.parametrize("pipe", sorted(pp.registered_pipelines()))
@pytest.mark.parametrize("name,data", STREAMS[:6])
def test_pipeline_device_stream_bit_identical(pipe, name, data):
    """Device-resident pipeline encode == host encode, for every registered
    pipeline (crz exercises the host fallback for the zstd stage)."""
    host = pp.encode(data, pipe)
    dev = pp.encode(jnp.asarray(data), pipe)
    assert dev == host, (pipe, name)
    assert np.array_equal(pp.decode(dev), data), (pipe, name)


def test_stream_stats_device_matches_host():
    rng = np.random.default_rng(3)
    data = np.clip(np.round(rng.laplace(128, 6, 200_000)), 0, 255).astype(np.uint8)
    sh = orc.stream_stats(orc.sample_stream(data), n_total=data.size)
    sd = orc.stream_stats(orc.sample_stream(jnp.asarray(data)), n_total=data.size)
    assert sh == sd  # exact equality: integer histograms, exact ratios


def test_encode_auto_device_matches_host():
    rng = np.random.default_rng(4)
    for data in (
        np.clip(np.round(rng.laplace(128, 8, 150_000)), 0, 255).astype(np.uint8),
        np.repeat(rng.integers(126, 131, 3000, dtype=np.uint8), 64),
        np.where(rng.random(120_000) < 0.02, rng.integers(0, 256, 120_000), 128).astype(np.uint8),
    ):
        bh, rh = orc.encode_auto(data)
        bd, rd = orc.encode_auto(jnp.asarray(data))
        assert bh == bd
        assert rh == rd  # same stats, same estimates, same chosen pipeline


def test_compressor_engine_paths_bit_identical(smooth3d):
    from repro.core import Compressor, CompressorSpec

    for pipeline in ("cr", "auto"):
        specs = [CompressorSpec(eb=1e-3, pipeline=pipeline, engine=e)
                 for e in ("numpy", "device", "auto")]
        bufs = [Compressor(s).compress(smooth3d) for s in specs]
        assert bufs[0] == bufs[1] == bufs[2], pipeline
        out = Compressor(specs[0]).decompress(bufs[1])
        rng = float(smooth3d.max() - smooth3d.min())
        assert np.abs(out - smooth3d).max() <= 1e-3 * rng * (1 + 1e-5) + 1e-9


def test_compressor_engine_validation():
    from repro.core import CompressorSpec

    with pytest.raises(ValueError, match="unknown engine"):
        CompressorSpec(engine="gpu")


def test_hf_nworkers_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_HF_WORKERS", "3")
    assert hf._nworkers() == 3
    monkeypatch.setenv("REPRO_HF_WORKERS", "not-a-number")
    assert hf._nworkers() >= 1
    monkeypatch.setenv("REPRO_HF_WORKERS", "-2")
    assert hf._nworkers() >= 1
    monkeypatch.delenv("REPRO_HF_WORKERS")
    assert hf._nworkers() >= 1
