"""Per-kernel interpret-mode validation against the pure-jnp/numpy oracles,
sweeping shapes and configurations."""
import numpy as np
import pytest

from repro.core.stencils import build_steps
from repro.kernels.bitshuffle import bitshuffle_pallas, bitshuffle_ref
from repro.kernels.histogram import histogram256_pallas, histogram256_ref
from repro.kernels.interp3d import compress_blocks_pallas, compress_blocks_ref
from repro.kernels.lorenzo3d import lorenzo_encode_pallas, lorenzo_encode_ref


@pytest.mark.parametrize("spline", ["linear", "cubic"])
@pytest.mark.parametrize("scheme", ["md", "1d"])
@pytest.mark.parametrize("nb", [1, 5])
def test_interp3d_matches_ref(spline, scheme, nb):
    rng = np.random.default_rng(nb)
    blocks = rng.standard_normal((nb, 17, 17, 17)).astype(np.float32)
    steps = build_steps(3, 17, (8, 4, 2, 1), (spline,) * 4, (scheme,) * 4)
    ck, ok, rk = compress_blocks_pallas(blocks, 0.01, steps)
    cr, orf, rr = compress_blocks_ref(blocks, 0.01, steps)
    assert (ck == cr).mean() > 0.9999  # fp tie-breaks only
    assert np.allclose(rk, rr, atol=2 * 0.01)
    assert np.abs(rk - blocks)[~ok].max() <= 0.01 + 1e-6  # error bound (non-outlier)


@pytest.mark.parametrize("eb", [1e-1, 1e-3])
def test_interp3d_anchor8(eb):
    rng = np.random.default_rng(7)
    blocks = rng.standard_normal((3, 17, 17, 17)).astype(np.float32)
    steps = build_steps(3, 17, (4, 2, 1), ("cubic",) * 3, ("1d",) * 3)
    ck, _, rk = compress_blocks_pallas(blocks, eb, steps, anchor_every=8)
    cr, _, rr = compress_blocks_ref(blocks, eb, steps, anchor_every=8)
    assert (ck == cr).mean() > 0.9999


@pytest.mark.parametrize("shape", [(8, 8, 128), (20, 24, 130), (33, 7, 250)])
@pytest.mark.parametrize("eb", [0.5, 0.01])
def test_lorenzo3d_matches_ref(shape, eb):
    rng = np.random.default_rng(hash(shape) % 2**31)
    x = rng.standard_normal(shape).astype(np.float32)
    ck, ok, cfk = lorenzo_encode_pallas(x, eb)
    cr, orf, cfr = lorenzo_encode_ref(x, eb)
    assert (ck == cr).all() and (ok == orf).all() and (cfk == cfr).all()


@pytest.mark.parametrize("n", [1, 1000, 8192, 100000])
def test_bitshuffle_matches_ref(n):
    d = np.random.default_rng(n).integers(0, 256, n, dtype=np.uint8)
    assert (bitshuffle_pallas(d) == bitshuffle_ref(d)).all()


@pytest.mark.parametrize("n", [1, 8192, 100001])
def test_histogram_matches_ref(n):
    d = np.random.default_rng(n).integers(0, 256, n, dtype=np.uint8)
    assert (histogram256_pallas(d) == histogram256_ref(d)).all()
