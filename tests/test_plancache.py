"""Plan cache: LRU mechanics, field signatures, and Compressor integration.

Covers the contract the compressd daemon leans on: recurring field
signatures skip both tuners (predictor plan + orchestrator pipeline
choice) and replay the recorded outcome to an equivalent container, while
distinct shapes/dtypes/bounds/spec-knobs never collide.
"""
import numpy as np
import pytest

import repro.core.compressor as compressor_mod
from repro.core import Compressor, CompressorSpec, PlanCache, plan_signature, stats_bucket
from repro.core.autotune import PredictorPlan


def _field(seed=0, n=24):
    g = np.linspace(0, 4 * np.pi, n)
    X, Y, Z = np.meshgrid(g, g, g, indexing="ij")
    rng = np.random.default_rng(seed)
    return (np.sin(X + seed) * np.cos(Y) * np.sin(Z)
            + 0.01 * rng.standard_normal(X.shape)).astype(np.float32)


# --------------------------------------------------------------- unit: LRU
def test_lru_hit_miss_eviction_counters():
    c = PlanCache(max_entries=2)
    assert c.get("a") is None  # miss
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1 and c.get("b") == 2  # hits
    c.put("c", 3)  # evicts LRU ("a": it was refreshed, then "b"... order: get(a), get(b) -> a is LRU)
    assert "a" not in c and c.get("c") == 3
    st = c.stats()
    assert st["entries"] == 2 and st["max_entries"] == 2
    assert st["misses"] == 1 and st["hits"] == 3 and st["evictions"] == 1
    assert st["hit_rate"] == pytest.approx(3 / 4)


def test_lru_recency_refresh_on_hit():
    c = PlanCache(max_entries=2)
    c.put("a", 1)
    c.put("b", 2)
    c.get("a")      # refresh "a"; "b" becomes LRU
    c.put("c", 3)
    assert "a" in c and "b" not in c and "c" in c


def test_lru_put_overwrites_and_peek_keeps_counters():
    c = PlanCache(max_entries=4)
    c.put("k", "old")
    c.put("k", "new")
    assert len(c) == 1 and c.peek("k") == "new"
    assert c.stats()["hits"] == 0 and c.stats()["misses"] == 0  # peek is silent
    c.clear()
    assert len(c) == 0 and c.peek("k") is None


def test_lru_capacity_one_thrashes():
    c = PlanCache(max_entries=1)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") is None and c.get("b") == 2
    assert c.stats()["evictions"] == 1


# --------------------------------------------------------- unit: signatures
def test_plan_signature_distinguishes_every_axis():
    base = dict(shape=(32, 32), dtype=np.float32, eb=1e-3, eb_mode="rel")

    def sig(**over):
        kw = dict(base, **over)
        bucket = kw.pop("bucket", (0, 0))
        extra = kw.pop("extra", ())
        return plan_signature(kw["shape"], kw["dtype"], kw["eb"], kw["eb_mode"],
                              bucket, extra=extra)

    ref = sig()
    assert sig() == ref  # deterministic
    assert sig(shape=(32, 33)) != ref
    assert sig(dtype=np.float64) != ref
    assert sig(eb=1e-4) != ref
    assert sig(eb_mode="abs") != ref
    assert sig(bucket=(1, 0)) != ref
    assert sig(extra=("interp",)) != ref


def test_plan_signature_is_hashable_and_serial_stable():
    s = plan_signature((8, 8), "float32", 1e-3, "rel", (2, -1), extra=("auto", 4))
    assert hash(s) == hash(plan_signature((8, 8), np.float32, 1e-3, "rel", (2, -1),
                                          extra=("auto", 4)))
    {s: 1}  # usable as a dict key


def test_stats_bucket_behaviour():
    x = _field(0)
    assert stats_bucket(x) == stats_bucket(x.copy())
    # scaling the value range by 2**8 moves the range-exponent bucket but
    # keeps the (range-normalized) spread bucket
    b0, b1 = stats_bucket(x), stats_bucket(x * 256.0)
    assert b1[0] == b0[0] + 8 and b1[1] == b0[1]
    # degenerate fields get sentinel buckets, not crashes
    assert stats_bucket(np.zeros(64, np.float32))[0] < -1000
    assert stats_bucket(np.full(64, np.nan, np.float32))[0] < -1000
    assert stats_bucket(np.full(64, 3.0, np.float32))[0] < -1000


def test_predictor_plan_bytes_roundtrip():
    hdr = {"ndim": 3, "anchor_stride": 4, "splines": ["cubic", "cubic"],
           "schemes": ["md", "md"]}
    plan = PredictorPlan.from_header(hdr)
    again = PredictorPlan.from_bytes(plan.to_bytes())
    assert again.to_header() == plan.to_header()


# ----------------------------------------------------- Compressor integration
@pytest.fixture
def counting_tuners(monkeypatch):
    """Count invocations of both tuners without changing their behavior."""
    calls = {"plan": 0, "autotune": 0}
    real_plan, real_tune = compressor_mod.autotune_plan, compressor_mod.autotune

    def plan_wrap(*a, **kw):
        calls["plan"] += 1
        return real_plan(*a, **kw)

    def tune_wrap(*a, **kw):
        calls["autotune"] += 1
        return real_tune(*a, **kw)

    monkeypatch.setattr(compressor_mod, "autotune_plan", plan_wrap)
    monkeypatch.setattr(compressor_mod, "autotune", tune_wrap)
    return calls


def test_cache_skips_plan_tuner_and_replays(counting_tuners):
    x = _field(0)
    cache = PlanCache(max_entries=8)
    comp = Compressor(CompressorSpec(eb=1e-3, predictor="auto", pipeline="auto"),
                      plan_cache=cache)
    b1 = comp.compress(x)
    assert comp.last_telemetry["plan_cache"] == "miss"
    assert counting_tuners["plan"] == 1
    pipe1 = comp.last_telemetry["pipeline"]

    b2 = comp.compress(x)
    assert comp.last_telemetry["plan_cache"] == "hit"
    assert counting_tuners["plan"] == 1  # tuner NOT re-run
    assert comp.last_telemetry["pipeline"] == pipe1  # orchestrator choice replayed
    assert Compressor.inspect(b2).get("pcached") is True
    assert Compressor.inspect(b1).get("pcached") is None
    # the replayed container decodes bit-identically to the tuned one
    assert np.array_equal(comp.decompress(b1), comp.decompress(b2))
    y = comp.decompress(b2)
    assert np.max(np.abs(x - y)) <= 1e-3 * (x.max() - x.min()) * (1 + 1e-5)
    assert cache.stats() == {"entries": 1, "max_entries": 8, "hits": 1, "misses": 1,
                             "evictions": 0, "hit_rate": 0.5}


def test_cache_skips_spline_tuner_for_interp_autotune(counting_tuners):
    x = _field(1)
    comp = Compressor(CompressorSpec(eb=1e-3, predictor="interp", autotune=True),
                      plan_cache=PlanCache(4))
    comp.compress(x)
    comp.compress(x)
    assert counting_tuners["autotune"] == 1
    assert comp.last_telemetry["plan_cache"] == "hit"


def test_distinct_fields_do_not_collide(counting_tuners):
    cache = PlanCache(max_entries=8)
    comp = Compressor(CompressorSpec(eb=1e-3, predictor="auto", pipeline="auto"),
                      plan_cache=cache)
    comp.compress(_field(0))
    comp.compress(_field(0, n=20))          # different shape
    comp.compress(_field(0) * 1e4)          # different stats bucket
    assert counting_tuners["plan"] == 3
    assert cache.stats()["hits"] == 0 and len(cache) == 3
    # spec knobs partition too: same field, different eb
    comp2 = Compressor(CompressorSpec(eb=1e-2, predictor="auto", pipeline="auto"),
                       plan_cache=cache)
    comp2.compress(_field(0))
    assert counting_tuners["plan"] == 4 and len(cache) == 4


def test_shared_cache_across_compressors(counting_tuners):
    cache = PlanCache(max_entries=8)
    spec = CompressorSpec(eb=1e-3, predictor="auto", pipeline="auto")
    Compressor(spec, plan_cache=cache).compress(_field(0))
    Compressor(spec, plan_cache=cache).compress(_field(0))  # fresh instance, same cache
    assert counting_tuners["plan"] == 1
    assert cache.stats()["hits"] == 1


def test_eviction_pressure_retunes(counting_tuners):
    cache = PlanCache(max_entries=1)
    comp = Compressor(CompressorSpec(eb=1e-3, predictor="auto", pipeline="auto"),
                      plan_cache=cache)
    a, b = _field(0), _field(0, n=20)
    comp.compress(a)
    comp.compress(b)   # evicts a
    comp.compress(a)   # must re-tune
    assert counting_tuners["plan"] == 3
    assert cache.stats()["evictions"] >= 2


def test_no_cache_means_no_telemetry_key_and_fixed_spec_uncacheable():
    x = _field(0)
    comp = Compressor(CompressorSpec(eb=1e-3))  # no plan_cache attached
    comp.compress(x)
    assert "plan_cache" not in comp.last_telemetry
    # fully fixed spec: nothing tunable, cache stays empty even when attached
    cache = PlanCache(4)
    fixed = Compressor(CompressorSpec(eb=1e-3, predictor="interp", autotune=False,
                                      pipeline="tp"), plan_cache=cache)
    fixed.compress(x)
    assert "plan_cache" not in fixed.last_telemetry and len(cache) == 0
