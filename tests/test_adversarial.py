"""Adversarial-corpus sweep: bound-or-typed-error on every cell.

The contract (see :mod:`repro.testing.adversarial`): for every corpus
field and every spec, either the round-trip honors the declared bound —
bit-exactly on non-finite points, within eb on finite points — or
``compress`` raises a typed error (``ValueError`` family /
``BoundViolationError``). Silent corruption is the only forbidden
outcome. The tier-1 sweep runs the full grid under the chaos seed
(``REPRO_FAULTS`` replays a failing cell exactly); the tier-2 hypothesis
sweep feeds arbitrary float32 fields, NaN/Inf included.
"""
import numpy as np
import pytest

from repro.core import Compressor, CompressorSpec, max_abs_err
from repro.core.errors import BoundViolationError, SpecError
from repro.testing import CORPUS, corpus_field
from repro.testing.faults import fault_seed

# verify=full makes the contract airtight: every point is checked after
# encode, so a surviving container *proves* the bound and anything else
# must have raised
SPECS = [
    "lossy,abs,1e-2,verify=full",
    "lossy,rel,1e-3,verify=full",
    "lossy,pw_rel,1e-2,verify=full",
]

TYPED_ERRORS = (ValueError, SpecError, BoundViolationError)


def _bits(a: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(a, np.float32).view(np.uint32)


def _assert_bound(x: np.ndarray, y: np.ndarray, spec: CompressorSpec) -> None:
    assert y.shape == x.shape and y.dtype == np.float32
    fin = np.isfinite(x)
    # non-finite points restore bit-exactly (NaN payloads, Inf signs)
    assert np.array_equal(_bits(x[~fin]), _bits(y[~fin]))
    assert np.isfinite(y[fin]).all()
    if not fin.any():
        return
    xf = x[fin].astype(np.float64)
    yf = y[fin].astype(np.float64)
    tol = 2e-4  # the systemwide f32-rounding slack (1e-4) plus margin
    if spec.eb_mode == "abs":
        assert np.max(np.abs(xf - yf)) <= spec.eb * (1 + tol)
    elif spec.eb_mode == "rel":
        rng = float(np.max(xf)) - float(np.min(xf))
        assert np.max(np.abs(xf - yf)) <= spec.eb * rng * (1 + tol) + 1e-30
    else:  # pw_rel: per-point, zeros exact
        zero = xf == 0.0
        assert np.array_equal(_bits(x[fin][zero]), _bits(y[fin][zero]))
        nz = ~zero
        if nz.any():
            assert np.max(np.abs(xf[nz] - yf[nz]) / np.abs(xf[nz])) <= spec.eb * (1 + tol)


@pytest.mark.parametrize("spec_str", SPECS)
@pytest.mark.parametrize("name", sorted(CORPUS))
def test_bound_or_typed_error(name, spec_str):
    x = corpus_field(name, seed=fault_seed())
    spec = CompressorSpec.from_string(spec_str)
    comp = Compressor(spec)
    try:
        buf = comp.compress(x)
    except TYPED_ERRORS:
        return  # typed refusal is a legal outcome; silence is not
    _assert_bound(x, comp.decompress(buf), spec)


@pytest.mark.parametrize("name", sorted(CORPUS))
def test_default_verify_sample_contract(name):
    """The default spec (verify=sample) satisfies the same contract on the
    corpus: the non-finite canonicalization is exact by construction and
    the deterministic sample covers these field sizes entirely."""
    x = corpus_field(name, seed=fault_seed())
    spec = CompressorSpec(eb=1e-3)
    comp = Compressor(spec)
    try:
        buf = comp.compress(x)
    except TYPED_ERRORS:
        return
    tel = comp.last_telemetry or {}
    if np.isfinite(x).any() and not np.isfinite(x).all():
        assert tel.get("nonfinite", {}).get("n", 0) > 0
    _assert_bound(x, comp.decompress(buf), spec)


def test_all_nonfinite_short_circuits():
    x = corpus_field("all_nan")
    comp = Compressor(CompressorSpec(eb=1e-3))
    buf = comp.compress(x)
    assert len(buf) < 1024  # trivial container, no predictor ran
    y = comp.decompress(buf)
    assert np.array_equal(_bits(x), _bits(y).reshape(x.shape))


def test_finite_containers_unchanged_by_verify():
    """verify costs zero bytes: a finite field encodes to the identical
    container whether verification runs or not."""
    x = corpus_field("single_voxel_outlier")
    b_off = Compressor(CompressorSpec(eb=1e-3, verify="off")).compress(x)
    b_on = Compressor(CompressorSpec(eb=1e-3, verify="full")).compress(x)
    assert b_off == b_on


def test_sweep_is_seed_deterministic():
    a = corpus_field("scattered_nonfinite", seed=123)
    b = corpus_field("scattered_nonfinite", seed=123)
    assert np.array_equal(_bits(a), _bits(b))


# --------------------------------------------------------------- tier 2
@pytest.mark.tier2
def test_hypothesis_bound_or_typed_error():
    hypothesis = pytest.importorskip("hypothesis", reason="optional dev dependency")
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.extra import numpy as hnp

    @given(
        data=hnp.arrays(
            np.float32,
            hnp.array_shapes(min_dims=1, max_dims=3, min_side=1, max_side=24),
            elements=st.floats(width=32, allow_nan=True, allow_infinity=True),
        ),
        eb=st.sampled_from([1e-1, 1e-3]),
        mode=st.sampled_from(["abs", "rel", "pw_rel"]),
    )
    @settings(max_examples=40, deadline=None)
    def prop(data, eb, mode):
        spec = CompressorSpec(eb=eb, eb_mode=mode, autotune=False, verify="full")
        comp = Compressor(spec)
        try:
            buf = comp.compress(data)
        except TYPED_ERRORS:
            return
        _assert_bound(data, comp.decompress(buf), spec)

    prop()


@pytest.mark.tier2
def test_property_max_abs_err_ignores_nonfinite():
    x = corpus_field("nan_slab")
    y = np.where(np.isfinite(x), x, 0.0).astype(np.float32)
    assert np.isfinite(max_abs_err(x, y))
