"""Spec-string grammar: parse/format round-trip, typed errors, and the
new error modes (pw_rel, psnr_target) the grammar exposes."""
import numpy as np
import pytest

from repro.core import Compressor, CompressorSpec, SpecError, max_rel_err, psnr
from repro.data import load_real_fields


# ------------------------------------------------------------------ grammar
def test_from_string_basics():
    sp = CompressorSpec.from_string("lossy,abs,1e-3")
    assert sp.eb_mode == "abs" and sp.eb == 1e-3

    sp = CompressorSpec.from_string("lossy,rel,0.01,predictor=auto,pipeline=auto")
    assert sp.predictor == "auto" and sp.pipeline == "auto" and sp.eb_mode == "rel"

    sp = CompressorSpec.from_string("lossy,pw_rel,1e-2")
    assert sp.eb_mode == "pw_rel" and sp.eb == 1e-2

    sp = CompressorSpec.from_string("lossy,psnr,60")
    assert sp.psnr_target == 60.0

    sp = CompressorSpec.from_string(
        "lossy,abs,1e-3,autotune=false,splines=cubic:linear:cubic:cubic,anchor_stride=8")
    assert sp.autotune is False
    assert sp.splines == ("cubic", "linear", "cubic", "cubic")
    assert sp.anchor_stride == 8


@pytest.mark.parametrize("s", [
    "lossy,abs,1e-3",
    "lossy,rel,0.001",
    "lossy,pw_rel,0.01",
    "lossy,psnr,60.0",
    "lossy,abs,1e-3,predictor=auto,pipeline=auto",
    "lossy,rel,1e-4,anchor_stride=8,autotune=false,reorder=false",
    "lossy,abs,0.5,pipeline_candidates=hf:tp,engine=numpy",
    "lossy,psnr,42.5,predictor=interp,pipeline=cr",
])
def test_round_trip(s):
    sp = CompressorSpec.from_string(s)
    again = CompressorSpec.from_string(sp.to_string())
    assert again == sp
    # canonical form is a fixed point
    assert again.to_string() == sp.to_string()


def test_to_string_skips_defaults():
    assert CompressorSpec(eb=1e-3, eb_mode="abs").to_string() == "lossy,abs,0.001"
    # non-defaults appear, sorted
    s = CompressorSpec(eb=1e-3, eb_mode="abs", predictor="auto", autotune=False).to_string()
    assert s == "lossy,abs,0.001,autotune=false,predictor=auto"


def test_psnr_head_form():
    sp = CompressorSpec(psnr_target=60.0)
    assert sp.to_string().startswith("lossy,psnr,60")
    assert CompressorSpec.from_string(sp.to_string()) == sp


@pytest.mark.parametrize("bad", [
    "",
    "lossy",
    "lossy,abs",
    "bogus,abs,1e-3",
    "lossy,bogus,1e-3",
    "lossy,abs,not-a-number",
    "lossy,abs,1e-3,unknownkey=1",
    "lossy,abs,1e-3,eb=2",               # duplicate of the head value
    "lossy,abs,1e-3,predictor",          # key without value
    "lossy,pw_rel,0",                    # pw_rel needs eb > 0
    "lossy,psnr,-5",                     # target must be positive
    "lossy,psnr,60,eb_mode=pw_rel",      # mutually exclusive
    "lossy,abs,1e-3,autotune=maybe",     # bad bool
    "lossless",                          # dataset-level, not a lossy spec
])
def test_invalid_specs_raise_typed_error(bad):
    with pytest.raises(SpecError):
        CompressorSpec.from_string(bad)
    # SpecError is a ValueError for pre-grammar handlers
    with pytest.raises(ValueError):
        CompressorSpec.from_string(bad)


# --------------------------------------------------------------- error modes
def test_pw_rel_bound_on_real_fixture():
    x = load_real_fields()["humidity"][:48, :64]
    eb = 1e-2
    comp = Compressor(CompressorSpec.from_string(
        f"lossy,pw_rel,{eb},pipeline=cr,autotune=false"))
    buf = comp.compress(x)
    y = comp.decompress(buf)
    assert max_rel_err(x, y) <= eb
    hdr = Compressor.inspect(buf)
    assert hdr["mode"] == "pw_rel" and hdr["eb_rel"] == eb
    assert "inner" in hdr  # the log-domain container is inspectable too


def test_pw_rel_signs_and_zeros_exact():
    rng = np.random.default_rng(3)
    x = (np.exp(rng.normal(0, 2, (24, 24, 24)))
         * rng.choice([-1.0, 1.0], (24, 24, 24))).astype(np.float32)
    x[0, :4, :4] = 0.0
    comp = Compressor(CompressorSpec.from_string("lossy,pw_rel,1e-2,autotune=false"))
    y = comp.decompress(comp.compress(x))
    assert np.all(y[x == 0] == 0)
    nz = x != 0
    assert np.all(np.sign(y[nz]) == np.sign(x[nz]))
    assert max_rel_err(x, y) <= 1e-2


def test_pw_rel_too_tight_for_f32_raises():
    x = np.linspace(1.0, 2.0, 4096, dtype=np.float32).reshape(64, 64)
    comp = Compressor(CompressorSpec.from_string("lossy,pw_rel,1e-8"))
    with pytest.raises(ValueError, match="resolution"):
        comp.compress(x)


def test_psnr_target_within_1db_on_real_fixture():
    x = load_real_fields()["temperature"][:48, :64]
    target = 60.0
    comp = Compressor(CompressorSpec.from_string(
        f"lossy,psnr,{target},pipeline=cr,autotune=false"))
    buf = comp.compress(x)
    search = comp.last_telemetry.get("psnr_search")
    y = comp.decompress(buf)
    achieved = psnr(x, y)
    assert achieved >= target - 1.0
    hdr = Compressor.inspect(buf)
    assert hdr["psnr_target"] == target
    # the searched bound is recorded like any fixed one: decode is oblivious
    assert hdr["eb_abs"] > 0
    assert search and search["trials"] >= 2


def test_psnr_target_constant_field_is_lossless():
    x = np.full((32, 32), 7.25, np.float32)
    comp = Compressor(CompressorSpec.from_string("lossy,psnr,60"))
    y = comp.decompress(comp.compress(x))
    assert np.array_equal(x, y)


# ----------------------------------------------------------- spec validation
def test_constructor_validation():
    with pytest.raises(ValueError):
        CompressorSpec(eb_mode="pw_rel", eb=0.0)
    with pytest.raises(ValueError):
        CompressorSpec(psnr_target=-1.0)
    with pytest.raises(ValueError):
        CompressorSpec(psnr_target=60.0, eb_mode="pw_rel")


# ------------------------------------------------------- verify spec field
def test_verify_spec_string_roundtrip():
    sp = CompressorSpec.from_string("lossy,rel,1e-3,verify=full")
    assert sp.verify == "full"
    assert CompressorSpec.from_string(sp.to_string()) == sp
    # the default mode is canonical and omitted from the string form
    assert "verify" not in CompressorSpec(eb=1e-3).to_string()
    assert CompressorSpec(eb=1e-3).verify == "sample"


def test_verify_spec_validation():
    with pytest.raises(ValueError):
        CompressorSpec(verify="always")
    with pytest.raises(Exception):
        CompressorSpec.from_string("lossy,rel,1e-3,verify=nope")


def test_pw_rel_signed_zero_bits_exact():
    # -0.0 and +0.0 must both survive with their sign bit intact: the sign
    # bitmap records signbit over every point, not just the nonzero ones
    x = np.linspace(-1.0, 1.0, 576, dtype=np.float32).reshape(24, 24)
    flat = x.reshape(-1)
    flat[0::7] = 0.0
    flat[1::7] = -0.0
    comp = Compressor(CompressorSpec.from_string("lossy,pw_rel,1e-2,autotune=false"))
    y = comp.decompress(comp.compress(x))
    zero = x == 0
    assert np.array_equal(x[zero].view(np.uint32), y[zero].view(np.uint32))
    assert max_rel_err(x, y) <= 1e-2


def test_pw_rel_sub_resolution_names_offender():
    x = np.linspace(1.0, 5.0, 4096, dtype=np.float32).reshape(64, 64)
    comp = Compressor(CompressorSpec.from_string("lossy,pw_rel,1e-8"))
    with pytest.raises(ValueError) as ei:
        comp.compress(x)
    msg = str(ei.value)
    assert "|x|=" in msg and "eb_mode='abs'" in msg  # actionable: names the magnitude
