"""Table 5: ablation — incremental cuSZ-Hi features over the cuSZ-I(B) base.

Increments (paper order):
  cusz-ib      : stride-8 anchors, 3 levels, 1D scheme, HF (+zstd as the
                 Bitcomp stand-in)
  +partition   : stride-16 anchors / 17^3 isotropic blocks (4 levels)
  +reorder     : level-grouped code mapping (Eq. 3)
  +md+autotune : multi-dimensional interpolation + per-level auto-tuning
  cusz-hi-cr   : full open-source CR lossless pipeline
  +plan        : plan-driven predictor (spline x ordering x stride planner)
"""
from __future__ import annotations

import zstandard

from repro.core import Compressor, CompressorSpec

from .common import get_data

_STEPS = [
    ("cusz-ib", CompressorSpec(predictor="interp", pipeline="hf", anchor_stride=8, autotune=False,
                               splines=("cubic",) * 3, schemes=("1d",) * 3, reorder=False), True),
    ("+partition", CompressorSpec(predictor="interp", pipeline="hf", anchor_stride=16, autotune=False,
                                  splines=("cubic",) * 4, schemes=("1d",) * 4, reorder=False), True),
    ("+reorder", CompressorSpec(predictor="interp", pipeline="hf", anchor_stride=16, autotune=False,
                                splines=("cubic",) * 4, schemes=("1d",) * 4, reorder=True), True),
    ("+md+autotune", CompressorSpec(predictor="interp", pipeline="hf", anchor_stride=16, autotune=True,
                                    reorder=True), True),
    ("cusz-hi-cr", CompressorSpec(predictor="interp", pipeline="cr", anchor_stride=16, autotune=True,
                                  reorder=True), False),
    ("+plan", CompressorSpec(predictor="auto", pipeline="cr", reorder=True), False),
    ("cusz-hi-crz(beyond)", CompressorSpec(predictor="interp", pipeline="crz", anchor_stride=16, autotune=True,
                                           reorder=True), False),
]


def run(*, full: bool = False, data_dir: str | None = None, datasets=("jhtdb", "miranda", "nyx", "rtm"), ebs=(1e-2, 1e-3)):
    rows = []
    cctx = zstandard.ZstdCompressor(level=3)
    for ds in datasets:
        x = get_data(ds, full=full, data_dir=data_dir)
        for eb in ebs:
            prev = None
            for name, spec, add_zstd in _STEPS:
                import dataclasses

                c = Compressor(dataclasses.replace(spec, eb=eb))
                buf = c.compress(x)
                size = len(cctx.compress(buf)) if add_zstd else len(buf)
                cr = x.nbytes / size
                rows.append({
                    "table": "table5", "dataset": ds, "eb": eb, "variant": name,
                    "cr": round(cr, 2),
                    "delta_pct": round(100.0 * (cr / prev - 1.0), 1) if prev else 0.0,
                })
                prev = cr
    return rows
