"""Service-lane benchmark: N concurrent clients against a compressd daemon.

    PYTHONPATH=src python -m benchmarks.bench_compressd --clients 8 --smoke \
        --out bench_compressd_smoke.json

Boots an in-process :class:`repro.launch.compressd.CompressdServer` (or
targets an external one via ``--addr``), then drives ``--clients``
threads, each cycling a small set of *recurring* field shapes through
compress + decompress roundtrips — the daemon's design load, where the
shared plan cache should absorb every tuning cost after warmup.

Reported per op kind: p50/p99 latency (ms), aggregate MB/s across all
clients, CR. The plan-cache claim is **asserted, not just timed**: after
a one-pass warmup, every measured compress response must report
``plan_cache == "hit"`` (each client echoes the daemon's per-response
telemetry); any miss fails the bench with a nonzero exit. Peak admitted
bytes stay bounded by the daemon's in-flight budget, and the run checks
the budget drains back to zero at the end.

The JSON output carries the grid (smoke flag, clients, shapes, eb) so
``benchmarks.check_service_regression`` can refuse to compare unlike
runs. Timing gates belong to the checker, with generous machine-variance
tolerance; CR and the hit assertion are deterministic.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time

import numpy as np

from repro.launch.compressd import CompressdClient, CompressdServer

FULL_SHAPES = [(64, 64, 64), (32, 64, 64), (96, 96)]
SMOKE_SHAPES = [(24, 24, 24), (16, 24, 24), (48, 48)]


def _make_fields(shapes) -> list[np.ndarray]:
    """One seeded smooth-plus-noise field per shape, shared by all clients
    (identical bytes -> identical plan signatures -> recurring load)."""
    fields = []
    for seed, shape in enumerate(shapes):
        rng = np.random.default_rng(seed)
        axes = [np.linspace(0, 4 * np.pi, n) for n in shape]
        mesh = np.meshgrid(*axes, indexing="ij")
        x = np.ones(shape, np.float32)
        for i, m in enumerate(mesh):
            x = x * np.sin(m + 0.3 * i).astype(np.float32)
        x += 0.01 * rng.standard_normal(shape).astype(np.float32)
        fields.append(np.ascontiguousarray(x, np.float32))
    return fields


def _spec(eb: float) -> str:
    # canonical spec-string grammar (CompressorSpec.from_string)
    return f"lossy,rel,{eb:g},predictor=auto,pipeline=auto"


def _percentiles(ms: list[float]) -> dict:
    arr = np.asarray(ms, np.float64)
    return {"p50_ms": float(np.percentile(arr, 50)),
            "p99_ms": float(np.percentile(arr, 99)),
            "mean_ms": float(arr.mean()), "n": int(arr.size)}


def run(addr: str, fields, *, clients: int, requests: int, eb: float) -> dict:
    # ---- warmup: populate the plan cache (and jit caches) once per shape
    containers = {}
    with CompressdClient(addr, stream="bench-warmup") as c:
        for i, x in enumerate(fields):
            containers[i] = c.compress(x, spec=_spec(eb))
            c.decompress(containers[i])

    comp_lat: list[float] = []
    deco_lat: list[float] = []
    misses: list[dict] = []
    raw_bytes = [0]
    comp_bytes = [0]
    errors: list[str] = []
    lock = threading.Lock()
    start_gate = threading.Barrier(clients + 1)

    def client_loop(k: int):
        try:
            with CompressdClient(addr, stream=f"bench-{k}") as c:
                start_gate.wait(timeout=60)
                for j in range(requests):
                    x = fields[(k + j) % len(fields)]
                    t0 = time.perf_counter()
                    buf = c.compress(x, spec=_spec(eb))
                    dt_c = time.perf_counter() - t0
                    info = dict(c.last_info or {})
                    t0 = time.perf_counter()
                    y = c.decompress(buf)
                    dt_d = time.perf_counter() - t0
                    if y.shape != x.shape:
                        raise RuntimeError(f"shape mismatch {y.shape} != {x.shape}")
                    with lock:
                        comp_lat.append(dt_c * 1e3)
                        deco_lat.append(dt_d * 1e3)
                        raw_bytes[0] += x.nbytes
                        comp_bytes[0] += len(buf)
                        if info.get("plan_cache") != "hit":
                            misses.append({"client": k, "req": j, "shape": list(x.shape),
                                           "plan_cache": info.get("plan_cache")})
        except Exception as e:  # pragma: no cover - failure path
            with lock:
                errors.append(f"client {k}: {e!r}")

    threads = [threading.Thread(target=client_loop, args=(k,)) for k in range(clients)]
    for t in threads:
        t.start()
    start_gate.wait(timeout=60)
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise RuntimeError("client failures: " + "; ".join(errors))

    with CompressdClient(addr) as c:
        stats = c.stats()
    n_ops = len(comp_lat)
    doc = {
        "compress": {**_percentiles(comp_lat),
                     "mbps_aggregate": raw_bytes[0] / (sum(comp_lat) / 1e3) / 1e6 * clients
                     if comp_lat else 0.0},
        "decompress": {**_percentiles(deco_lat),
                       "mbps_aggregate": raw_bytes[0] / (sum(deco_lat) / 1e3) / 1e6 * clients
                       if deco_lat else 0.0},
        "wall_seconds": wall,
        "roundtrips_per_s": n_ops / wall if wall > 0 else 0.0,
        # bytes crossing the compressor in both directions over wall clock:
        # the number a capacity plan would use
        "mbps_wall": (2 * raw_bytes[0]) / wall / 1e6 if wall > 0 else 0.0,
        "cr": raw_bytes[0] / max(comp_bytes[0], 1),
        "plan_cache": stats["plan_cache"],
        "plan_cache_ok": not misses,
        "plan_cache_misses_post_warmup": misses,
        "inflight_bytes_at_end": stats["queue"]["inflight_bytes"],
    }
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests", type=int, default=None,
                    help="roundtrips per client (default: 4 smoke, 12 full)")
    ap.add_argument("--eb", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true", help="small fields for CI")
    ap.add_argument("--addr", default=None,
                    help="target an already-running daemon instead of in-process")
    ap.add_argument("--workers", type=int, default=4, help="in-process daemon width")
    ap.add_argument("--out", default=None, help="write the result JSON here")
    args = ap.parse_args(argv)

    shapes = SMOKE_SHAPES if args.smoke else FULL_SHAPES
    requests = args.requests if args.requests is not None else (4 if args.smoke else 12)
    fields = _make_fields(shapes)

    server = None
    addr = args.addr
    if addr is None:
        server = CompressdServer("127.0.0.1:0", workers=args.workers).start()
        addr = server.address
    try:
        doc = run(addr, fields, clients=args.clients, requests=requests, eb=args.eb)
    finally:
        if server is not None:
            server.close()

    doc = {
        "bench": "compressd",
        "smoke": bool(args.smoke),
        "clients": args.clients,
        "requests_per_client": requests,
        "eb": args.eb,
        "shapes": [list(s) for s in shapes],
        **doc,
    }
    print(json.dumps(doc, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
    if not doc["plan_cache_ok"]:
        print(f"FAIL: {len(doc['plan_cache_misses_post_warmup'])} post-warmup compress "
              "responses were not plan-cache hits", file=sys.stderr)
        return 1
    if doc["inflight_bytes_at_end"] != 0:
        print("FAIL: in-flight byte budget did not drain to zero", file=sys.stderr)
        return 1
    c, d = doc["compress"], doc["decompress"]
    print(f"compressd bench: {args.clients} clients x {requests} roundtrips, "
          f"compress p50 {c['p50_ms']:.1f} ms / p99 {c['p99_ms']:.1f} ms, "
          f"decompress p50 {d['p50_ms']:.1f} ms / p99 {d['p99_ms']:.1f} ms, "
          f"CR {doc['cr']:.2f}, plan-cache hits asserted on all "
          f"{c['n']} measured ops", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
