"""Quality gate for the CI io lane (real-field fixture bench).

    PYTHONPATH=src python -m benchmarks.check_io_regression \
        --baseline BENCH_io_smoke.json --fresh bench_io_smoke.json

Checks every (field, spec) cell of a fresh ``bench_lossless --fixture
real --metrics`` JSON two ways:

* **absolute quality contracts** on the fresh run alone — PSNR at or
  above the header-implied floor ``20*log10(range/eb_abs)`` (an abs
  bound of eb_abs caps MSE at eb_abs^2, so falling below the floor means
  the bound itself broke), achieved PSNR within ``--psnr-slack`` dB of
  ``psnr_target`` on target rows, and ``max_rel_err <= eb`` on pw_rel
  rows;
* **relative regression** against the committed baseline — compression
  ratio within ``--max-drop-pct`` of the baseline cell, and no baseline
  cell missing from the fresh run.

Timing columns are ignored (machine-dependent); the fixtures are the
committed seeded npz, so CR and the quality columns are deterministic.
"""
from __future__ import annotations

import argparse
import json
import sys


def cells(doc: dict) -> dict:
    out = {}
    for row in doc.get("stages", []):
        if row.get("fixture") == "real":
            out[(row["stream"], row["spec"])] = row
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--max-drop-pct", type=float, default=5.0,
                    help="max CR drop vs the baseline cell")
    ap.add_argument("--psnr-slack", type=float, default=1.0,
                    help="max dB below psnr_target an achieved PSNR may land")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    for field in ("smoke", "fixture"):
        if base.get(field) != fresh.get(field):
            print(f"GRID MISMATCH: {field} baseline={base.get(field)} "
                  f"fresh={fresh.get(field)} (the gate only compares like-for-like runs)")
            return 1
    bcells, fcells = cells(base), cells(fresh)
    floor = 1.0 - args.max_drop_pct / 100.0
    failures = []
    for key, row in sorted(fcells.items()):
        tag = f"{key[0]} [{key[1]}]"
        if "psnr_floor" in row and "psnr" in row:
            if row["psnr"] < row["psnr_floor"]:
                failures.append(f"{tag}: PSNR {row['psnr']:.2f} dB below header-implied "
                                f"floor {row['psnr_floor']:.2f} dB")
        if "psnr_target" in row and "psnr" in row:
            if row["psnr"] < row["psnr_target"] - args.psnr_slack:
                failures.append(f"{tag}: PSNR {row['psnr']:.2f} dB missed target "
                                f"{row['psnr_target']:.1f} dB by more than {args.psnr_slack:g}")
        if "eb_rel" in row and "max_rel_err" in row:
            if row["max_rel_err"] > row["eb_rel"]:
                failures.append(f"{tag}: max_rel_err {row['max_rel_err']:.3e} "
                                f"exceeds pw_rel eb {row['eb_rel']:.3e}")
    compared = 0
    for key, brow in sorted(bcells.items()):
        tag = f"{key[0]} [{key[1]}]"
        if key not in fcells:
            failures.append(f"{tag}: cell missing from fresh run (was CR {brow['cr']:.3f})")
            continue
        compared += 1
        fcr = fcells[key]["cr"]
        if fcr < brow["cr"] * floor:
            failures.append(f"{tag}: CR {brow['cr']:.3f} -> {fcr:.3f} "
                            f"({(fcr / brow['cr'] - 1) * 100:+.2f}%)")
    print(f"io gate: {len(fcells)} cells quality-checked, {compared} compared "
          f"against baseline (CR tolerance {args.max_drop_pct:g}%, "
          f"PSNR slack {args.psnr_slack:g} dB)")
    if failures:
        print("FAILURES:")
        for f_ in failures:
            print(" ", f_)
        return 1
    print("(timing columns ignored by design)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
