"""Lossless hot-path benchmark: MB/s per stage + CR, emitted as JSON.

    PYTHONPATH=src python -m benchmarks.bench_lossless [--out BENCH_lossless.json]
    PYTHONPATH=src python -m benchmarks.bench_lossless --smoke   # tiny CI grid

Measures each lossless stage on a 4 MiB quantization-code-like stream (the
codec's actual workload: Laplacian codes centered on 128) across the
``engine`` dimension (``--engines``: ``numpy`` = the reference host
stages, ``device`` = the jit/Pallas encoding engine of
repro.core.lossless.engine, verified byte-identical before timing) — in
*both* directions: every stage/pipeline/end-to-end row carries decode
columns (``dec_mbps``, and ``dec_dev_mbps`` where a decode twin exists),
with byte-identity between the decode paths asserted before any timing,
sweeps *every registered pipeline* plus the orchestrated ``auto`` mode
over a synthetic byte-stream suite (each row carries a ``pipeline``
dimension with CR + MB/s), sweeps the fixed-steps predictor
configurations plus the
plan-driven ``predictor="auto"`` over a synthetic *field* suite (each row
carries a ``predictor`` dimension; the auto rows record the chosen
PredictorPlan and ``cr_vs_best_fixed``), and times the end-to-end
compressor on a smooth float32 field (after JIT warmup). Each timing is
the best of ``--reps`` runs (timeit-style min-time, which rejects
scheduler noise on shared hosts).

``--devices N`` adds a sharded dimension: an (N, side^3) field compressed
device-parallel through ``repro.core.distributed.shard_compress`` (one
container-v3 frame per device shard) vs the host-sequential chunked
writer, timed and CR-recorded like every other row. When jax initialized
with fewer devices the script re-execs itself once with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (fake CPU devices;
the flag must be set before jax starts).

``--smoke`` shrinks every grid (64 KiB streams, 24^3 fields, 1 rep) so CI
can run the whole script in seconds and upload the JSON as an artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

from repro.core import Compressor, CompressorSpec, compression_ratio, cusz_hi_cr, max_abs_err
from repro.core.autotune import fixed_step_baselines
from repro.core.metrics import max_rel_err, psnr, quality_report, value_range
from repro.core.lossless import bitshuffle as bs
from repro.core.lossless import huffman as hf
from repro.core.lossless import orchestrate as orc
from repro.core.lossless import pipelines as pp
from repro.core.lossless import rre, tcms

STREAM_BYTES = 4 << 20
FIELD_SIDE = 64
PRED_FIELD_SIDE = 48  # 27 blocks: the planner samples exhaustively
SMOKE_STREAM_BYTES = 64 << 10
SMOKE_FIELD_SIDE = 24

# The fixed-steps baselines predictor="auto" must match or beat (same
# lossless pipeline, so the comparison isolates the lossy side). Shared
# with tests/test_autotune.py via the importable core/data modules.
FIXED_PREDICTORS = fixed_step_baselines()


def _best(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def quant_code_stream(nbytes: int = STREAM_BYTES, scale: float = 8.0, seed: int = 0) -> np.ndarray:
    """Laplacian uint8 codes centered on 128 — the predictor's output law."""
    rng = np.random.default_rng(seed)
    return np.clip(np.round(rng.laplace(128.0, scale, nbytes)), 0, 255).astype(np.uint8)


def smooth_field(side: int = FIELD_SIDE) -> np.ndarray:
    g = np.stack(np.meshgrid(*[np.linspace(0, 3, side)] * 3, indexing="ij"))
    return (np.sin(g[0] * 2.1) * np.cos(g[1] * 1.7) + 0.5 * np.sin(g[2] * 3.3 + g[0])).astype(np.float32)


def bench_stage(name, enc, dec, data, reps) -> dict:
    payload, hdr = enc(data)
    out = dec(payload, hdr)
    assert np.array_equal(np.asarray(out).view(np.uint8).reshape(-1), data), name
    te = _best(lambda: enc(data), reps)
    td = _best(lambda: dec(payload, hdr), reps)
    nbytes = len(payload) if isinstance(payload, (bytes, bytearray)) else payload.nbytes
    return {
        "stage": name,
        "engine": "numpy",
        "enc_mbps": data.size / te / 1e6,
        "dec_mbps": data.size / td / 1e6,
        "cr": data.size / max(nbytes, 1),
    }


def bench_stage_device(name, enc_dev, dec, data, reps, enc_ref=None, dec_dev=None) -> dict:
    """Engine-dimension twin of bench_stage: the jit/Pallas encode path of
    repro.core.lossless.engine on a device-resident stream. The payload is
    verified byte-identical to the numpy encoder's (the engine contract)
    before timing. ``dec_dev`` times the stage's device decode twin from
    host payload bytes (H2D upload included), verified byte-identical to
    the stream before timing; without one, decode stays on the reference
    path."""
    import jax
    import jax.numpy as jnp

    d = jnp.asarray(data)
    payload, hdr = enc_dev(d)  # also warms the jit caches
    pb = np.asarray(payload).tobytes()
    if enc_ref is not None:  # the contract itself, at bench size
        ref_payload, ref_hdr = enc_ref(data)
        assert pb == ref_payload and hdr == ref_hdr, f"{name}: device != numpy bytes"
    if dec_dev is not None:
        out = dec_dev(pb, hdr)  # warms the decode jit caches
        assert np.array_equal(np.asarray(out).reshape(-1), data), f"{name}: device decode != stream"
        td_fn = lambda: jax.block_until_ready(dec_dev(pb, hdr))  # noqa: E731
    else:
        out = dec(pb, hdr)
        assert np.array_equal(np.asarray(out).view(np.uint8).reshape(-1), data), name
        td_fn = lambda: dec(pb, hdr)  # noqa: E731
    te = _best(lambda: jax.block_until_ready(enc_dev(d)[0]), reps)
    td = _best(td_fn, reps)
    return {
        "stage": name,
        "engine": "device",
        "enc_mbps": data.size / te / 1e6,
        "dec_mbps": data.size / td / 1e6,
        "cr": data.size / max(len(pb), 1),
    }


def synthetic_streams(nbytes: int = STREAM_BYTES) -> dict:
    """The synthetic stream suite: code-stream laws the orchestrator must span."""
    rng = np.random.default_rng(7)
    return {
        "laplace8": quant_code_stream(nbytes, scale=8.0),
        "laplace1": quant_code_stream(nbytes, scale=1.0),
        "runs": np.repeat(rng.integers(126, 131, nbytes // 64, dtype=np.uint8), 64)[:nbytes],
        "sparse": np.where(rng.random(nbytes) < 0.02, rng.integers(0, 256, nbytes), 128).astype(np.uint8),
        "random": rng.integers(0, 256, nbytes, dtype=np.uint8),
    }


def synthetic_fields(side: int = PRED_FIELD_SIDE) -> dict:
    """The synthetic field suite for the predictor dimension: one field per
    regime a spline/scheme/stride choice discriminates (repro.data)."""
    from repro.data import predictor_suite

    return predictor_suite(side)


def sweep_predictors(x: np.ndarray, stream: str, reps: int, eb: float = 1e-3) -> list[dict]:
    """Fixed-steps configs + predictor="auto" on one field; predictor rows."""
    rng = float(x.max() - x.min())
    rows = []

    def case(predictor: str, comp: Compressor) -> dict:
        buf = comp.compress(x)
        y = comp.decompress(buf)
        assert max_abs_err(x, y) <= eb * rng * (1 + 1e-4) + 1e-9, (stream, predictor)
        te = _best(lambda: comp.compress(x), reps)
        td = _best(lambda: comp.decompress(buf), reps)
        return {
            "stage": f"predictor:{predictor}",
            "predictor": predictor,
            "stream": stream,
            "enc_mbps": x.nbytes / te / 1e6,
            "dec_mbps": x.nbytes / td / 1e6,
            "cr": compression_ratio(x, buf),
        }

    for name, cfg in FIXED_PREDICTORS.items():
        rows.append(case(name, Compressor(CompressorSpec(eb=eb, pipeline="cr", autotune=False, **cfg))))
    comp = Compressor(CompressorSpec(eb=eb, predictor="auto", pipeline="cr"))
    row = case("auto", comp)
    row["plan"] = str(comp.last_plan)
    best_fixed = max(r["cr"] for r in rows)
    row["cr_vs_best_fixed"] = row["cr"] / best_fixed
    rows.append(row)
    return rows


def sweep_pipelines(data: np.ndarray, stream: str, reps: int,
                    device: bool = False) -> list[dict]:
    """All registered pipelines + auto on one stream; pipeline dimension rows.
    ``device=True`` adds an ``engine="device"`` row per pipeline: the same
    stream decoded through the stages' decode twins (byte-identity verified
    against the source stream before timing, result on device)."""
    rows = []
    for pipe in sorted(pp.PIPELINES):
        buf = pp.encode(data, pipe)
        assert np.array_equal(pp.decode(buf), data)
        te = _best(lambda: pp.encode(data, pipe), reps)
        td = _best(lambda: pp.decode(buf), reps)
        rows.append(
            {
                "stage": f"pipeline:{pipe}",
                "pipeline": pipe,
                "stream": stream,
                "enc_mbps": data.size / te / 1e6,
                "dec_mbps": data.size / td / 1e6,
                "cr": data.size / len(buf),
            }
        )
        if device:
            import jax
            import jax.numpy as jnp

            dev = jnp.asarray(data)
            dbuf = pp.encode(dev, pipe)  # warms encode jit caches
            assert dbuf == buf, f"{pipe}: device != numpy stream bytes"
            out = pp.decode(buf, device=True)  # warms decode jit caches
            assert np.array_equal(np.asarray(out), data), f"{pipe}: device decode != stream"
            tde = _best(lambda: pp.encode(dev, pipe), reps)
            tdd = _best(lambda: jax.block_until_ready(pp.decode(buf, device=True)), reps)
            rows.append(
                {
                    "stage": f"pipeline:{pipe}",
                    "pipeline": pipe,
                    "engine": "device",
                    "stream": stream,
                    "enc_mbps": data.size / tde / 1e6,
                    "dec_mbps": data.size / tdd / 1e6,
                    "cr": data.size / len(buf),
                }
            )
    buf, record = orc.encode_auto(data)
    assert np.array_equal(pp.decode(buf), data)
    te = _best(lambda: orc.encode_auto(data), reps)
    td = _best(lambda: pp.decode(buf), reps)
    best_fixed = max(r["cr"] for r in rows)
    cr_auto = data.size / len(buf)
    rows.append(
        {
            "stage": "pipeline:auto",
            "pipeline": "auto",
            "stream": stream,
            "picked": record["pipeline"],
            "enc_mbps": data.size / te / 1e6,
            "dec_mbps": data.size / td / 1e6,
            "cr": cr_auto,
            "cr_vs_best_fixed": cr_auto / best_fixed,
        }
    )
    return rows


# The real-fixture spec grid: one abs-mode point (the paper's classic
# regime), the point-wise-relative mode, and the PSNR-target mode — all
# as canonical spec strings, so the bench exercises the same entry point
# (CompressorSpec.from_string) every other consumer uses.
REAL_SPECS = (
    "lossy,rel,1e-3,pipeline=cr,autotune=false",
    "lossy,pw_rel,1e-2,pipeline=cr,autotune=false",
    "lossy,psnr,60,pipeline=cr,autotune=false",
)


def sweep_real_fields(reps: int, smoke: bool, with_metrics: bool) -> list[dict]:
    """The real-field fixture lane: weather/CFD-like structured grids
    (repro.data.realfields, the committed tests/data npz when present)
    swept over the spec-string grid above. Every row verifies its error
    contract before timing — abs bound ≤ header eb, pw_rel max relative
    error ≤ eb, achieved PSNR within 1 dB of target — and (with
    ``--metrics``) carries the full quality_report columns the CI io lane
    gates on."""
    from repro.data import load_real_fields

    rows = []
    for name, field in sorted(load_real_fields().items()):
        if smoke:  # crop, don't subsample: keep the spatial structure
            field = field[tuple(slice(0, min(s, 48 if field.ndim == 2 else 32))
                                for s in field.shape)]
        x = np.ascontiguousarray(field, np.float32)
        rng = value_range(x)
        for spec_str in REAL_SPECS:
            spec = CompressorSpec.from_string(spec_str)
            comp = Compressor(spec)
            buf = comp.compress(x)
            search = (comp.last_telemetry or {}).get("psnr_search")
            y = comp.decompress(buf)
            hdr = Compressor.inspect(buf)
            row = {
                "stage": f"real:{name}", "stream": name, "fixture": "real",
                "spec": spec_str, "value_range": rng, "cr": compression_ratio(x, buf),
            }
            if spec.eb_mode == "pw_rel":
                mre = max_rel_err(x, y)
                assert mre <= spec.eb, (name, spec_str, mre)
                row["eb_rel"] = spec.eb
                row["max_rel_err_vs_eb"] = mre / spec.eb
            else:
                eb_abs = float(hdr["eb_abs"])
                assert max_abs_err(x, y) <= eb_abs * (1 + 1e-4) + 1e-9, (name, spec_str)
                row["eb_abs"] = eb_abs
                if eb_abs > 0:  # the header-implied PSNR floor the gate asserts
                    row["psnr_floor"] = 20.0 * np.log10(rng / eb_abs)
            if spec.psnr_target is not None:
                achieved = psnr(x, y)
                assert achieved >= spec.psnr_target - 1.0, (name, achieved)
                row["psnr_target"] = spec.psnr_target
                if search:
                    row["psnr_search_trials"] = search["trials"]
            if with_metrics:
                row.update(quality_report(x, y, buf))
            te = _best(lambda: comp.compress(x), reps)
            td = _best(lambda: comp.decompress(buf), reps)
            row["enc_mbps"] = x.nbytes / te / 1e6
            row["dec_mbps"] = x.nbytes / td / 1e6
            rows.append(row)
    return rows


def sweep_sharded(devices: int, side: int, reps: int, eb: float = 1e-3) -> list[dict]:
    """Device-parallel shard_compress vs the host-sequential chunked writer
    on an (devices, side^3) field; one row per writer, pipeline=cr."""
    import jax

    from repro.core import chunk_compress, shard_compress, shard_decompress

    base = smooth_field(side)
    x = np.stack([(base * (1 + 0.05 * i)).astype(np.float32) for i in range(devices)])
    spec = CompressorSpec(eb=eb, pipeline="cr", autotune=False)
    buf = shard_compress(x, spec=spec)
    y = shard_decompress(buf)
    rng = float(x.max() - x.min())
    assert max_abs_err(x, y) <= eb * rng * (1 + 1e-5) + 1e-9
    cbuf = chunk_compress(x, n_chunks=devices, spec=spec)  # its own bytes: a
    # chunk-writer size regression must not hide behind the sharded row's CR
    te = _best(lambda: shard_compress(x, spec=spec), reps)
    td = _best(lambda: shard_decompress(buf, workers=devices), reps)
    tc = _best(lambda: chunk_compress(x, n_chunks=devices, spec=spec), reps)
    common = {"pipeline": "cr", "devices": devices,
              "jax_devices": jax.device_count(), "n_frames": devices}
    return [
        dict(common, stage=f"shard_compress:{devices}dev", stream=f"sharded-{devices}dev",
             cr=x.nbytes / len(buf),
             enc_mbps=x.nbytes / te / 1e6, dec_mbps=x.nbytes / td / 1e6),
        dict(common, stage=f"chunk_compress:{devices}dev", stream=f"chunked-{devices}dev",
             cr=x.nbytes / len(cbuf),
             enc_mbps=x.nbytes / tc / 1e6,
             dec_mbps=x.nbytes / _best(lambda: shard_decompress(cbuf), reps) / 1e6),
    ]


def run(reps: int = 5, smoke: bool = False, devices: int = 1,
        engines: tuple = ("numpy", "device"), fixture: str = "synthetic",
        with_metrics: bool = False) -> dict:
    stream_bytes = SMOKE_STREAM_BYTES if smoke else STREAM_BYTES
    if fixture == "real":
        # the quality lane: real-field fixtures only, spec-string grid,
        # metric columns — a separate JSON shape from the hot-path grid
        return {
            "bench": "real_fields",
            "smoke": bool(smoke),
            "fixture": "real",
            "metrics": bool(with_metrics),
            "specs": list(REAL_SPECS),
            "timing": f"best of {reps} reps after warmup",
            "stages": sweep_real_fields(reps, smoke, with_metrics),
        }
    field_side = SMOKE_FIELD_SIDE if smoke else FIELD_SIDE
    pred_side = SMOKE_FIELD_SIDE if smoke else PRED_FIELD_SIDE
    data = quant_code_stream(stream_bytes)
    rows = []
    if "numpy" in engines:
        rows += [
            bench_stage("hf", hf.encode, hf.decode, data, reps),
            bench_stage("rre4", lambda d: rre.rre_encode(d, 4), rre.rre_decode, data, reps),
            bench_stage("rze1", lambda d: rre.rze_encode(d, 1), rre.rze_decode, data, reps),
            bench_stage("tcms8", lambda d: tcms.tcms_encode(d, 8), tcms.tcms_decode, data, reps),
            bench_stage("bit1", bs.bitshuffle_encode, bs.bitshuffle_decode, data, reps),
        ]
    if "device" in engines:
        from repro.core.lossless import engine as eng

        rows += [
            bench_stage_device("hf", eng.hf_encode_device, hf.decode, data, reps,
                               enc_ref=hf.encode, dec_dev=eng.hf_decode_device),
            bench_stage_device("rre4", lambda d: eng.rre_encode_device(d, 4), rre.rre_decode, data, reps,
                               enc_ref=lambda d: rre.rre_encode(d, 4), dec_dev=eng.rre_decode_device),
            bench_stage_device("rze1", lambda d: eng.rze_encode_device(d, 1), rre.rze_decode, data, reps,
                               enc_ref=lambda d: rre.rze_encode(d, 1), dec_dev=eng.rze_decode_device),
            bench_stage_device("tcms8", lambda d: eng.tcms_encode_device(d, 8), tcms.tcms_decode, data, reps,
                               enc_ref=lambda d: tcms.tcms_encode(d, 8), dec_dev=eng.tcms_decode_device),
            bench_stage_device("bit1", eng.bit1_encode_device, bs.bitshuffle_decode, data, reps,
                               enc_ref=bs.bitshuffle_encode, dec_dev=eng.bit1_decode_device),
        ]
    for stream, sdata in synthetic_streams(stream_bytes).items():
        rows.extend(sweep_pipelines(sdata, stream, reps, device="device" in engines))
    for stream, field in synthetic_fields(pred_side).items():
        rows.extend(sweep_predictors(field, stream, reps))
    if devices > 1:
        rows.extend(sweep_sharded(devices, field_side, reps))
    # end-to-end compressor on a smooth field, warmed up (JIT + caches)
    x = smooth_field(field_side)
    comp = cusz_hi_cr(eb=1e-3)
    buf = comp.compress(x)
    y = comp.decompress(buf)
    rng = float(x.max() - x.min())
    assert max_abs_err(x, y) <= 1e-3 * rng * (1 + 1e-5) + 1e-9
    tc = _best(lambda: comp.compress(x), reps)
    td = _best(lambda: comp.decompress(buf), reps)
    rows.append(
        {
            "stage": f"cusz_hi_cr:{field_side}^3",
            "enc_mbps": x.nbytes / tc / 1e6,
            "dec_mbps": x.nbytes / td / 1e6,
            "compress_seconds": tc,
            "decompress_seconds": td,
            "cr": compression_ratio(x, buf),
        }
    )
    # verify-mode overhead: the same end-to-end encode under the runtime
    # bound-verification ladder. The container must be byte-identical in
    # every mode (verification is read-only on a clean encode); the CI
    # gate caps the verify=sample overhead so the default-on guarantee
    # stays cheap.
    t_off = None
    for vmode in ("off", "sample", "full"):
        vcomp = Compressor(CompressorSpec(eb=1e-3, pipeline="cr", autotune=False, verify=vmode))
        vbuf = vcomp.compress(x)
        if vmode == "off":
            base_buf = vbuf
        else:
            assert vbuf == base_buf, f"verify={vmode} changed the container bytes"
        tv = _best(lambda: vcomp.compress(x), reps)
        t_off = tv if t_off is None else t_off
        rows.append(
            {
                "stage": f"verify:{vmode}",
                "verify": vmode,
                "enc_mbps": x.nbytes / tv / 1e6,
                "dec_mbps": x.nbytes / td / 1e6,
                "cr": compression_ratio(x, vbuf),
                "verify_overhead_pct": max(0.0, (tv / t_off - 1.0) * 100.0),
            }
        )
    if "device" in engines:
        # end-to-end decompress-onto-device: decode twins + device
        # reconstruct, result left on device (bit-identity verified)
        import jax

        yd = comp.decompress(buf, out="device")  # warms jit caches
        assert comp.last_telemetry["fallbacks"] == [], comp.last_telemetry
        assert np.array_equal(np.asarray(yd), y), "device decompress != numpy"
        tdd = _best(lambda: jax.block_until_ready(comp.decompress(buf, out="device")), reps)
        rows.append(
            {
                "stage": f"cusz_hi_cr:{field_side}^3",
                "engine": "device",
                "enc_mbps": x.nbytes / tc / 1e6,
                "dec_mbps": x.nbytes / tdd / 1e6,
                "decompress_seconds": tdd,
                "cr": compression_ratio(x, buf),
            }
        )
    return {
        "bench": "lossless_hot_path",
        "smoke": bool(smoke),
        "devices": int(devices),
        "engines": list(engines),
        "stream_bytes": stream_bytes,
        "field": f"{field_side}^3 float32, eb=1e-3 rel",
        "pred_field": f"{pred_side}^3 float32, eb=1e-3 rel, pipeline=cr",
        "timing": f"best of {reps} reps after warmup",
        "stages": rows,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_lossless.json")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI: 64 KiB streams, 24^3 fields, 1 rep")
    ap.add_argument("--devices", type=int, default=1,
                    help="sharded dimension: shard_compress over N (fake CPU) devices")
    ap.add_argument("--engines", default="numpy,device",
                    help="comma-separated lossless-engine dimension to sweep "
                         "over the stage benches (numpy = reference host "
                         "stages, device = jit/Pallas engine)")
    ap.add_argument("--fixture", default="synthetic", choices=("synthetic", "real"),
                    help="real = the weather/CFD fixture lane (spec-string "
                         "grid incl. pw_rel + psnr_target, quality columns)")
    ap.add_argument("--metrics", action="store_true",
                    help="record quality_report columns (psnr/ssim/spectral "
                         "error/...) on every real-fixture row")
    args = ap.parse_args(argv)
    engines = tuple(e.strip() for e in args.engines.split(",") if e.strip())
    for e in engines:
        if e not in ("numpy", "device"):
            ap.error(f"unknown engine {e!r}; choose from numpy,device")
    if args.smoke:
        args.reps = min(args.reps, 1)
    import jax

    if args.devices > 1 and args.devices != jax.device_count() and os.environ.get("_BENCH_REEXEC") != "1":
        # the device count must be fixed before jax initializes: re-exec once
        # (also when jax has MORE devices — n % ndev would otherwise shunt the
        # sharded row through the host-sequential fallback unnoticed).
        # XLA honours the LAST occurrence of a repeated flag, so inherited
        # device-count overrides are stripped, not merely prepended-around.
        inherited = [f for f in os.environ.get("XLA_FLAGS", "").split()
                     if not f.startswith("--xla_force_host_platform_device_count")]
        env = dict(os.environ, _BENCH_REEXEC="1",
                   XLA_FLAGS=" ".join([f"--xla_force_host_platform_device_count={args.devices}"]
                                      + inherited))
        return subprocess.run([sys.executable, os.path.abspath(__file__)]
                              + (argv if argv is not None else sys.argv[1:]), env=env).returncode
    result = run(args.reps, smoke=args.smoke, devices=args.devices, engines=engines,
                 fixture=args.fixture, with_metrics=args.metrics)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    for r in result["stages"]:
        tag = r["stage"] + (f"[{r['stream']}]" if "stream" in r and "fixture" not in r else "")
        if "engine" in r:
            tag += f"({r['engine']})"
        if "spec" in r:
            tag += f"[{r['spec'].split(',pipeline')[0]}]"
        picked = f"  -> {r['picked']}" if "picked" in r else ""
        if "plan" in r:
            picked = f"  -> {r['plan']}  (x{r['cr_vs_best_fixed']:.3f} vs best fixed)"
        if "psnr" in r:
            picked += f"  PSNR {r['psnr']:6.2f} dB  SSIM {r['ssim']:.4f}  spec_err {r['spectral_error']:.4f}"
        print(
            f"{tag:44s} enc {r['enc_mbps']:8.1f} MB/s   dec {r['dec_mbps']:8.1f} MB/s   CR {r['cr']:8.2f}{picked}"
        )
    print(f"-> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
