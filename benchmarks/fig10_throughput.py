"""Figure 10: compression/decompression throughput (CPU-proxy GiB/s).

The paper measures GPU kernel throughput on A100/RTX6000Ada; this container
is CPU-only, so absolute numbers are a proxy — the *relative* ordering of
pipeline costs (TP mode > CR mode; Huffman dominates CR-mode time) is the
reproducible claim. Stage-level timings are also reported.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.lossless import pipelines as pp

from .common import COMPRESSORS, get_data, run_case


def run(*, full: bool = False, data_dir: str | None = None, datasets=("nyx",), ebs=(1e-2, 1e-3)):
    rows = []
    for ds in datasets:
        x = get_data(ds, full=full, data_dir=data_dir)
        for eb in ebs:
            for name, mk in COMPRESSORS.items():
                r = run_case(mk, eb, x)
                rows.append({
                    "table": "fig10", "dataset": ds, "eb": eb, "compressor": name,
                    "comp_gibs": round(r["comp_gibs"], 4), "decomp_gibs": round(r["decomp_gibs"], 4),
                    "comp_us": round(r["comp_us"], 1), "decomp_us": round(r["decomp_us"], 1),
                })
        # stage-level: lossless pipelines on a representative code stream
        from repro.core import Compressor, CompressorSpec

        c = Compressor(CompressorSpec(eb=1e-3, pipeline="none", autotune=False))
        buf = c.compress(x)

        from repro.core.compressor import _sections_unpack

        _, sections = _sections_unpack(buf)
        codes = np.frombuffer(pp.decode(sections[0]), np.uint8)
        for pipe in ("cr", "tp", "hf", "fz"):
            t0 = time.time()
            enc = pp.encode(codes, pipe)
            t1 = time.time()
            pp.decode(enc)
            t2 = time.time()
            rows.append({
                "table": "fig10-stages", "dataset": ds, "compressor": f"pipeline:{pipe}",
                "comp_gibs": round(codes.nbytes / max(t1 - t0, 1e-9) / 2**30, 4),
                "decomp_gibs": round(codes.nbytes / max(t2 - t1, 1e-9) / 2**30, 4),
                "cr": round(codes.nbytes / len(enc), 2),
            })
    return rows
