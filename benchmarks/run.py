"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table4,...]

Prints ``name,us_per_call,derived`` CSV rows per case (derived carries the
table-specific metric: CR / PSNR / GiB/s / roofline terms)."""
from __future__ import annotations

import argparse
import sys
import time


def _emit(rows):
    for r in rows:
        name = ":".join(str(r.get(k)) for k in ("table", "dataset", "arch", "shape", "compressor", "variant", "eb") if r.get(k) is not None)
        us = r.get("comp_us", r.get("us", 0.0))
        derived = {k: v for k, v in r.items() if k not in ("table", "dataset", "arch", "shape", "compressor", "variant", "eb", "comp_us")}
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="full-size datasets (slow)")
    ap.add_argument("--data-dir", default=None, help="real SDRBench files if available")
    ap.add_argument("--only", default="", help="comma list: table4,fig8,fig10,table5,table1,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    from . import fig8_rate_distortion, fig10_throughput, roofline, table1_residual, table4_cr, table5_ablation

    jobs = {
        "table4": lambda: table4_cr.run(full=args.full, data_dir=args.data_dir),
        "fig8": lambda: fig8_rate_distortion.run(full=args.full, data_dir=args.data_dir),
        "fig10": lambda: fig10_throughput.run(full=args.full, data_dir=args.data_dir),
        "table5": lambda: table5_ablation.run(full=args.full, data_dir=args.data_dir),
        "table1": lambda: table1_residual.run(full=args.full, data_dir=args.data_dir),
        "roofline": roofline.run,
    }
    for name, job in jobs.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = job()
            _emit(rows)
            print(f"# {name}: {len(rows)} rows in {time.time()-t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            print(f"# {name}: FAILED {type(e).__name__}: {e}")
            raise
    return 0


if __name__ == "__main__":
    sys.exit(main())
