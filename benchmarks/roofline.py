"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh:
  compute    = flops_per_device / 197e12           (v5e bf16 peak)
  memory     = bytes_per_device / 819e9            (HBM BW)
  collective = collective_bytes_per_device / 50e9  (ICI per link)
plus MODEL_FLOPS (6ND dense / 6·N_active·D MoE; 2N per token decode) and the
useful-compute ratio MODEL_FLOPS / (flops_per_device * n_devices).
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import SHAPES, get_config
from repro.configs.base import active_param_count, param_count

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def model_flops(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    seq, gbatch, kind = SHAPES[shape]
    n = active_param_count(cfg) if cfg.n_experts else param_count(cfg)
    if kind == "train":
        return 6.0 * n * seq * gbatch
    if kind == "prefill":
        return 2.0 * n * seq * gbatch
    return 2.0 * n * gbatch  # decode: one token


def analyze_record(rec: dict) -> dict:
    tot = rec.get("cost_total") or rec.get("cost") or {}
    colls = rec.get("collectives_total") or rec.get("collectives") or {}
    ndev = rec.get("n_partitions", 256)
    flops = float(tot.get("flops", 0.0))
    byt = float(tot.get("bytes", 0.0))
    coll = float(sum(v for k, v in colls.items() if "/" not in k))
    t_compute = flops / PEAK_FLOPS
    t_memory = byt / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(flops * ndev, 1e-30)
    mem = rec.get("memory", {})
    perdev_gib = ((mem.get("argument_bytes") or 0) + (mem.get("temp_bytes") or 0)) / 2**30
    bound = max(terms.values())
    return {
        "table": "roofline",
        "arch": rec["arch"],
        "shape": rec["shape"],
        "t_compute_s": f"{t_compute:.3e}",
        "t_memory_s": f"{t_memory:.3e}",
        "t_collective_s": f"{t_coll:.3e}",
        "bottleneck": dom,
        "model_flops": f"{mf:.3e}",
        "useful_ratio": round(useful, 3),
        "roofline_frac": round(t_compute / max(bound, 1e-30), 3),
        "mem_gib_per_dev": round(perdev_gib, 2),
        "step_time_bound_s": f"{bound:.3e}",
    }


def run(tag: str = "", pod: str = "pod1"):
    rows = []
    for f in sorted(DRYRUN_DIR.glob(f"*__{pod}{('__' + tag) if tag else ''}.json")):
        if not tag and f.stem.count("__") != 2:
            continue
        rec = json.loads(f.read_text())
        rows.append(analyze_record(rec))
    return rows
