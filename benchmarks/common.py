"""Shared benchmark utilities."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    compression_ratio,
    cusz_hi_autoplan,
    cusz_hi_cr,
    cusz_hi_crz,
    cusz_hi_tp,
    cusz_i,
    cusz_l,
    cuszp2_like,
    fzgpu_like,
    max_abs_err,
    psnr,
)
from repro.data import load_or_generate

COMPRESSORS = {
    "cuSZ-Hi-CR": cusz_hi_cr,
    "cuSZ-Hi-TP": cusz_hi_tp,
    "cuSZ-Hi-CRZ": cusz_hi_crz,  # beyond-paper mode
    "cuSZ-Hi-Auto": cusz_hi_autoplan,  # plan-driven predictor + auto pipeline
    "cuSZ-L": cusz_l,
    "cuSZ-I": cusz_i,
    "cuSZp2-like": cuszp2_like,
    "FZGPU-like": fzgpu_like,
}

DATASETS = ["cesm", "jhtdb", "miranda", "nyx", "qmcpack", "rtm"]


def get_data(name: str, *, full: bool = False, data_dir: str | None = None) -> np.ndarray:
    x = load_or_generate(name, data_dir)
    if not full:  # bounded runtime: central crop to <= ~8 MiB
        slices = []
        budget = int(round((2 * 1024 * 1024) ** (1.0 / x.ndim)))
        for d in x.shape:
            take = min(d, max(budget, 32))
            start = (d - take) // 2
            slices.append(slice(start, start + take))
        x = np.ascontiguousarray(x[tuple(slices)])
    return x


def run_case(comp_factory, eb: float, x: np.ndarray) -> dict:
    c = comp_factory(eb=eb)
    t0 = time.time()
    buf = c.compress(x)
    t1 = time.time()
    y = c.decompress(buf)
    t2 = time.time()
    rng = float(x.max() - x.min())
    plan = getattr(c, "last_plan", None)
    return {
        "predictor": c.spec.predictor,
        "plan": None if plan is None else str(plan),
        "cr": compression_ratio(x, buf),
        "psnr": psnr(x, y),
        "maxerr_rel": max_abs_err(x, y) / max(rng, 1e-30),
        "comp_gibs": x.nbytes / max(t1 - t0, 1e-9) / 2**30,
        "decomp_gibs": x.nbytes / max(t2 - t1, 1e-9) / 2**30,
        "comp_us": (t1 - t0) * 1e6,
        "decomp_us": (t2 - t1) * 1e6,
        "ok": max_abs_err(x, y) <= eb * rng * (1 + 1e-4) + 1e-9,
    }
