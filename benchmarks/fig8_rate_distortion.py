"""Figure 8: rate-distortion (bitrate vs decompression PSNR) curves."""
from __future__ import annotations


from .common import COMPRESSORS, get_data, run_case

EBS = [5e-2, 1e-2, 5e-3, 1e-3, 5e-4, 1e-4]


def run(*, full: bool = False, data_dir: str | None = None, datasets=("jhtdb", "miranda"), ebs=None):
    rows = []
    for ds in datasets:
        x = get_data(ds, full=full, data_dir=data_dir)
        for name, mk in COMPRESSORS.items():
            for eb in ebs or EBS:
                r = run_case(mk, eb, x)
                rows.append({
                    "table": "fig8", "dataset": ds, "compressor": name, "eb": eb,
                    "bitrate": 32.0 / max(r["cr"], 1e-9), "psnr": round(r["psnr"], 2), "cr": r["cr"],
                })
    return rows
