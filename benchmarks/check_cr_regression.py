"""CR regression gate for CI (bench-smoke job).

    PYTHONPATH=src python -m benchmarks.check_cr_regression \
        --baseline BENCH_lossless_smoke.json --fresh bench_smoke.json

Compares every (stream, pipeline), (stream, predictor) and (stage,
engine) cell of a fresh bench JSON against the committed baseline and
fails (exit 1) if any cell's compression ratio dropped more than
``--max-drop-pct`` (default 2%), or if a baseline cell vanished (a
pipeline/predictor silently deregistered). Timing columns are ignored —
MB/s is machine-dependent, CR is not: the synthetic streams are seeded
and the arithmetic is deterministic, so a CR drop is a real codec
regression, not noise.

The gate also caps the *verify overhead*: the fresh run's
``verify:sample`` row (the default-on bound-verification mode) must not
cost more than ``--max-verify-overhead-pct`` over ``verify:off`` — a
blown cap means verification regressed from "one decode per encode" to
something pathological (an accidental repair loop, a quadratic check).
This is the one timing-derived check in the gate: it compares a *ratio*
of two timings from the same run on the same machine, so machine speed
cancels out.

The two JSONs must come from the same grid (same ``smoke`` flag and
stream sizes); comparing a smoke run against a full run would diff
different workloads, so that is an error, not a pass. A *dimension*
present in only one of the two runs (e.g. a baseline predating the
``engine`` sweep, or a fresh run with ``--engines`` narrowed) is
tolerated: its cells are skipped with a note instead of reported as
per-cell regressions — adding a sweep dimension must not break the gate
against older baselines.
"""
from __future__ import annotations

import argparse
import json
import sys


def cell_key(row: dict) -> tuple | None:
    """(kind, stream, name) for rows carrying a sweep dimension + CR."""
    if "cr" not in row:
        return None
    if "engine" in row:  # engine dimension (numpy vs device): checked FIRST,
        # so device rows of the pipeline sweep key distinctly from their
        # numpy twins. Each engine value is its own kind: narrowing
        # --engines drops a whole kind (tolerated as a grid difference)
        # instead of leaving per-cell "missing" failures
        return (f"engine/{row['engine']}", row.get("stream", "-"), row["stage"])
    for dim in ("pipeline", "predictor"):
        if dim in row:
            return (dim, row.get("stream", "-"), row[dim])
    return None


def cells(doc: dict) -> dict:
    out = {}
    for row in doc.get("stages", []):
        key = cell_key(row)
        if key is not None:
            out[key] = float(row["cr"])
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--max-drop-pct", type=float, default=2.0)
    ap.add_argument("--max-verify-overhead-pct", type=float, default=300.0,
                    help="cap on the fresh run's verify:sample encode overhead "
                         "vs verify:off (ratio of same-run timings, so "
                         "machine-independent); 0 disables the check")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    for field in ("smoke", "stream_bytes"):
        if base.get(field) != fresh.get(field):
            print(f"GRID MISMATCH: {field} baseline={base.get(field)} fresh={fresh.get(field)} "
                  "(the gate only compares like-for-like runs)")
            return 1
    bcells, fcells = cells(base), cells(fresh)
    floor = 1.0 - args.max_drop_pct / 100.0
    # a sweep dimension absent from one side entirely is a grid difference
    # (old baseline vs new script, or a narrowed sweep), not a regression
    fresh_dims = {k[0] for k in fcells}
    skipped_dims = sorted({k[0] for k in bcells} - fresh_dims)
    failures = []
    compared = 0
    for key, bcr in sorted(bcells.items()):
        if key[0] in skipped_dims:
            continue
        compared += 1
        if key not in fcells:
            failures.append(f"{key}: cell missing from fresh run (was CR {bcr:.3f})")
            continue
        fcr = fcells[key]
        if fcr < bcr * floor:
            failures.append(f"{key}: CR {bcr:.3f} -> {fcr:.3f} ({(fcr / bcr - 1) * 100:+.2f}%)")
    if skipped_dims:
        print(f"note: dimension(s) {', '.join(skipped_dims)} absent from the fresh run; "
              "their baseline cells were skipped (grid difference, not a regression)")
    if args.max_verify_overhead_pct > 0:
        vrows = {r.get("verify"): r for r in fresh.get("stages", []) if "verify" in r}
        if "sample" in vrows:
            ovh = float(vrows["sample"].get("verify_overhead_pct", 0.0))
            if ovh > args.max_verify_overhead_pct:
                failures.append(
                    f"verify:sample overhead {ovh:.1f}% exceeds cap "
                    f"{args.max_verify_overhead_pct:g}% (bound verification "
                    "should cost ~one decode per encode)")
            else:
                print(f"verify gate: sample overhead {ovh:.1f}% "
                      f"(cap {args.max_verify_overhead_pct:g}%)")
        else:
            print("note: fresh run has no verify rows; overhead gate skipped "
                  "(pre-verify bench grid)")
    kept = compared - len(failures)
    print(f"CR gate: {kept}/{compared} cells within {args.max_drop_pct:g}% of baseline")
    if failures:
        print("REGRESSIONS:")
        for f_ in failures:
            print(" ", f_)
        return 1
    improved = sum(1 for k in bcells if k in fcells and fcells[k] > bcells[k])
    print(f"({improved} cells improved; timing columns ignored by design)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
