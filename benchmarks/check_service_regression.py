"""Service-lane regression gate for CI (service job).

    PYTHONPATH=src python -m benchmarks.check_service_regression \
        --baseline BENCH_compressd_smoke.json --fresh bench_compressd_smoke.json

Compares a fresh ``benchmarks.bench_compressd`` JSON against the
committed baseline:

* **grid mismatch** (different smoke flag, client count, shapes or eb):
  exit 1 — unlike runs must not be compared;
* **missing baseline file**: note + exit 0 — a freshly added lane (or a
  branch predating the baseline) skips with a note instead of failing,
  mirroring the bench-smoke job's missing-dimension policy;
* **p99 latency gate**: compress and decompress p99 must stay within
  ``--max-slowdown``x of baseline (default 4x — CI machines vary widely;
  the gate catches order-of-magnitude service regressions like a lost
  plan cache or an admission deadlock, not scheduler jitter);
* **throughput gate**: aggregate MB/s must stay above baseline divided
  by the same slowdown factor;
* **CR gate**: within ``--max-cr-drop-pct`` (default 2%) — the fields
  are seeded, so CR is deterministic;
* **plan-cache gate**: the fresh run's ``plan_cache_ok`` assertion (every
  post-warmup compress a hit) must hold, and the daemon-side hit rate
  must not drop more than ``--max-hit-rate-drop`` absolute.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

GRID_FIELDS = ("bench", "smoke", "clients", "requests_per_client", "eb", "shapes")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--max-slowdown", type=float, default=4.0,
                    help="p99 latency may grow (and MB/s shrink) by this factor")
    ap.add_argument("--max-cr-drop-pct", type=float, default=2.0)
    ap.add_argument("--max-hit-rate-drop", type=float, default=0.05,
                    help="absolute drop allowed in daemon plan-cache hit rate")
    args = ap.parse_args(argv)

    if not os.path.exists(args.baseline):
        print(f"note: baseline {args.baseline} not committed yet; skipping the "
              "service gate (run bench_compressd --smoke and commit the JSON "
              "to arm it)")
        return 0
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    for field in GRID_FIELDS:
        if base.get(field) != fresh.get(field):
            print(f"GRID MISMATCH: {field} baseline={base.get(field)!r} "
                  f"fresh={fresh.get(field)!r} (the gate only compares "
                  "like-for-like runs)")
            return 1

    failures = []
    if not fresh.get("plan_cache_ok", False):
        failures.append("plan_cache_ok is false: post-warmup compresses missed "
                        f"({len(fresh.get('plan_cache_misses_post_warmup', []))} misses)")
    b_hr = float(base.get("plan_cache", {}).get("hit_rate", 0.0))
    f_hr = float(fresh.get("plan_cache", {}).get("hit_rate", 0.0))
    if f_hr < b_hr - args.max_hit_rate_drop:
        failures.append(f"plan-cache hit rate {b_hr:.3f} -> {f_hr:.3f} "
                        f"(allowed drop {args.max_hit_rate_drop})")

    for op in ("compress", "decompress"):
        b_op, f_op = base.get(op, {}), fresh.get(op, {})
        bp99, fp99 = float(b_op.get("p99_ms", 0)), float(f_op.get("p99_ms", 0))
        if bp99 > 0 and fp99 > bp99 * args.max_slowdown:
            failures.append(f"{op} p99 {bp99:.1f} ms -> {fp99:.1f} ms "
                            f"(> {args.max_slowdown:g}x)")
        bmb, fmb = float(b_op.get("mbps_aggregate", 0)), float(f_op.get("mbps_aggregate", 0))
        if bmb > 0 and fmb < bmb / args.max_slowdown:
            failures.append(f"{op} aggregate {bmb:.1f} MB/s -> {fmb:.1f} MB/s "
                            f"(< 1/{args.max_slowdown:g}x)")

    bcr, fcr = float(base.get("cr", 0)), float(fresh.get("cr", 0))
    if bcr > 0 and fcr < bcr * (1 - args.max_cr_drop_pct / 100.0):
        failures.append(f"CR {bcr:.3f} -> {fcr:.3f} "
                        f"(> {args.max_cr_drop_pct:g}% drop)")

    if failures:
        print("SERVICE REGRESSIONS:")
        for f_ in failures:
            print(" ", f_)
        return 1
    print(f"service gate: p99 within {args.max_slowdown:g}x "
          f"(compress {float(base['compress']['p99_ms']):.1f} -> "
          f"{float(fresh['compress']['p99_ms']):.1f} ms), CR {bcr:.3f} -> {fcr:.3f}, "
          f"plan-cache hits asserted ({fresh['compress'].get('n', 0)} ops, "
          f"daemon hit rate {f_hr:.3f})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
