"""Table 4: fixed-error-bound compression ratio per (dataset x eb x compressor)."""
from __future__ import annotations

from .common import COMPRESSORS, DATASETS, get_data, run_case

EBS = [1e-2, 1e-3, 1e-4]


def run(*, full: bool = False, data_dir: str | None = None, datasets=None, ebs=None):
    rows = []
    for ds in datasets or DATASETS:
        x = get_data(ds, full=full, data_dir=data_dir)
        for eb in ebs or EBS:
            best_hi, best_base = 0.0, 0.0
            for name, mk in COMPRESSORS.items():
                r = run_case(mk, eb, x)
                rows.append({"table": "table4", "dataset": ds, "eb": eb, "compressor": name, **r})
                if name.startswith("cuSZ-Hi"):
                    best_hi = max(best_hi, r["cr"])
                else:
                    best_base = max(best_base, r["cr"])
            rows.append({
                "table": "table4", "dataset": ds, "eb": eb, "compressor": "ADV%",
                "cr": round(100.0 * (best_hi / max(best_base, 1e-9) - 1.0), 1),
            })
    return rows
