"""§Perf hillclimb driver: re-lower a cell under a config/rules variant and
report the roofline deltas vs the stored baseline.

    python -m benchmarks.hillclimb --arch gemma3-12b --shape long_500k \
        --tag kvq --cfg kv_quant=1

Results land in experiments/dryrun/<cell>__pod1__<tag>.json and print the
three roofline terms next to the baseline's.
"""
from __future__ import annotations

# must precede jax/repro imports (512 fake devices)
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

import argparse
import ast
import json
import pathlib
import sys

OUT_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def _parse_kv(items):
    out = {}
    for it in items or []:
        k, v = it.split("=", 1)
        try:
            out[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            out[k] = v
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--cfg", nargs="*", help="ModelConfig overrides k=v")
    ap.add_argument("--rules", nargs="*", help="activation-rule overrides k=v")
    args = ap.parse_args(argv)

    from benchmarks.roofline import analyze_record
    from repro.launch.dryrun import run_cell

    cfg_over = _parse_kv(args.cfg)
    rules_over = _parse_kv(args.rules)
    rec = run_cell(args.arch, args.shape, multi_pod=False, cfg_override=cfg_over or None,
                   rules_override=rules_over or None, tag=args.tag)
    name = f"{args.arch}__{args.shape}__pod1__{args.tag}"
    (OUT_DIR / f"{name}.json").write_text(json.dumps(rec, indent=1))
    new = analyze_record(rec)
    base_f = OUT_DIR / f"{args.arch}__{args.shape}__pod1.json"
    if base_f.exists():
        base = analyze_record(json.loads(base_f.read_text()))
        print("metric           baseline        variant         delta")
        for k in ("t_compute_s", "t_memory_s", "t_collective_s", "step_time_bound_s", "mem_gib_per_dev", "useful_ratio", "roofline_frac"):
            b, n = base[k], new[k]
            try:
                d = (float(n) - float(b)) / max(abs(float(b)), 1e-30) * 100.0
                print(f"{k:16s} {b:>14} {n:>14}  {d:+7.1f}%")
            except (TypeError, ValueError):
                print(f"{k:16s} {b:>14} {n:>14}")
        print("bottleneck:", base["bottleneck"], "->", new["bottleneck"])
    else:
        print(json.dumps(new, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
