"""Table 1: residual compressibility of each compressor's OUTPUT.

The paper probes with NVIDIA Bitcomp; we probe with zstd (DESIGN.md §7.3).
A ratio near 1.0 means the pipeline left no redundancy behind (cuSZ-Hi's
claim); large ratios indicate under-used correlation (cuSZ-L, cuSZp2...)."""
from __future__ import annotations

import zstandard

from .common import COMPRESSORS, get_data


def run(*, full: bool = False, data_dir: str | None = None, datasets=("nyx",), eb=1e-2):
    rows = []
    cctx = zstandard.ZstdCompressor(level=3)
    for ds in datasets:
        x = get_data(ds, full=full, data_dir=data_dir)
        for name, mk in COMPRESSORS.items():
            buf = mk(eb=eb).compress(x)
            probe = cctx.compress(buf)
            rows.append({
                "table": "table1", "dataset": ds, "eb": eb, "compressor": name,
                "residual_cr": round(len(buf) / max(len(probe), 1), 3),
            })
    return rows
