"""In-situ distributed field compression — the paper's deployment scenario.

A 3-D simulation field is sharded tile-per-device (data-parallel); every
device compresses its tile independently (the 17^3 block design needs no
halo exchange — DESIGN.md §3), and the host writes one container per tile
plus a manifest. The compressor runs fully orchestrated
(``predictor="auto"`` + ``pipeline="auto"``): the planner tunes the
per-level interpolation (spline/scheme/anchor stride) per tile, the
orchestrator samples each tile's quantization-code stream, and both
choices — the ``PredictorPlan`` and the best-fit lossless pipeline — are
recorded per tile in its container header (``Compressor.inspect``). Run
with fake devices to see the multi-device path:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/compress_field.py
"""
import json
import time

import jax
import numpy as np

from repro.core import Compressor, PredictorPlan, compression_ratio, cusz_hi_autoplan, max_abs_err
from repro.data import get_field

devices = jax.devices()
n = len(devices)
field = get_field("jhtdb")[:128]  # (128, 256, 256)
tiles = np.array_split(field, n, axis=0)
print(f"devices={n}, field {field.shape}, tile ~{tiles[0].shape}")

comp = cusz_hi_autoplan(eb=1e-3)
t0 = time.time()
blobs = [comp.compress(np.ascontiguousarray(t)) for t in tiles]  # per-device tiles
dt = time.time() - t0


def _tile_entry(t, b):
    hdr = Compressor.inspect(b)
    plan = PredictorPlan.from_header(hdr["pplan"])
    return {"shape": list(t.shape), "bytes": len(b), "pipeline": hdr["pipeline"], "plan": str(plan)}


manifest = {
    "tiles": [_tile_entry(t, b) for t, b in zip(tiles, blobs)],
    "total_cr": field.nbytes / sum(len(b) for b in blobs),
}
print(json.dumps(manifest, indent=1))
print(f"aggregate throughput {field.nbytes/dt/2**30:.3f} GiB/s (CPU proxy)")

# verify reconstruction
recon = np.concatenate([comp.decompress(b) for b in blobs], axis=0)
rng = field.max() - field.min()
assert max_abs_err(field, recon) <= 1e-3 * rng * (1 + 1e-5)
print(f"roundtrip ok: CR={compression_ratio(field, b''.join(blobs)):.2f}, error bound holds")
