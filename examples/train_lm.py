"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
compressed checkpointing (cuSZ-Hi codec) and fault-tolerant loop.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.data import Prefetcher, TokenPipeline
from repro.runtime.steps import make_train_state, make_train_step
from repro.runtime.train_loop import LoopConfig, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
args = ap.parse_args()

# ~100M params: mamba2-370m backbone narrowed
cfg = get_config("mamba2-370m").scaled(
    d_model=512, n_layers=8, vocab=8192, ssm_state=64, ssm_headdim=32, ssm_chunk=64
)
from repro.configs.base import param_count

print(f"model: {cfg.name} scaled, ~{param_count(cfg)/1e6:.1f}M params")

state = make_train_state(cfg, jax.random.PRNGKey(0))
step = jax.jit(make_train_step(cfg, None, lr=3e-4), donate_argnums=(0,))
data = Prefetcher(TokenPipeline(cfg.vocab, batch=8, seq=256))
trainer = Trainer(
    step, state, data,
    LoopConfig(total_steps=args.steps, save_every=100, ckpt_dir=args.ckpt_dir, ckpt_eb=1e-4, log_every=25),
)
trainer.run()
k = max(len(trainer.losses) // 10, 1)
print(f"loss: {np.mean(trainer.losses[:k]):.3f} -> {np.mean(trainer.losses[-k:]):.3f}")
assert np.mean(trainer.losses[-k:]) < np.mean(trainer.losses[:k])
print("done: loss decreased; checkpoints written with cuSZ-Hi codec")
