"""Dataset I/O walkthrough: the ``repro.io`` facade end to end.

A small two-variable "weather" dataset (temperature in Kelvin, specific
humidity — the strictly-positive field where a point-wise relative bound
is the scientifically meaningful one) is written as one chunked
container-v3 file. Each variable gets its own compression spec in the
canonical spec-string grammar (``CompressorSpec.from_string``):

* ``t2m``  — absolute bound, 0.05 K;
* ``q``    — ``pw_rel``: every point reconstructs within 1% of its own
  magnitude, signs and exact zeros preserved;

then the file is read back three ways — full dataset, one variable, and
one *chunk* of one variable by random access (only that frame's bytes
are touched) — and per-variable quality is reported with the metrics the
paper evaluates on: PSNR, SSIM, spectral error.

    PYTHONPATH=src python examples/dataset_io.py
"""
import os
import tempfile

import repro.io as rio
from repro.core import quality_report
from repro.data import load_real_fields

fields = load_real_fields()
ds = rio.Dataset(attrs={"title": "weather demo", "source": "repro.data.realfields"})
ds["t2m"] = rio.Variable(fields["temperature"], ("lat", "lon"), {"units": "K"})
ds["q"] = rio.Variable(fields["humidity"], ("lat", "lon"), {"units": "kg/kg"})

path = os.path.join(tempfile.mkdtemp(), "weather.cszh3")
manifest = rio.write(
    ds, path,
    compression={
        "t2m": "lossy,abs,0.05,predictor=auto",
        "q": "lossy,pw_rel,1e-2,predictor=auto",
    },
    chunks=(48, 64),  # 2x2 chunk grid per variable, one v3 frame each
)
raw = sum(v.data.nbytes for v in ds.variables.values())
print(f"wrote {path}: {raw} raw bytes -> {manifest['bytes_written']} "
      f"(CR {raw / manifest['bytes_written']:.2f})")
for v in manifest["variables"]:
    print(f"  {v['name']}{tuple(v['shape'])} spec={v['spec']!r} "
          f"chunks={v['n_chunks']}")

# ---- read back: whole dataset, then one chunk by random access
back = rio.read(path)
corner = rio.read_variable(path, "t2m", chunks=(0, 0))  # top-left 48x64 block
assert corner.shape == (48, 64)
print(f"random access: t2m chunk (0,0) -> {corner.shape}, "
      f"decoded without touching the other {manifest['variables'][0]['n_chunks'] - 1} frames")

# ---- per-variable quality, the paper's evaluation metrics
for name in ds:
    rep = quality_report(ds[name].data, back[name].data)
    print(f"{name}: PSNR {rep['psnr']:.1f} dB  SSIM {rep['ssim']:.4f}  "
          f"spectral_err {rep['spectral_error']:.4f}  "
          f"max_rel_err {rep['max_rel_err']:.2e}")
