"""Quickstart: compress a scientific field with cuSZ-Hi, inspect quality.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import compression_ratio, cusz_hi_cr, cusz_hi_tp, max_abs_err, psnr
from repro.data import get_field

field = get_field("nyx")[:128, :128, :128]  # synthetic cosmology-like field
print(f"field: {field.shape} {field.dtype} ({field.nbytes/2**20:.1f} MiB)")

for name, make in [("CR mode", cusz_hi_cr), ("TP mode", cusz_hi_tp)]:
    comp = make(eb=1e-3)  # value-range-relative error bound
    blob = comp.compress(field)
    recon = comp.decompress(blob)
    rng = field.max() - field.min()
    print(
        f"{name}: CR={compression_ratio(field, blob):7.2f}  "
        f"PSNR={psnr(field, recon):6.2f} dB  "
        f"max|err|/range={max_abs_err(field, recon)/rng:.2e} (bound 1e-3)"
    )
