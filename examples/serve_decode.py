"""Serve a small model with batched requests: prefill + token-by-token decode
(the decode path is what the decode_32k / long_500k dry-run cells lower).

    PYTHONPATH=src python examples/serve_decode.py --arch recurrentgemma-2b
"""
import argparse

from repro.launch.serve import main

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="recurrentgemma-2b")
args = ap.parse_args()

raise SystemExit(main(["--arch", args.arch, "--scaled", "--batch", "4", "--prompt-len", "16", "--tokens", "16"]))
