"""Sharded, streaming compression walkthrough (container v3).

Where examples/compress_field.py loops tiles through ``compress()`` on the
host, this walkthrough uses the PR 4 subsystem end to end:

1. ``shard_compress`` scatters the field across the device mesh and runs
   block gather + interpolation prediction + code emission *on the
   devices* (one ``shard_map`` pass); only the compact uint8 code streams
   come back to host, where each shard gets its own PredictorPlan +
   best-fit lossless pipeline and becomes one container-v3 frame.
2. The v3 stream is written to disk *incrementally* (``out=file``) — each
   frame lands as soon as its shard finishes encoding.
3. Decode is partial, out-of-order, and parallel: any frame subset
   reconstructs just those shards; a thread pool decodes independent
   frames concurrently.

Run with fake devices to see the multi-device path:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/shard_compress.py
"""
import pathlib
import tempfile
import time

import jax
import numpy as np

from repro.core import (
    Compressor,
    CompressorSpec,
    compression_ratio,
    max_abs_err,
    shard_compress,
    shard_decompress,
)
from repro.data import get_field

ndev = jax.device_count()
field = get_field("jhtdb")[:64]  # (64, 256, 256)
print(f"devices={ndev}, field {field.shape} ({field.nbytes / 2**20:.0f} MiB)")

# fully synergistic spec: per-shard plan + per-shard pipeline choice
spec = CompressorSpec(eb=1e-3, predictor="auto", pipeline="auto")

with tempfile.TemporaryDirectory() as d:
    path = pathlib.Path(d) / "field.csz3"
    t0 = time.time()
    with open(path, "wb") as f:
        n_frames = shard_compress(field, spec=spec, out=f)  # frames stream to disk
    dt = time.time() - t0
    blob = path.read_bytes()
    print(f"wrote {n_frames} frames, {len(blob)} bytes in {dt:.2f}s "
          f"(CR {compression_ratio(field, blob):.2f})")

    # every frame records its own plan + pipeline: the synergy is per shard
    hdr = Compressor.inspect(blob)
    for i, fh in enumerate(hdr["frames"]):
        plan = fh.get("pplan")
        print(f"  frame {i}: shape={fh['shape']} pipeline={fh.get('pipeline')} "
              f"plan={'s%d:%s' % (plan['anchor_stride'], ','.join(plan['splines'])) if plan else '-'}")

    # partial decode: only the middle shards, in reverse order
    some = shard_decompress(blob, frames_sel=[3, 2] if n_frames > 3 else [0])
    print(f"partial decode -> {some.shape}")

    # full parallel decode + error-bound check
    t0 = time.time()
    recon = shard_decompress(blob, workers=ndev)
    print(f"parallel decode ({ndev} workers): {time.time() - t0:.2f}s")
    rng = float(field.max() - field.min())
    assert recon.shape == field.shape
    assert max_abs_err(field, recon) <= 1e-3 * rng * (1 + 1e-5)
    print("roundtrip ok: error bound holds on every shard")
