"""Talk to a compressd daemon: the multi-tenant service front end.

Boots an in-process daemon by default so the example is self-contained;
pass ``--addr HOST:PORT`` (or ``unix:/path``) to target one started with

    PYTHONPATH=src python -m repro.launch.compressd --addr 127.0.0.1:7733

Two tenants stream fields concurrently: a "checkpoint" stream writing
the same tensor shape every step and a "kv" stream paging KV-shaped
tensors. After the first request per signature, every compress is a
plan-cache hit — the daemon replays the recorded predictor plan and
pipeline choice instead of re-autotuning — and the final ``stats`` call
shows per-stream CR/MB/s plus the shared cache's hit rate.

    PYTHONPATH=src python examples/compressd_client.py
"""
import argparse
import json
import threading

import numpy as np

from repro.launch.compressd import CompressdClient, CompressdServer

ap = argparse.ArgumentParser()
ap.add_argument("--addr", default=None, help="existing daemon (default: boot in-process)")
ap.add_argument("--steps", type=int, default=4)
args = ap.parse_args()

server = None
addr = args.addr
if addr is None:
    server = CompressdServer("127.0.0.1:0", workers=4).start()
    addr = server.address
    print(f"booted in-process daemon at {addr}")


def checkpoint_tenant():
    """Same parameter geometry every save step — the plan cache's home turf."""
    rng = np.random.default_rng(0)
    g = np.linspace(0, 4 * np.pi, 48)
    base = (np.sin(g)[:, None, None] * np.cos(g)[None, :, None] * np.sin(g)[None, None, :])
    with CompressdClient(addr, stream="checkpoint") as c:
        for step in range(args.steps):
            x = (base + 0.01 * step + 0.005 * rng.standard_normal(base.shape)).astype(np.float32)
            buf = c.compress(x, eb=1e-3, predictor="auto", pipeline="auto")
            info = c.last_info
            print(f"  checkpoint step {step}: CR {info['cr']:.2f}, "
                  f"pipeline {info['pipeline']}, plan_cache {info['plan_cache']}")
            y = c.decompress(buf)
            assert np.max(np.abs(x - y)) <= 1e-3 * (x.max() - x.min()) * (1 + 1e-5)


def kv_tenant():
    """KV-page shapes: a couple of fixed (heads, seq, dim) signatures."""
    rng = np.random.default_rng(1)
    with CompressdClient(addr, stream="kv") as c:
        for step in range(args.steps):
            shape = (4, 64, 32) if step % 2 == 0 else (4, 32, 32)
            x = np.cumsum(rng.standard_normal(shape), axis=1).astype(np.float32)
            c.compress(x, eb=1e-2, pipeline="auto")
            info = c.last_info
            print(f"  kv page {step} {shape}: CR {info['cr']:.2f}, "
                  f"plan_cache {info['plan_cache']}")


threads = [threading.Thread(target=checkpoint_tenant), threading.Thread(target=kv_tenant)]
for t in threads:
    t.start()
for t in threads:
    t.join()

with CompressdClient(addr) as c:
    st = c.stats()
print("\nper-stream telemetry:")
for name, rec in sorted(st["streams"].items()):
    print(f"  {name}: {rec['requests']} requests, CR {rec['cr']:.2f}, "
          f"{rec['mbps']:.1f} MB/s, {rec['plan_cache_hits']} cache hits")
print("shared plan cache:", json.dumps(st["plan_cache"]))
if server is not None:
    server.close()
